"""Per-architecture smoke tests: REDUCED same-family configs, one forward
+ train-grad step on CPU, asserting output shapes and no NaNs — plus
prefill/decode-vs-forward consistency for the cache paths, and eval_shape
parameter-count fidelity for the FULL configs (no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models.api import build_model, make_batch, param_count_shape_only

BATCH, SEQ = 2, 32


def small(arch):
    return get_config(arch).reduced()


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_forward_and_grad_step(self, arch):
        cfg = small(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        batch = make_batch(cfg, "train", BATCH, SEQ)

        @jax.jit
        def step(p):
            (l, metrics), g = jax.value_and_grad(model.loss,
                                                 has_aux=True)(p, batch)
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                                 for x in jax.tree_util.tree_leaves(g)))
            return l, metrics["ce"], gnorm

        loss, ce, gnorm = step(params)
        assert np.isfinite(float(loss)), f"{arch}: loss NaN/inf"
        assert np.isfinite(float(gnorm)), f"{arch}: grad NaN/inf"
        # untrained CE should be near log(vocab)
        assert 0.2 * np.log(cfg.vocab) < float(ce) < 3 * np.log(cfg.vocab)

    def test_prefill_decode_shapes(self, arch):
        cfg = small(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(1))
        batch = make_batch(cfg, "train", BATCH, SEQ)
        cache = model.init_cache(BATCH, SEQ + 4)
        if cfg.family in ("rwkv",):
            cache = model.init_cache(BATCH, SEQ)
        logits, cache = jax.jit(model.prefill)(params, batch, cache)
        assert logits.shape == (BATCH, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        dec_batch = make_batch(cfg, "decode", BATCH, 1)
        logits2, cache = jax.jit(model.decode)(params, dec_batch, cache)
        assert logits2.shape == (BATCH, cfg.vocab)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()
        assert int(cache["len"]) == SEQ + 1


# ---------------------------------------------------------------------------
# cache correctness: teacher-forced forward logits == prefill+decode logits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mistral_nemo_12b", "gemma2_9b",
                                  "rwkv6_7b", "zamba2_7b", "dbrx_132b"])
def test_decode_matches_forward(arch):
    """Prefill on s tokens then decode token s must equal the teacher-forced
    forward logits at position s (same params, fp32 compute)."""
    cfg = small(arch)
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(2))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab, size=(BATCH, SEQ + 1)).astype(np.int32)

    # teacher-forced logits at position SEQ-1 predict token SEQ
    from repro.models import transformer as T
    from repro.models import layers as L

    full_batch = {"tokens": jnp.asarray(toks),
                  "labels": jnp.asarray(toks)}
    # hidden via the model's internal path: use loss's logits indirectly —
    # easier: prefill on SEQ+1 tokens returns logits at the LAST position.
    cache_a = model.init_cache(BATCH, SEQ + 1, jnp.float32)
    ref_logits, _ = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks)}, cache_a)

    # prefill on SEQ tokens, then decode token SEQ
    cache_b = model.init_cache(BATCH, SEQ + 1, jnp.float32)
    _, cache_b = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks[:, :SEQ])}, cache_b)
    got_logits, _ = jax.jit(model.decode)(
        params, {"tokens": jnp.asarray(toks[:, SEQ:SEQ + 1])}, cache_b)

    np.testing.assert_allclose(np.asarray(got_logits),
                               np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# full-config parameter fidelity (eval_shape only — no allocation)
# ---------------------------------------------------------------------------

PUBLISHED_PARAMS = {
    # arch: (published total, tolerance) — stub-frontend archs compare
    # against the published BACKBONE share.
    "starcoder2_15b": (15.0e9, 0.10),
    "minitron_8b": (8.0e9, 0.08),
    "mistral_nemo_12b": (12.2e9, 0.05),
    "gemma2_9b": (9.2e9, 0.05),
    "dbrx_132b": (132e9, 0.03),
    "kimi_k2_1t": (1000e9, 0.05),
    "qwen2_vl_2b": (1.5e9, 0.10),       # backbone share of the 2B VLM
    "seamless_m4t_medium": (0.6e9, 0.15),  # text backbone of 1.2B model
    "zamba2_7b": (7.0e9, 0.08),
    "rwkv6_7b": (7.0e9, 0.10),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    n = param_count_shape_only(get_config(arch))
    target, tol = PUBLISHED_PARAMS[arch]
    assert abs(n - target) / target < tol, \
        f"{arch}: {n/1e9:.2f}B vs published {target/1e9:.1f}B"
