"""Hypothesis property tests on system invariants.

Invariants covered:
  * MultiWrite delivers exactly-once to exactly the destination set, for
    ANY topology/destination combination — and never puts more bytes on
    any link than unicast does.
  * Fabric-family forwarding tables: ``path()`` never loops, rail-first
    grouping holds for every (server count, rail count) combo, and the
    multiwrite combine ledger mirrors the dispatch ledger on symmetric
    fabrics.
  * The latency model is monotone in message size and respects the
    scheme ordering at large sizes.
  * Checkpoint save/restore is identity for arbitrary pytrees.
  * Data pipeline determinism across host splits.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import latency_model as lm
from repro.core import schedules as sch
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import ClusterSpec, full_mesh, two_server_cluster


@st.composite
def cluster_specs(draw):
    """Arbitrary small fabrics: (servers, npus, rails) with rails <= npus."""
    servers = draw(st.integers(1, 4))
    npus = draw(st.integers(2, 6))
    rails = draw(st.integers(1, min(3, npus))) if servers > 1 else 1
    return ClusterSpec(num_servers=servers, npus_per_server=npus,
                       rails_per_npu=rails)


class TestMultiWriteProperties:
    @settings(max_examples=40, deadline=None)
    @given(src=st.integers(0, 15),
           dests=st.sets(st.integers(0, 15), min_size=1, max_size=10),
           nbytes=st.integers(1, 2048))
    def test_exactly_once_delivery_two_server(self, src, dests, nbytes):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        data = np.arange(nbytes, dtype=np.uint8)
        sim.multiwrite(src, {d: "x" for d in dests}, data)
        for d in dests:
            np.testing.assert_array_equal(sim.memory[d]["x"], data)
            assert sim.delivery_count[(d, "x")] == 1
        # nobody else got it
        for node in range(topo.num_nodes):
            if node not in dests:
                assert (node, "x") not in sim.delivery_count

    @settings(max_examples=30, deadline=None)
    @given(src=st.integers(0, 15),
           dests=st.sets(st.integers(0, 15), min_size=1, max_size=10),
           nbytes=st.integers(1, 1024))
    def test_never_worse_than_unicast_per_link(self, src, dests, nbytes):
        """MultiWrite bytes <= unicast bytes on EVERY link (the paper's
        §3.3 principle as a universally-quantified invariant)."""
        topo = two_server_cluster()
        data = np.arange(nbytes, dtype=np.uint8)
        mw, uni = MultiWriteSimulator(topo), MultiWriteSimulator(topo)
        mw.multiwrite(src, {d: "x" for d in dests}, data)
        for d in dests:
            if d != src:
                uni.write(src, d, "x", data)
            else:
                uni.memory[d]["x"] = data
        for link, b in mw.link_bytes.items():
            assert b <= uni.link_bytes.get(link, 0) + 0, \
                f"link {link}: mw {b} > unicast {uni.link_bytes.get(link)}"

    @settings(max_examples=30, deadline=None)
    @given(n=st.sampled_from([4, 6, 8, 12]), seed=st.integers(0, 999))
    def test_full_mesh_single_hop_no_relay_cost(self, n, seed):
        """On a full mesh with no relay hint, MultiWrite == n unicasts
        (every destination is one hop away: rule 3 degenerates)."""
        topo = full_mesh(n)
        rng = np.random.default_rng(seed)
        dests = rng.choice([i for i in range(n) if i != 0],
                           size=min(3, n - 1), replace=False)
        sim = MultiWriteSimulator(topo)
        data = np.arange(64, dtype=np.uint8)
        sim.multiwrite(0, {int(d): "x" for d in dests}, data)
        assert not sim.relay_bytes        # no relaying needed
        assert sum(sim.link_bytes.values()) == 64 * len(dests)


class TestFabricForwardingProperties:
    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs(), seed=st.integers(0, 999))
    def test_path_never_loops(self, spec, seed):
        """path() terminates within num_nodes hops for every node pair on
        every generated fabric (no forwarding loops)."""
        topo = spec.build()
        rng = np.random.default_rng(seed)
        nodes = rng.choice(topo.num_nodes, size=min(6, topo.num_nodes),
                           replace=False)
        for src in nodes:
            for dst in nodes:
                if src == dst:
                    continue
                p = topo.path(int(src), int(dst),
                              max_hops=topo.num_nodes)
                assert p[0] == src and p[-1] == dst
                assert len(set(p)) == len(p)               # no revisits

    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs(), src=st.integers(0, 23))
    def test_rail_first_grouping(self, spec, src):
        """For every (server count, rail count): a remote server's whole
        destination set groups under that server's rail peers of the
        source — at most ``rails`` copies cross per MultiWrite."""
        if spec.num_servers < 2:
            return
        topo = spec.build()
        src = src % topo.num_nodes
        for sv in range(spec.num_servers):
            if sv == topo.server_of(src):
                continue
            groups = topo.partition_by_next_hop(src, topo.server_nodes(sv))
            assert set(groups) <= set(topo.rail_peers(src, sv))
            assert 1 <= len(groups) <= spec.rails_per_npu
            # every destination lands in exactly one group
            got = sorted(d for g in groups.values() for d in g)
            assert got == topo.server_nodes(sv)

    @settings(max_examples=25, deadline=None)
    @given(spec=cluster_specs(), seed=st.integers(0, 999))
    def test_combine_mirrors_dispatch_on_symmetric_fabrics(self, spec, seed):
        """Multiwrite combine == link-reverse of multiwrite dispatch:
        exact per-link mirror on single-rail fabrics, equal total rail
        crossings on multi-rail ones."""
        topo = spec.build()
        n = topo.num_nodes
        experts = max(1, 32 // n) * n
        routing = sch.make_routing(4, n, experts, min(4, experts),
                                   seed=seed)
        disp, comb = MultiWriteSimulator(topo), MultiWriteSimulator(topo)
        sch.dispatch_multiwrite(disp, routing, 128)
        sch.combine_multiwrite(comb, routing, 128)
        sch.check_combine(comb, routing, 128)
        if spec.rails_per_npu <= 1:
            assert dict(comb.link_bytes) == \
                {(b, a): v for (a, b), v in disp.link_bytes.items()}
        else:
            def rail_total(sim):
                return sum(v for (a, b), v in sim.link_bytes.items()
                           if topo.server_of(a) != topo.server_of(b))
            assert rail_total(comb) == rail_total(disp)


class TestLatencyModelProperties:
    @settings(max_examples=50, deadline=None)
    @given(s1=st.integers(2**20, 2**27), s2=st.integers(2**20, 2**27))
    def test_monotone_in_size(self, s1, s2):
        if s1 > s2:
            s1, s2 = s2, s1
        for scheme in lm.ALLGATHER_LINK_LOAD:
            assert lm.allgather_latency(scheme, s1) <= \
                lm.allgather_latency(scheme, s2)

    @settings(max_examples=30, deadline=None)
    @given(s=st.integers(8 * 2**20, 2**28))
    def test_scheme_ordering_at_large_sizes(self, s):
        """Above the crossover: mw_paired < unicast_paired < baseline."""
        b = lm.allgather_latency("baseline", s)
        u = lm.allgather_latency("unicast_paired", s)
        m = lm.allgather_latency("multiwrite_paired", s)
        assert m < u < b

    @settings(max_examples=30, deadline=None)
    @given(batch=st.integers(32, 4096))
    def test_dispatch_redundant_always_slower_at_scale(self, batch):
        assert lm.dispatch_cross_server_time(batch, True) > \
            lm.dispatch_cross_server_time(batch, False)


class TestReduceLedgerProperties:
    """Closed-form invariants of the gradient-sync (reduce) ledgers on
    arbitrary generated fabrics: byte conservation, ring step counts,
    log-depth trees, and the hierarchical/multiwrite schedules never
    store-and-forwarding (every hop they charge is a direct link on
    ClusterSpec fabrics)."""

    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs(), nbytes=st.integers(1024, 2 ** 24),
           phases=st.sampled_from([1, 2]))
    def test_ring_bytes_and_step_count(self, spec, nbytes, phases):
        topo = spec.build()
        R = topo.num_nodes
        led = sch.reduce_ring_ledger(topo, float(nbytes), phases=phases)
        per_edge = phases * nbytes * (R - 1) / R
        total = sum(led.link_bytes.values())
        # R ring hops, each charging per_edge on every link of its path:
        # equality iff no hop store-and-forwards (odd server counts may
        # forward the closing edge)
        assert total >= R * per_edge - 1e-6
        if not led.relayed:
            assert total == pytest.approx(R * per_edge)
        # phases*(R-1) rounds; one is covered by alpha_base
        assert led.alpha_extra_s == pytest.approx(
            (phases * (R - 1) - 1) * sch.REDUCE_STEP_ALPHA_S)

    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs())
    def test_tree_depth_is_ceil_log2(self, spec):
        import math
        topo = spec.build()
        S, P = spec.num_servers, spec.npus_per_server
        want = ((math.ceil(math.log2(P)) if P > 1 else 0)
                + (math.ceil(math.log2(S)) if S > 1 else 0))
        assert sch.reduce_tree_depth(topo) == want
        led = sch.reduce_tree_ledger(topo, 4096.0)
        assert led.alpha_extra_s == pytest.approx(
            max(0, want - 1) * sch.REDUCE_STEP_ALPHA_S)

    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs(), nbytes=st.integers(1024, 2 ** 24))
    def test_hierarchical_conserves_bytes(self, spec, nbytes):
        topo = spec.build()
        S, P = spec.num_servers, spec.npus_per_server
        led = sch.reduce_hierarchical_ledger(topo, float(nbytes), phases=2)
        shard = nbytes / P if P > 1 else nbytes
        want = 0.0
        if P > 1:
            want += S * P * 2.0 * nbytes * (P - 1) / P
        if S > 1:
            want += P * S * 2.0 * shard * (S - 1) / S
        if spec.rails_per_npu <= 1:
            # intra rings run on full-mesh links, the inter ring on
            # same-index rail links: no hop ever forwards
            assert not led.relayed and not led.relay_bytes
            assert sum(led.link_bytes.values()) == pytest.approx(want)
        else:
            # multi-rail striping (dst index jd routes via rail jd % r)
            # can add an intra forwarding hop per rail transfer — the
            # ledger charges it, so bytes only grow
            assert sum(led.link_bytes.values()) >= want - 1e-6

    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs(), nbytes=st.integers(1024, 2 ** 24))
    def test_multiwrite_conserves_bytes_and_relay_work(self, spec, nbytes):
        topo = spec.build()
        S, P = spec.num_servers, spec.npus_per_server
        led = sch.reduce_multiwrite_ledger(topo, float(nbytes))
        slice_b = nbytes / P
        inter = (S - 1) if S > 1 else 0
        # per relay: (P-1) funnel-in + inter rail copies + (P-1) replicate
        want_wire = S * P * (2 * (P - 1) + inter) * slice_b
        # relay rx processing: local partials + remote pre-reduced copies
        want_relay = S * P * ((P - 1) + inter) * slice_b
        if spec.rails_per_npu <= 1:
            assert sum(led.link_bytes.values()) == pytest.approx(want_wire)
            assert sum(led.relay_bytes.values()) == pytest.approx(want_relay)
            # bottleneck rail link carries exactly ONE slice per
            # (server pair, index)
            for (a, b), v in led.link_bytes.items():
                if topo.server_of(a) != topo.server_of(b):
                    assert v == pytest.approx(slice_b)
        else:
            # striped forwarding adds hops: charges only grow
            assert sum(led.link_bytes.values()) >= want_wire - 1e-6
            assert sum(led.relay_bytes.values()) >= want_relay - 1e-6

    @settings(max_examples=30, deadline=None)
    @given(spec=cluster_specs(), nbytes=st.integers(1024, 2 ** 22))
    def test_scatter_conserves_bytes_on_full_mesh(self, spec, nbytes):
        n = spec.npus_per_server
        topo = full_mesh(n)
        led = sch.reduce_scatter_a2a_ledger(topo, float(nbytes))
        # every ordered pair moves N/R once, all single-hop
        assert not led.relayed
        assert sum(led.link_bytes.values()) == pytest.approx(
            (n - 1) * nbytes)


class TestCheckpointProperties:
    @settings(max_examples=20, deadline=None)
    @given(shapes=st.lists(
        st.tuples(st.integers(1, 5), st.integers(1, 5)),
        min_size=1, max_size=4),
        seed=st.integers(0, 2**31))
    def test_roundtrip_identity(self, tmp_path_factory, shapes, seed):
        import jax.numpy as jnp
        from repro.checkpoint.store import CheckpointManager
        d = tmp_path_factory.mktemp("ck")
        rng = np.random.default_rng(seed)
        tree = {f"k{i}": jnp.asarray(rng.normal(size=s).astype(np.float32))
                for i, s in enumerate(shapes)}
        cm = CheckpointManager(str(d))
        cm.save(1, tree)
        back, _ = cm.restore(1, tree)
        for a, b in zip(tree.values(), back.values()):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDataProperties:
    @settings(max_examples=20, deadline=None)
    @given(hosts=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 100))
    def test_host_split_invariance(self, hosts, step):
        from repro.data.pipeline import DataConfig, SyntheticLM
        d = SyntheticLM(DataConfig(vocab=64, seq_len=8, global_batch=8))
        full = d.batch(step, 0, 1)["tokens"]
        parts = np.concatenate([d.batch(step, h, hosts)["tokens"]
                                for h in range(hosts)])
        np.testing.assert_array_equal(parts, full)


class TestContentionProperties:
    """Phase-level contention model + joint search (ISSUE 7)."""

    @settings(max_examples=25, deadline=None)
    @given(fabric=st.sampled_from(["mesh8", "2x8", "2x8r2", "2x8asym",
                                   "4x8", "tpu_2x16"]),
           picks=st.lists(
               st.tuples(st.sampled_from([("dispatch", "multiwrite"),
                                          ("dispatch", "unicast"),
                                          ("combine", "multiwrite"),
                                          ("allreduce", "ring"),
                                          ("allreduce", "tree"),
                                          ("allreduce", "hierarchical"),
                                          ("allreduce", "multiwrite")]),
                         st.integers(2**12, 2**24)),
               min_size=2, max_size=4))
    def test_merged_phase_ledger_is_per_link_sum(self, fabric, picks):
        """The phase ledger is EXACTLY the per-link sum of its site
        ledgers, for any mix of real plan ledgers on any fabric —
        per-fabric merging is bookkeeping, not modeling."""
        from repro.core import plan as plan_ir
        from repro.core import planner  # noqa: F401  (fills the registry)
        from repro.core.topology import get_fabric
        topo = get_fabric(fabric)
        scen = plan_ir.default_scenarios(topo)
        ledgers = [plan_ir.get_plan(op, name).simulate(scen[op], float(n))
                   for (op, name), n in picks]
        merged = lm.merge_ledgers(ledgers)
        assert len(merged) == 1     # one fabric in play -> one ledger
        m = merged[0]
        assert m.stages == 1 and not m.overlap and m.compute_s == 0.0
        for field in ("link_bytes", "relay_bytes", "flow_counts"):
            want = {}
            for led in ledgers:
                for k, v in getattr(led, field).items():
                    want[k] = want.get(k, 0) + v
            got = getattr(m, field)
            assert set(got) == set(want)
            for k in want:
                assert got[k] == pytest.approx(want[k])
        # and the phase floor can never undercut any single site's floor
        assert lm.ledger_wire_s(m) >= max(
            lm.ledger_wire_s(l) for l in ledgers) - 1e-12

    @settings(max_examples=8, deadline=None)
    @given(fabric=st.sampled_from(["mesh8", "2x8"]),
           batch=st.sampled_from([64, 256, 1024, 4096]),
           n_params=st.sampled_from([10**7, 10**8, 10**9]))
    def test_beam_never_worse_than_greedy_and_matches_oracle(
            self, fabric, batch, n_params):
        """Joint beam search (a) never loses to independent per-site
        planning re-scored under the phase model and (b) matches the
        exhaustive oracle on the mesh8/2x8 training programs."""
        from repro.core import plan as plan_ir
        from repro.core import planner as pl
        from repro.core.topology import get_fabric
        topo = get_fabric(fabric)
        d, c = plan_ir.moe_sites(
            "train", num_experts=64, top_k=8, tokens_per_rank=batch,
            token_bytes=lm.TOKEN_BYTES,
            compute_s=lm.expert_compute_time_s(batch, 8, 7168, 2048))
        gs = plan_ir.grad_sync_site(
            "train", payload_bytes=n_params * 4 / 8,
            compute_s=lm.backward_compute_s(n_params, 2048, tp=8))
        program = plan_ir.CollectiveProgram("train", (d, c, gs))
        beam = pl.Planner(search="beam").plan_program(program, topo)
        beam_s = beam.phase_report["train"]["score_s"]
        planner = pl.Planner()
        groups = program.phases()["train"]
        bundles = [planner._group_candidates(g, topo, planner.hw, True)
                   for g in groups]
        greedy_s = lm.score_phase(
            [(b["cands"][0]["score_s"], b["cands"][0]["ledgers"])
             for b in bundles], planner.hw)
        assert beam_s <= greedy_s + 1e-12
        oracle = pl.Planner(search="exhaustive").plan_program(program,
                                                              topo)
        oracle_s = oracle.phase_report["train"]["score_s"]
        assert oracle_s <= beam_s + 1e-12
        assert beam_s == pytest.approx(oracle_s, rel=1e-9)


class TestFailoverProperties:
    @settings(max_examples=40, deadline=None)
    @given(spec=cluster_specs(), nbytes=st.integers(1024, 2 ** 24),
           seed=st.integers(0, 999), frac=st.floats(0.0, 0.9))
    def test_no_surviving_candidate_charges_a_dead_link(
            self, spec, nbytes, seed, frac):
        """On ANY fabric x ANY dead-rail subset, the planner either
        raises the typed NoFeasiblePlanError or every surviving
        candidate's ledger avoids the dead links entirely — feasibility
        masking admits no middle ground."""
        import random

        from repro.core import planner as pl
        from repro.core.topology import FailureState

        topo = spec.build()
        rails = sorted(k for k in topo.links
                       if topo.server_of(k[0]) != topo.server_of(k[1]))
        rng = random.Random(seed)
        dead = set(rng.sample(rails, int(len(rails) * frac)))
        failures = FailureState(dead_links=dead)
        failed = topo.with_failures(failures) if dead else topo
        planner = pl.Planner()
        for op in ("dispatch", "allreduce", "reduce_scatter"):
            scenario = pl.Planner._scenario(op, failed, {})
            try:
                rows = planner._site_rows(op, scenario, nbytes,
                                          planner.hw, True)
            except pl.NoFeasiblePlanError as e:
                assert e.op == op
                assert e.masked    # the typed error names its evidence
                continue
            for row in rows:
                ledger = row[4]
                assert pl.ledger_infeasible(ledger, failures) is None
