"""Tests for the collective-plan IR + latency-model-driven planner.

Covers the ISSUE-1 acceptance properties:
  * the Fig 7 crossover is EMERGENT: Planner.choose flips from baseline
    to multiwrite near ~2 MB under the calibrated DEFAULT HardwareModel;
  * the LRU plan cache hits on repeated (op, topo, payload bucket) keys;
  * registry round-trip: every registered plan's simulated ledger matches
    the MultiWriteSimulator correctness properties that
    tests/test_multiwrite_core.py pins for the raw schedules.
"""

import math

import numpy as np
import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core import schedules as sch
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import split_tp_full_mesh, two_server_cluster

TOPO_AG, DOMAINS = split_tp_full_mesh(8, tp=4)


# ---------------------------------------------------------------------------
# crossover (acceptance criterion)
# ---------------------------------------------------------------------------

class TestCrossover:
    def test_baseline_below_multiwrite_above_2mb(self):
        """Planner.choose selects baseline below and multiwrite above a
        crossover within 1-4 MB under DEFAULT calibration (Fig 7)."""
        planner = pl.Planner()
        below = planner.choose("allgather", 256 * 2 ** 10, TOPO_AG)
        above = planner.choose("allgather", 8 * 2 ** 20, TOPO_AG)
        assert below.plan == "baseline"
        assert above.plan.startswith("multiwrite")
        xover = pl.emergent_crossover_bytes(TOPO_AG, planner=planner)
        assert 1 * 2 ** 20 <= xover <= 4 * 2 ** 20

    def test_crossover_tracks_closed_form(self):
        """The emergent crossover agrees with the closed-form §5.2 value
        within one payload bucket."""
        xover = pl.emergent_crossover_bytes(TOPO_AG)
        closed = lm.allgather_crossover_bytes()
        assert xover / 2 <= closed <= xover * 2

    def test_ideal_regime_always_multiwrite(self):
        """Zero overheads -> multiwrite wins at every size (§3.1 exact)."""
        planner = pl.Planner(hw=lm.IDEAL)
        for frag in (64 * 2 ** 10, 2 ** 20, 16 * 2 ** 20):
            d = planner.choose("allgather", frag, TOPO_AG)
            assert d.plan.startswith("multiwrite"), (frag, d.plan)

    def test_chosen_split_near_analytic_seed(self):
        d = pl.Planner().choose("allgather", 16 * 2 ** 20, TOPO_AG)
        seed = sch.optimal_split(d.plan)
        assert abs(d.knob("split") - seed) <= 0.25

    def test_decision_exposes_shard_map_kwargs(self):
        planner = pl.Planner()
        d = planner.choose("allgather", 16 * 2 ** 20, TOPO_AG,
                           executable_only=True)
        assert d.shard_map_kwargs["mode"] in ("paired", "full")
        assert 0 < d.shard_map_kwargs["split"] < 1
        d0 = planner.choose("allgather", 64 * 2 ** 10, TOPO_AG,
                            executable_only=True)
        assert d0.shard_map_kwargs["mode"] is None

    def test_dispatch_decision_fig8_shape(self):
        """Small decode batches stay unicast, large prefill batches flip
        to multiwrite (Fig 8 as planner behaviour)."""
        planner = pl.Planner()
        topo = two_server_cluster()
        small = planner.choose("dispatch", 8 * lm.TOKEN_BYTES, topo,
                               token_bytes=lm.TOKEN_BYTES)
        large = planner.choose("dispatch", 2048 * lm.TOKEN_BYTES, topo,
                               token_bytes=lm.TOKEN_BYTES)
        assert small.plan == "unicast"
        assert large.plan == "multiwrite"
        assert large.delta_vs_baseline > 0

    def test_dispatch_tracks_calibrated_fig8_model(self):
        """The planner's ledger scores agree with the repo's closed-form
        dispatch_e2e_time (validated against paper Table 1 / Fig 8) on
        winner AND magnitude across the Fig 8 batches: mw loses at decode
        batch 64, wins from prefill batches on."""
        planner = pl.Planner()
        topo = two_server_cluster()
        for batch, want in ((64, "unicast"), (1024, "multiwrite"),
                            (2048, "multiwrite")):
            d = planner.choose("dispatch", batch * lm.TOKEN_BYTES, topo,
                               token_bytes=lm.TOKEN_BYTES)
            assert d.plan == want, (batch, d.plan)
            # the closed form is unchunked: compare the G == 1 candidate
            # of each plan (the grid also carries pipelined G > 1 cells)
            cand = {n: t for n, kn, t in d.candidates
                    if dict(kn).get("microbatch", 1) == 1}
            for scheme, key in (("multiwrite", "multiwrite"),
                                ("unicast", "unicast")):
                closed = lm.dispatch_e2e_time(batch, scheme)
                assert cand[key] == pytest.approx(closed, rel=0.25), \
                    (batch, scheme, cand[key], closed)


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

class TestPlanCache:
    def test_cache_hit_on_same_bucket(self):
        planner = pl.Planner()
        d1 = planner.choose("allgather", 3 * 2 ** 20, TOPO_AG)
        misses = planner.cache_info()["misses"]
        # same power-of-two bucket (4 MB) -> hit, identical decision object
        d2 = planner.choose("allgather", 3.5 * 2 ** 20, TOPO_AG)
        assert d2 is d1
        assert planner.cache_info()["hits"] == 1
        assert planner.cache_info()["misses"] == misses

    def test_cache_keyed_on_topology_and_hw(self):
        planner = pl.Planner()
        planner.choose("allgather", 2 ** 20, TOPO_AG)
        slow, _ = split_tp_full_mesh(8, tp=4, link_bw=1e9)
        planner.choose("allgather", 2 ** 20, slow)       # different topo
        planner.choose("allgather", 2 ** 20, TOPO_AG, hw=lm.IDEAL)
        assert planner.cache_info()["misses"] == 3
        assert planner.cache_info()["hits"] == 0

    def test_cache_eviction_lru(self):
        planner = pl.Planner(cache_size=2)
        for frag in (2 ** 18, 2 ** 20, 2 ** 22):
            planner.choose("allgather", frag, TOPO_AG)
        assert planner.cache_info()["size"] == 2
        planner.choose("allgather", 2 ** 18, TOPO_AG)    # evicted -> miss
        assert planner.cache_info()["misses"] == 4

    def test_default_planner_is_process_wide(self):
        assert pl.default_planner() is pl.default_planner()


# ---------------------------------------------------------------------------
# registry round-trip: plan ledgers == simulator correctness properties
# ---------------------------------------------------------------------------

class TestRegistryRoundTrip:
    def test_all_paper_schemes_registered(self):
        names = {p.name for p in plan_ir.plans_for("allgather")}
        assert names >= {"baseline", "unicast_paired", "multiwrite_paired",
                         "unicast_full", "multiwrite_full"}
        assert {p.name for p in plan_ir.plans_for("dispatch")} >= \
            {"unicast", "multiwrite"}

    @pytest.mark.parametrize("scheme", list(lm.ALLGATHER_LINK_LOAD))
    def test_plan_ledger_matches_closed_form(self, scheme):
        """Each registered allgather plan's simulated+scaled ledger scores
        exactly like the §3.1 closed forms in the ideal regime — the same
        property test_paper_claims pins for the raw schedule drivers."""
        frag = 1 << 20
        p = plan_ir.get_plan("allgather", scheme)
        scn = plan_ir.AllGatherScenario.split_tp(TOPO_AG)
        ledger = p.simulate(scn, frag, split=sch.optimal_split(scheme))
        t = lm.score_ledger(ledger, lm.IDEAL)
        ref = lm.allgather_latency(scheme, frag, hw=lm.IDEAL)
        assert t == pytest.approx(ref, rel=0.02)

    @pytest.mark.parametrize("scheme", ["baseline", "unicast_paired",
                                        "multiwrite_paired", "unicast_full",
                                        "multiwrite_full"])
    def test_plan_driver_keeps_simulator_semantics(self, scheme):
        """Driving the registered plan's schedule delivers every fragment
        bit-exact (the test_multiwrite_core delivery properties)."""
        frag = 1 << 10
        sim = MultiWriteSimulator(TOPO_AG)
        rng = np.random.default_rng(7)
        payloads = [rng.integers(0, 256, frag, dtype=np.uint8)
                    for _ in range(8)]
        sch.run_allgather_scheme(scheme, sim, DOMAINS, payloads)
        sch.check_allgather(sim, DOMAINS, payloads)
        # multiwrite schemes put zero redundant bytes on cross links
        if scheme.startswith("multiwrite"):
            red = sim.redundant_bytes()
            for (a, b), v in red.items():
                if sch.domain_of(a, DOMAINS) != sch.domain_of(b, DOMAINS):
                    assert v == 0

    def test_dispatch_plan_ledgers_preserve_rail_property(self):
        """multiwrite dispatch plan: one rail crossing per (token, remote
        server); unicast plan: k_remote redundant crossings — the §3.2
        single-copy property, via the registry path."""
        topo = two_server_cluster()
        scn = plan_ir.DispatchScenario(topo=topo, token_bytes=1024)
        batch_bytes = 32 * 1024
        uni = plan_ir.get_plan("dispatch", "unicast").simulate(
            scn, batch_bytes)
        mw = plan_ir.get_plan("dispatch", "multiwrite").simulate(
            scn, batch_bytes)

        def rail(ledger):
            return max(v for (a, b), v in ledger.link_bytes.items()
                       if a // 8 != b // 8)

        assert rail(mw) < rail(uni)
        assert 2.5 <= rail(uni) / rail(mw) <= 4.5   # ~k_remote dedup ratio

    def test_ledger_scaling_is_linear(self):
        p = plan_ir.get_plan("allgather", "multiwrite_paired")
        scn = plan_ir.AllGatherScenario.split_tp(TOPO_AG)
        small = p.simulate(scn, 2 ** 16, split=0.5)
        big = p.simulate(scn, 2 ** 22, split=0.5)
        for k, v in small.link_bytes.items():
            assert big.link_bytes[k] == pytest.approx(v * 64, rel=1e-6)

    def test_unknown_plan_raises_with_inventory(self):
        with pytest.raises(KeyError, match="multiwrite_paired"):
            plan_ir.get_plan("allgather", "nope")

    def test_knob_grids_seeded_on_optimal_split(self):
        for name in ("unicast_paired", "multiwrite_paired", "unicast_full",
                     "multiwrite_full"):
            grid = plan_ir.get_plan("allgather", name).knobs["split"]
            assert grid[0] == sch.optimal_split(name)   # seed listed first
            assert all(0 < v < 1 for v in grid)


# ---------------------------------------------------------------------------
# context-level consumption
# ---------------------------------------------------------------------------

class TestContextIntegration:
    def test_moe_dispatch_decision_helper(self):
        d = pl.moe_dispatch_decision(
            num_pods=2, ep_per_pod=8, num_experts=64, top_k=8,
            tokens_per_rank=2048, token_bytes=7168)
        assert d.op == "dispatch"
        assert d.shard_map_kwargs["moe_scheme"] in ("hierarchical",
                                                    "baseline")
        assert d.plan == "multiwrite"    # large batch on a slow DCN axis

    def test_fixed_policy_returns_none(self):
        """Without a mesh we can't build a ParallelContext; exercise the
        policy gate through a minimal stand-in."""
        import jax

        from repro.launch.mesh import make_test_mesh
        from repro.parallel.context import ParallelContext
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = make_test_mesh(shape=(1,), axes=("model",))
        pctx = ParallelContext(mesh=mesh, pod_axis=None, data_axis="model",
                               model_axis="model")
        assert pctx.plan_policy == "fixed"
        kw = pctx.moe_pipeline_kwargs(64, 8, 1024, 7168)
        assert kw["moe_scheme"] == "hierarchical"
        assert kw["microbatch"] == 1

    def test_auto_policy_resolves_scheme(self):
        import dataclasses

        import jax

        from repro.launch.mesh import make_test_mesh
        from repro.parallel.context import ParallelContext
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = make_test_mesh(shape=(1,), axes=("model",))
        pctx = ParallelContext(mesh=mesh, pod_axis=None, data_axis="model",
                               model_axis="model")
        auto = dataclasses.replace(pctx, plan_policy="auto")
        kw = auto.moe_pipeline_kwargs(64, 8, 4096, 7168)
        # single-pod mesh has no slow axis: planned on the all-ICI full
        # mesh where MultiWrite cannot beat unicast -> relay-free plan
        assert kw["moe_scheme"] == "baseline"
        assert kw["moe_combine"] == "baseline"
