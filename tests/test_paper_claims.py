"""Validation of the paper's quantitative claims (§3.1, §6.2–§6.4).

Three tiers:
  1. EXACT — the §3.1 derivations, reproduced by both the closed-form
     latency model in the ideal regime AND the simulator ledger.
  2. CALIBRATED — Fig 6/7 endpoints and Table 1, reproduced by the
     calibrated model within stated tolerances.
  3. QUALITATIVE — Fig 8 shape (mw worse at batch 64, parity ~128,
     growing gains at 1k/2k).
"""

import numpy as np
import pytest

from repro.core import latency_model as lm
from repro.core import schedules as sch
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import (
    HCCS_LINK_BW, split_tp_full_mesh, two_server_cluster)

S16 = 16 * 2**20  # Fig 6 per-rank message


def run_allgather(scheme: str, frag_bytes: int = 1 << 16):
    topo, domains = split_tp_full_mesh(8, tp=4)
    sim = MultiWriteSimulator(topo)
    rng = np.random.default_rng(42)
    payloads = [rng.integers(0, 256, frag_bytes, dtype=np.uint8)
                for _ in range(8)]
    sch.ALLGATHER_SCHEMES[scheme](sim, domains, payloads)
    sch.check_allgather(sim, domains, payloads)
    return sim


# ---------------------------------------------------------------------------
# Tier 1: exact §3.1 derivations
# ---------------------------------------------------------------------------

class TestSection31Exact:
    """Paper §3.1: baseline s/w; unicast paired 3s/(4w); multiwrite paired
    s/(2w) -> 50% vs baseline, 33% vs unicast; full-multipath multicast
    >= 16% vs full-multipath unicast."""

    def test_closed_form_ideal_regime(self):
        s, w = float(S16), HCCS_LINK_BW
        t = {k: lm.allgather_latency(k, s, w, lm.IDEAL)
             for k in lm.ALLGATHER_LINK_LOAD}
        assert t["baseline"] == pytest.approx(s / w)
        assert t["unicast_paired"] == pytest.approx(3 * s / (4 * w))
        assert t["multiwrite_paired"] == pytest.approx(s / (2 * w))
        assert t["unicast_full"] == pytest.approx(3 * s / (5 * w))
        assert t["multiwrite_full"] == pytest.approx(s / (2 * w))
        # headline reductions
        assert 1 - t["multiwrite_paired"] / t["baseline"] == pytest.approx(0.50)
        assert 1 - t["multiwrite_paired"] / t["unicast_paired"] == \
            pytest.approx(1 / 3)
        assert 1 - t["multiwrite_full"] / t["unicast_full"] == \
            pytest.approx(1 / 6)  # "at least 16%"
        assert 1 - t["multiwrite_full"] / t["unicast_full"] >= 0.16

    @pytest.mark.parametrize("scheme", list(lm.ALLGATHER_LINK_LOAD))
    def test_simulator_ledger_matches_closed_form(self, scheme):
        """The executable schedule's bottleneck-link bytes == the closed-form
        link-load fraction (the §3.1 math, via actual packet accounting)."""
        frag = 1 << 16
        sim = run_allgather(scheme, frag)
        t_ledger = lm.ledger_latency(sim, lm.IDEAL)
        t_model = lm.allgather_latency(scheme, frag, HCCS_LINK_BW, lm.IDEAL)
        # array_split rounding on the full-multipath slices -> 2% tolerance
        assert t_ledger == pytest.approx(t_model, rel=0.02)

    @pytest.mark.parametrize("scheme", list(lm.ALLGATHER_LINK_LOAD))
    def test_relay_bytes_ledger_matches_model(self, scheme):
        frag = 1 << 16
        sim = run_allgather(scheme, frag)
        _, relay_frac, _ = lm.ALLGATHER_LINK_LOAD[scheme]
        if relay_frac == 0:
            assert not sim.relay_bytes
        else:
            got = max(sim.relay_bytes.values()) / frag
            assert got == pytest.approx(relay_frac, rel=0.02)

    def test_multiwrite_eliminates_cross_link_redundancy(self):
        sim_u = run_allgather("unicast_paired")
        sim_m = run_allgather("multiwrite_paired")
        topo, domains = split_tp_full_mesh(8, tp=4)

        def cross(a, b):
            return sch.domain_of(a, domains) != sch.domain_of(b, domains)

        red_u = sum(v for (a, b), v in sim_u.redundant_bytes().items()
                    if cross(a, b))
        red_m = sum(v for (a, b), v in sim_m.redundant_bytes().items()
                    if cross(a, b))
        assert red_u > 0
        assert red_m == 0


# ---------------------------------------------------------------------------
# Tier 2: calibrated Fig 6 / Fig 7 / Table 1
# ---------------------------------------------------------------------------

class TestFig6Fig7Calibrated:
    def test_fig6_30pct_reduction_at_16mb(self):
        t_base = lm.allgather_latency("baseline", S16)
        t_mw = lm.allgather_latency("multiwrite_paired", S16)
        reduction = 1 - t_mw / t_base
        assert reduction == pytest.approx(0.30, abs=0.03)  # paper: ~30%

    def test_fig6_mw_beats_unicast_multipath(self):
        t_uni = lm.allgather_latency("unicast_paired", S16)
        t_mw = lm.allgather_latency("multiwrite_paired", S16)
        reduction = 1 - t_mw / t_uni
        # paper: 17%; model (mean, no interference derate): same ordering,
        # 15-30% band
        assert 0.15 <= reduction <= 0.30

    def test_fig7_crossover_near_2mb(self):
        s_star = lm.allgather_crossover_bytes()
        assert 1.0 * 2**20 <= s_star <= 3.0 * 2**20  # paper: "around 2 MB"

    def test_fig7_small_messages_favor_baseline(self):
        s = 256 * 2**10
        assert lm.allgather_latency("multiwrite_paired", s) > \
            lm.allgather_latency("baseline", s)

    def test_fig7_large_messages_favor_multiwrite(self):
        for s in (8 * 2**20, 64 * 2**20, 200 * 2**20):
            assert lm.allgather_latency("multiwrite_paired", s) < \
                lm.allgather_latency("baseline", s)

    def test_fig7_monotone_in_message_size(self):
        ts = [lm.allgather_latency("multiwrite_paired", s)
              for s in lm.FIG7_MESSAGE_BYTES]
        assert ts == sorted(ts)


class TestTable1Calibrated:
    @pytest.mark.parametrize("batch", sorted(lm.TABLE1_PAPER_US))
    def test_with_redundant_within_12pct(self, batch):
        paper_us = lm.TABLE1_PAPER_US[batch][0]
        model_us = lm.dispatch_cross_server_time(batch, redundant=True) * 1e6
        assert model_us == pytest.approx(paper_us, rel=0.12)

    @pytest.mark.parametrize("batch", sorted(lm.TABLE1_PAPER_US))
    def test_without_redundant_within_8pct(self, batch):
        paper_us = lm.TABLE1_PAPER_US[batch][1]
        model_us = lm.dispatch_cross_server_time(batch, redundant=False) * 1e6
        assert model_us == pytest.approx(paper_us, rel=0.08)

    def test_delta_grows_with_batch(self):
        deltas = [lm.dispatch_cross_server_time(b, True)
                  - lm.dispatch_cross_server_time(b, False)
                  for b in sorted(lm.TABLE1_PAPER_US)]
        assert deltas == sorted(deltas)


# ---------------------------------------------------------------------------
# Tier 2b: simulator ledger reproduces Table 1 byte counts
# ---------------------------------------------------------------------------

class TestDispatchLedger:
    def _run(self, batch, scheme, seed=0):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        routing = sch.make_routing(batch, 16, 64, 8, seed)
        fn = sch.dispatch_unicast if scheme == "unicast" else sch.dispatch_multiwrite
        fn(sim, routing, lm.TOKEN_BYTES)
        sch.check_dispatch(sim, routing, lm.TOKEN_BYTES)
        return sim, routing

    def rail_bytes(self, sim):
        def is_rail(a, b):
            return a // 8 != b // 8
        return max(v for (a, b), v in sim.link_bytes.items() if is_rail(a, b))

    def test_multiwrite_rail_bytes_one_copy_per_server(self, batch=64):
        sim, routing = self._run(batch, "multiwrite")
        # every token crosses its source rail at most once
        expect = lm.TOKEN_BYTES * batch  # upper bound: all tokens cross
        assert self.rail_bytes(sim) <= expect
        # and redundancy on every rail is zero
        red = sim.redundant_bytes()
        for (a, b), v in red.items():
            if a // 8 != b // 8:
                assert v == 0

    def test_unicast_rail_redundancy_ratio(self, batch=128):
        """Table 1 ratio: ~4 crossings/token unicast vs ~1 multiwrite."""
        sim_u, _ = self._run(batch, "unicast", seed=3)
        sim_m, _ = self._run(batch, "multiwrite", seed=3)
        ratio = self.rail_bytes(sim_u) / self.rail_bytes(sim_m)
        # expected remote NPUs/token ~3.375 unicast (per-NPU dedup in the
        # routing -> one write per distinct NPU), ~1 crossing multiwrite
        assert 2.5 <= ratio <= 4.5

    def test_ledger_latency_ordering_large_batch(self):
        sim_u, _ = self._run(1024, "unicast", seed=1)
        sim_m, _ = self._run(1024, "multiwrite", seed=1)
        assert lm.ledger_latency(sim_m) < lm.ledger_latency(sim_u)


# ---------------------------------------------------------------------------
# Tier 3: Fig 8 qualitative shape
# ---------------------------------------------------------------------------

class TestFig8Qualitative:
    def test_decode_batch64_mw_worse(self):
        assert lm.dispatch_e2e_time(64, "multiwrite") > \
            lm.dispatch_e2e_time(64, "unicast")

    def test_parity_near_batch128(self):
        t_u = lm.dispatch_e2e_time(128, "unicast")
        t_m = lm.dispatch_e2e_time(128, "multiwrite")
        assert abs(t_m - t_u) / t_u < 0.15  # "nearly identical latency"

    def test_prefill_gains_grow_with_batch(self):
        red = []
        for b in (1024, 2048):
            t_u = lm.dispatch_e2e_time(b, "unicast")
            t_m = lm.dispatch_e2e_time(b, "multiwrite")
            red.append(1 - t_m / t_u)
        assert red[0] > 0.05          # paper: 12% at 1k
        assert red[1] > red[0]        # paper: 27% at 2k > 12% at 1k
