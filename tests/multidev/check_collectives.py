"""8-device equality checks for the shard_map MultiWrite collectives.

Run as a subprocess by tests/test_collectives.py (so the forced device
count never leaks into the main test process):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python tests/multidev/check_collectives.py

Prints one line per check; exits nonzero on any failure.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.parallel.compat import shard_map  # noqa: E402
from repro.core import collectives as cl  # noqa: E402


def check(name, ok):
    print(f"{'PASS' if ok else 'FAIL'} {name}")
    if not ok:
        raise SystemExit(1)


# ===========================================================================
# multiwrite_allgather == reference (paper §5.2 equivalence)
# ===========================================================================

def run_allgather_checks():
    mesh = jax.make_mesh((8,), ("x",))
    rng = np.random.default_rng(0)
    for rows, feat in ((16, 32), (8, 5), (64, 128)):
        x = jnp.asarray(rng.normal(size=(8 * rows, feat)).astype(np.float32))
        for mode, split in [("paired", 0.5), ("paired", 0.25),
                            ("paired", 0.75), ("full", 0.5), ("full", 0.375)]:
            ref_fn = jax.jit(shard_map(
                functools.partial(cl.allgather_reference, axis_name="x"),
                mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                check_vma=False))
            mw_fn = jax.jit(shard_map(
                functools.partial(cl.multiwrite_allgather, axis_name="x",
                                  split=split, mode=mode),
                mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                check_vma=False))
            ref = np.asarray(ref_fn(x))
            got = np.asarray(mw_fn(x))
            ok = np.array_equal(ref, got)
            check(f"allgather mode={mode} split={split} shape=({rows},{feat})",
                  ok)
    # planner-driven path: scheme + split come from Planner.choose at
    # trace time (no hard-coded mode=/split=), result must stay bit-exact.
    # DEFAULT hw + tiny fragment -> the baseline branch; IDEAL hw -> the
    # planner picks multiwrite at ANY size, exercising the mw branch too.
    from repro.core import latency_model as lm
    from repro.core.planner import default_planner
    from repro.core.topology import split_tp_full_mesh
    x = jnp.asarray(rng.normal(size=(8 * 16, 32)).astype(np.float32))
    ref_fn = jax.jit(shard_map(
        functools.partial(cl.allgather_reference, axis_name="x"),
        mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
    topo, _ = split_tp_full_mesh(8, tp=4)
    for hw, want_mw in ((None, False), (lm.IDEAL, True)):
        planned_fn = jax.jit(shard_map(
            functools.partial(cl.planned_allgather, axis_name="x", hw=hw),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
        ok = np.array_equal(np.asarray(ref_fn(x)), np.asarray(planned_fn(x)))
        d = default_planner().choose("allgather", x.nbytes // 8, topo, hw,
                                     executable_only=True)
        branch_ok = d.plan.startswith("multiwrite") == want_mw
        check(f"planned_allgather hw={'IDEAL' if hw else 'DEFAULT'} "
              f"(plan={d.plan}) == reference", ok and branch_ok)


# ===========================================================================
# MoE dispatch/combine == dense reference
# ===========================================================================

def moe_reference(tokens, ids, gates, num_experts):
    """Dense oracle: out[t] = sum_k gate * scale(e_k) * token."""
    scale = (np.arange(num_experts) + 1.0) * 0.01
    out = np.zeros_like(tokens, dtype=np.float64)
    for t in range(tokens.shape[0]):
        for kk in range(ids.shape[1]):
            out[t] += gates[t, kk] * scale[ids[t, kk]] * tokens[t]
    return out.astype(np.float32)


def run_dispatch_checks(scheme):
    pods, eps = 2, 4
    mesh = jax.make_mesh((pods, eps), ("pod", "ep"))
    num_experts, k, n_per_chip, h = 16, 4, 24, 8
    epmesh = cl.EPMesh(pod_axis="pod", ep_axis="ep", num_pods=pods,
                       ep_per_pod=eps)
    cfg = cl.DispatchConfig(num_experts=num_experts, top_k=k,
                            pod_capacity=1.0, ep_capacity=1.0,
                            expert_capacity=1.0)
    per_rank = num_experts // (pods * eps)
    n_total = n_per_chip * pods * eps
    rng = np.random.default_rng(7)
    tokens = rng.normal(size=(n_total, h)).astype(np.float32)
    logits = rng.normal(size=(n_total, num_experts)).astype(np.float32)
    gates_np, ids_np = jax.jit(
        functools.partial(cl.route_topk, k=k))(jnp.asarray(logits))
    gates_np, ids_np = np.asarray(gates_np), np.asarray(ids_np)
    ref = moe_reference(tokens, ids_np, gates_np, num_experts)

    def step(tok, ids, gates):
        scale = (jnp.arange(num_experts, dtype=jnp.float32) + 1.0) * 0.01
        my_pod = jax.lax.axis_index("pod")
        my_ep = jax.lax.axis_index("ep")
        my_rank = my_pod * eps + my_ep
        if scheme in ("hierarchical", "hierarchical_unicast_combine"):
            exp_tok, exp_gate, state = cl.hierarchical_dispatch(
                tok, ids, gates, cfg, epmesh)
            local_scale = scale[my_rank * per_rank
                                + jnp.arange(per_rank)][:, None, None]
            combine = (cl.hierarchical_combine_unicast
                       if scheme == "hierarchical_unicast_combine"
                       else cl.hierarchical_combine)
            out = combine(exp_tok * local_scale, exp_gate, state)
        else:
            exp_tok, exp_gate, state = cl.baseline_dispatch(
                tok, ids, gates, cfg, epmesh)
            local_scale = scale[my_rank * per_rank
                                + jnp.arange(per_rank)][:, None, None]
            out = cl.baseline_combine(exp_tok * local_scale, exp_gate, state)
        return out

    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(P(("pod", "ep")), P(("pod", "ep")), P(("pod", "ep"))),
        out_specs=P(("pod", "ep")), check_vma=False))
    got = np.asarray(fn(jnp.asarray(tokens), jnp.asarray(ids_np),
                        jnp.asarray(gates_np)))
    err = np.max(np.abs(got - ref))
    check(f"moe {scheme} dispatch+combine == dense reference (err={err:.2e})",
          err < 1e-4)


# ===========================================================================
# capacity-drop invariants
# ===========================================================================

def run_capacity_checks():
    """With a tight expert capacity, delivered outputs are a masked subset:
    dropped (token, expert) contributions vanish, everything else exact."""
    mesh = jax.make_mesh((2, 4), ("pod", "ep"))
    num_experts, k, n_per_chip, h = 16, 2, 16, 4
    epmesh = cl.EPMesh("pod", "ep", 2, 4)
    cfg = cl.DispatchConfig(num_experts, k, pod_capacity=1.0,
                            ep_capacity=1.0, expert_capacity=0.25)
    per_rank = 2
    rng = np.random.default_rng(3)
    n_total = n_per_chip * 8
    tokens = rng.normal(size=(n_total, h)).astype(np.float32)
    logits = rng.normal(size=(n_total, num_experts)).astype(np.float32)
    gates, ids = cl.route_topk(jnp.asarray(logits), k)

    def step(tok, ids_, gates_):
        my_rank = jax.lax.axis_index("pod") * 4 + jax.lax.axis_index("ep")
        exp_tok, exp_gate, state = cl.hierarchical_dispatch(
            tok, ids_, gates_, cfg, epmesh)
        return cl.hierarchical_combine(exp_tok, exp_gate, state)

    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(jax.sharding.PartitionSpec(("pod", "ep")),) * 3,
        out_specs=jax.sharding.PartitionSpec(("pod", "ep")),
        check_vma=False))
    got = np.asarray(fn(jnp.asarray(tokens), ids, gates))
    # identity experts -> out[t] = (sum of surviving gates) * token[t];
    # surviving-gate sum in [0, 1]:
    tok_norm = np.sum(tokens * tokens, axis=1)
    coef = np.sum(got * tokens, axis=1) / np.maximum(tok_norm, 1e-9)
    ok = np.all(coef < 1.0 + 1e-4) and np.all(coef > -1e-4)
    resid = got - coef[:, None] * tokens
    ok = ok and float(np.max(np.abs(resid))) < 1e-4
    check("moe capacity drop keeps outputs a gated subset", ok)


# ===========================================================================
# layers.split_tp_allgather (tp_subgroups path through the planner)
# ===========================================================================

def run_split_tp_layer_checks():
    import dataclasses

    from repro.models import layers as L
    from repro.parallel.context import ParallelContext

    mesh = jax.make_mesh((8,), ("x",))
    pctx = ParallelContext(mesh=mesh, pod_axis=None, data_axis="x",
                           model_axis="x", tp_subgroups=2)
    rng = np.random.default_rng(4)
    for rows, feat in ((16, 32), (8, 5)):
        x = jnp.asarray(rng.normal(size=(8 * rows, feat)).astype(np.float32))
        ref_fn = jax.jit(shard_map(
            functools.partial(cl.allgather_reference, axis_name="x",
                              num_domains=2),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_vma=False))
        ref = np.asarray(ref_fn(x))
        for policy in ("fixed", "auto"):
            p = dataclasses.replace(pctx, plan_policy=policy)
            fn = jax.jit(shard_map(
                functools.partial(L.split_tp_allgather, pctx=p),
                mesh=mesh, in_specs=P("x"), out_specs=P("x"),
                check_vma=False))
            got = np.asarray(fn(x))
            check(f"layers.split_tp_allgather policy={policy} "
                  f"shape=({rows},{feat}) == reference",
                  np.array_equal(ref, got))


# ===========================================================================
# pipelined moe_ffn (microbatch G > 1, double-buffered) == serial G == 1
# ===========================================================================

def run_moe_pipeline_checks():
    import dataclasses
    import types

    from repro.models.moe import init_moe, moe_ffn
    from repro.parallel.context import ParallelContext

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = types.SimpleNamespace(num_experts=8, top_k=2, act="silu",
                                moe_capacity=4.0)
    d_model, f = 16, 32
    params = init_moe(jax.random.key(0), d_model, f, cfg.num_experts)
    rng = np.random.default_rng(5)
    # b*s = 64 -> n_local = 16 per (pod, data) rank, divisible by G = 4
    x = jnp.asarray(rng.normal(size=(4, 16, d_model)).astype(np.float32))
    base = ParallelContext(mesh=mesh, pod_axis="pod", data_axis="data",
                           model_axis="model", plan_policy="fixed")
    # both dispatch schemes x both combine schemes (baseline dispatch has
    # no relay to reduce at, so its return path is always unicast)
    combos = [("hierarchical", "hierarchical"),
              ("hierarchical", "baseline"),
              ("baseline", "baseline")]
    for scheme, combine in combos:
        outs, auxs = {}, {}
        for g in (1, 4):
            pctx = dataclasses.replace(base, moe_scheme=scheme,
                                       moe_combine=combine,
                                       moe_microbatch=g)
            with mesh:
                out, aux = jax.jit(
                    lambda xx, p=pctx: moe_ffn(params, xx, cfg, p))(x)
            outs[g], auxs[g] = np.asarray(out), float(aux)
        ok = np.array_equal(outs[1], outs[4])
        err = float(np.max(np.abs(outs[1] - outs[4])))
        check(f"moe_ffn pipelined G=4 bit-exact vs G=1 "
              f"(dispatch={scheme}, combine={combine}, err={err:.1e})", ok)
        check(f"moe_ffn pipelined aux finite "
              f"(dispatch={scheme}, combine={combine})",
              np.isfinite(auxs[4]) and np.isfinite(auxs[1]))


# ===========================================================================
# moe_ffn under a bound ExecutionPlan == the legacy knob/resolve path
# ===========================================================================

def run_execution_plan_checks():
    import dataclasses
    import types

    from repro.core import plan as plan_ir
    from repro.core.latency_model import moe_overlap_compute_s
    from repro.models.moe import init_moe, moe_ffn
    from repro.parallel.context import ParallelContext

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = types.SimpleNamespace(num_experts=8, top_k=2, act="silu",
                                moe_capacity=4.0)
    d_model, f = 16, 32
    params = init_moe(jax.random.key(0), d_model, f, cfg.num_experts)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(4, 16, d_model)).astype(np.float32))
    base = ParallelContext(mesh=mesh, pod_axis="pod", data_axis="data",
                           model_axis="model", plan_policy="fixed")
    # the EXACT workload moe_ffn derives at trace time (fp32 tokens)
    n_local = (4 * 16) // (2 * 2)
    token_bytes = d_model * 4
    compute_s = moe_overlap_compute_s(n_local, cfg.top_k, d_model, f, tp=2)

    def run(pctx):
        with mesh:
            out, aux = jax.jit(
                lambda xx, p=pctx: moe_ffn(params, xx, cfg, p))(x)
        return np.asarray(out), float(aux)

    combos = [("hierarchical", "hierarchical", 4),
              ("hierarchical", "baseline", 4),
              ("baseline", "baseline", 2)]
    for scheme, combine, g in combos:
        legacy = dataclasses.replace(base, moe_scheme=scheme,
                                     moe_combine=combine,
                                     moe_microbatch=g)
        sites = legacy.moe_sites("train", num_experts=cfg.num_experts,
                                 top_k=cfg.top_k, tokens_per_rank=n_local,
                                 token_bytes=token_bytes,
                                 compute_s=compute_s)
        program = plan_ir.CollectiveProgram("train", sites)
        pinned = plan_ir.pinned_execution_plan(
            program, {"train/moe_dispatch": {"moe_scheme": scheme,
                                             "moe_combine": combine,
                                             "microbatch": g}})
        # bound context declares CONTRASTING knobs: only the plan lookup
        # can produce the pinned configuration
        bound = dataclasses.replace(base, moe_scheme="baseline",
                                    moe_microbatch=1).bind(pinned)
        got = bound.moe_pipeline_kwargs(cfg.num_experts, cfg.top_k,
                                        tokens_per_rank=n_local,
                                        token_bytes=token_bytes,
                                        compute_s=compute_s)
        check(f"bound-plan lookup hit (dispatch={scheme}, combine={combine}"
              f", G={g})",
              got == {"moe_scheme": scheme, "moe_combine": combine,
                      "microbatch": g})
        out_legacy, aux_legacy = run(legacy)
        out_bound, aux_bound = run(bound)
        ok = np.array_equal(out_legacy, out_bound)
        err = float(np.max(np.abs(out_legacy - out_bound)))
        check(f"moe_ffn bound ExecutionPlan bit-exact vs legacy knobs "
              f"(dispatch={scheme}, combine={combine}, G={g}, "
              f"err={err:.1e})", ok)
        check(f"moe_ffn bound aux matches (dispatch={scheme}, "
              f"combine={combine})", aux_legacy == aux_bound)

    # a genuinely PLANNED bind agrees bit-exactly with the ad-hoc auto
    # path (same joint decisions, different resolution mechanism)
    auto = dataclasses.replace(base, plan_policy="auto")
    program = plan_ir.CollectiveProgram(
        "train", auto.moe_sites("train", num_experts=cfg.num_experts,
                                top_k=cfg.top_k, tokens_per_rank=n_local,
                                token_bytes=token_bytes,
                                compute_s=compute_s))
    eplan = auto.plan_collectives(program)
    out_bound, _ = run(auto.bind(eplan))
    out_auto, _ = run(auto)
    check("moe_ffn planned bind bit-exact vs ad-hoc auto "
          f"[{eplan.fingerprint}]", np.array_equal(out_bound, out_auto))


# ===========================================================================
# telemetry LiveProbe: every executable plan's lowering times on the mesh
# ===========================================================================

def run_live_probe_checks():
    from repro.core import plan as plan_ir
    from repro.core.topology import two_server_cluster
    from repro.telemetry import LiveProbe, probe_sweep

    mesh = jax.make_mesh((2, 4), ("pod", "ep"))
    probe = LiveProbe(mesh, axis_name="ep", ep_axis="ep", pod_axis="pod",
                      repeats=1, warmup=1)
    topo = two_server_cluster(npus_per_server=4, num_servers=2)
    records = probe_sweep(topo, probe,
                          payloads={"allgather": (1 << 16,),
                                    "dispatch": (32 * 512,),
                                    "combine": (32 * 512,)},
                          token_bytes=512, num_experts=16, top_k=4)
    by_op = {}
    for r in records:
        by_op.setdefault(r["op"], []).append(r)
        check(f"live probe {r['op']}/{r['plan']} timed "
              f"({r['measured_s']*1e3:.1f}ms, source={r['source']})",
              np.isfinite(r["measured_s"]) and r["measured_s"] > 0
              and r["source"] == "live")
    executable = {op: len([p for p in plan_ir.plans_for(
        op, executable_only=True)]) for op in ("allgather", "dispatch",
                                               "combine")}
    for op, n in executable.items():
        check(f"live probe covered all {n} executable {op} plans",
              len(by_op.get(op, [])) == n)

    # directed p2p rail microbenchmark on the live mesh (per ordered
    # server pair — the probe that fits never-bottlenecking directions)
    from repro.telemetry import probe_link_directions
    drecords = probe_link_directions(topo, probe, payloads=(1 << 16,))
    roles = sorted(r["bottleneck_role"] for r in drecords)
    check(f"live directed probes cover both rail directions ({roles})",
          roles == ["inter:0>1", "inter:1>0"]
          and all(r["measured_s"] > 0 for r in drecords))


# ===========================================================================
# transformer block: SP gather routed through split_tp_allgather
# (tp_subgroups > 1) must not change the forward pass
# ===========================================================================

def run_split_tp_block_checks():
    import dataclasses

    from repro.configs.base import get_config
    from repro.models.api import build_model
    from repro.parallel.context import ParallelContext

    cfg = get_config("mistral_nemo_12b").reduced(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=8, d_ff=128,
        vocab=256)
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    pctx = ParallelContext(mesh=mesh, pod_axis=None, data_axis="data",
                           model_axis="model", fsdp=False, remat="none",
                           seq_parallel=True)
    rng = np.random.default_rng(11)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (2, 64)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 256, (2, 64)),
                                   jnp.int32)}
    outs = {}
    for nd in (1, 2, 4):
        p = dataclasses.replace(pctx, tp_subgroups=nd)
        model = build_model(cfg, p, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        with mesh:
            loss, metrics = jax.jit(model.loss)(params, batch)
        outs[nd] = float(loss)
    # nd=2/4 route every block's SP boundary gather through
    # layers.split_tp_allgather (hierarchical: intra-domain multiwrite
    # gather + one cross-domain gather); nd=1 is the implicit GSPMD path.
    for nd in (2, 4):
        ok = np.isfinite(outs[nd]) and abs(
            outs[nd] - outs[1]) <= 1e-4 * max(1.0, abs(outs[1]))
        check(f"transformer block split-TP gather tp_subgroups={nd} "
              f"matches tp_subgroups=1 (loss {outs[nd]:.6f} vs "
              f"{outs[1]:.6f})", ok)


if __name__ == "__main__":
    assert len(jax.devices()) == 8, jax.devices()
    run_allgather_checks()
    run_dispatch_checks("hierarchical")
    run_dispatch_checks("hierarchical_unicast_combine")
    run_dispatch_checks("baseline")
    run_capacity_checks()
    run_moe_pipeline_checks()
    run_execution_plan_checks()
    run_split_tp_layer_checks()
    run_split_tp_block_checks()
    run_live_probe_checks()
    print("ALL OK")
