"""8-device checks for the compressed / hierarchical gradient collectives.

Run by tests/test_compression.py in a subprocess.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.parallel.compat import shard_map  # noqa: E402
from repro.parallel.compression import (  # noqa: E402
    compressed_psum, hierarchical_psum)


def check(name, ok):
    print(f"{'PASS' if ok else 'FAIL'} {name}")
    if not ok:
        raise SystemExit(1)


def run_compressed_psum():
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    n = 4096
    # per-rank gradients: rank r holds g_r; mean = average
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)

    def inner(g):
        out, err = compressed_psum(g, "data")
        return out, err

    fn = jax.jit(shard_map(inner, mesh=mesh, in_specs=P("data"),
                               out_specs=(P("data"), P("data")),
                               check_vma=False))
    out, err = fn(jnp.asarray(gs.reshape(-1)))
    out = np.asarray(out).reshape(8, n)
    # every rank sees the same (quantized) mean
    for r in range(1, 8):
        check_ok = np.allclose(out[0], out[r])
        if not check_ok:
            check("compressed_psum replicas agree", False)
    # int8 quantization error bound: 2 quant steps of the max |value|
    step1 = np.abs(gs).max() / 127
    step2 = np.abs(mean).max() / 127
    tol = 2 * (step1 + step2)
    err_to_mean = np.abs(out[0] - mean).max()
    check(f"compressed_psum ~= mean (err {err_to_mean:.4f} < tol {tol:.4f})",
          err_to_mean < tol)
    # error feedback residual: g + (-sent) == err
    check("error-feedback residual finite",
          np.isfinite(np.asarray(err)).all())


def run_error_feedback_convergence():
    """With error feedback, the time-average of compressed means converges
    to the true mean (residuals don't accumulate)."""
    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    n = 512
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)

    def inner(g, err):
        return compressed_psum(g, "data", err)

    fn = jax.jit(shard_map(inner, mesh=mesh,
                               in_specs=(P("data"), P("data")),
                               out_specs=(P("data"), P("data")),
                               check_vma=False))
    err = jnp.zeros((8 * n,), jnp.float32)
    g = jnp.asarray(gs.reshape(-1))
    acc = np.zeros(n)
    steps = 20
    for _ in range(steps):
        out, err = fn(g, err)
        acc += np.asarray(out).reshape(8, n)[0]
    drift = np.abs(acc / steps - mean).max()
    naive = np.abs(mean).max() / 127 * 2
    check(f"error feedback keeps time-avg near mean "
          f"(drift {drift:.5f} <= {naive:.5f})", drift <= naive + 1e-5)


def run_hierarchical_psum():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    rng = np.random.default_rng(2)
    n = 1024
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)

    def inner(g):
        return hierarchical_psum(g, "pod", "data")

    fn = jax.jit(shard_map(
        inner, mesh=mesh, in_specs=P(("pod", "data")),
        out_specs=P(("pod", "data")), check_vma=False))
    out = np.asarray(fn(jnp.asarray(gs.reshape(-1)))).reshape(8, n)
    ok = all(np.allclose(out[r], mean, atol=1e-5) for r in range(8))
    check("hierarchical_psum == exact mean on every rank", ok)

    # DCN byte check: pod-axis bytes should be ~1/data_size of flat ring
    from repro.launch.hlo_analysis import MeshLayout
    from repro.launch.hlo_module import analyze_module
    layout = MeshLayout(("pod", "data"), (2, 4))
    text = fn.lower(jax.ShapeDtypeStruct((8 * n,), jnp.float32)) \
        .compile().as_text()
    cost = analyze_module(text, layout)
    pod_b = cost.collective_by_axis.get("pod", 0)
    flat_ring = 2 * n * 4          # what a flat 8-rank ring would move
    check(f"hierarchical pod bytes {pod_b:.0f} < flat ring {flat_ring}",
          0 < pod_b < flat_ring)


if __name__ == "__main__":
    assert len(jax.devices()) == 8
    run_compressed_psum()
    run_error_feedback_convergence()
    run_hierarchical_psum()
    print("ALL OK")
