"""8-device checks for the planned gradient-sync lowerings.

Every registered executable allreduce scheme's ``planned_psum`` must be
bit-compatible with ``lax.psum / R`` (float summation order aside); the
lossy compressed opt-in must land within its quantization tolerance.

Run by tests/test_allreduce_multidev.py in a subprocess.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core.collectives import butterfly_psum, planned_psum  # noqa: E402
from repro.parallel.compat import shard_map  # noqa: E402
from repro.parallel.compression import hierarchical_psum_flat  # noqa: E402


def check(name, ok):
    print(f"{'PASS' if ok else 'FAIL'} {name}")
    if not ok:
        raise SystemExit(1)


def _run(fn_inner, gs, out_spec=None):
    mesh = jax.make_mesh((8,), ("data",))
    f = jax.jit(shard_map(fn_inner, mesh=mesh, in_specs=P("data"),
                          out_specs=out_spec or P("data"),
                          check_vma=False))
    return np.asarray(f(jnp.asarray(gs.reshape(-1))))


def run_every_scheme_matches_psum():
    rng = np.random.default_rng(0)
    n = 4096
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)
    for scheme in ("ring", "tree", "hierarchical", "multiwrite"):
        out = _run(lambda g, s=scheme: planned_psum(
            g, "data", num_servers=2, reduce_scheme=s), gs).reshape(8, n)
        ok = all(np.allclose(out[r], mean, atol=1e-5) for r in range(8))
        check(f"planned_psum[{scheme}] == mean on every rank", ok)


def run_planner_decided_scheme():
    """decision=None: the process planner picks from payload + fabric;
    whatever it picks must still be the exact mean."""
    rng = np.random.default_rng(1)
    n = 2048
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)
    out = _run(lambda g: planned_psum(g, "data", num_servers=2),
               gs).reshape(8, n)
    ok = all(np.allclose(out[r], mean, atol=1e-5) for r in range(8))
    check("planned_psum[planner-decided] == mean on every rank", ok)


def run_bound_decision_scheme():
    """The bound ExecutionPlan path: plan a train program with a
    grad_sync site, feed its decision into planned_psum."""
    from repro.core import plan as plan_ir
    from repro.core import planner as pl
    from repro.core.topology import get_fabric

    topo = get_fabric("2x8")
    site = plan_ir.grad_sync_site("train", payload_bytes=8 * 4096 * 4,
                                  compute_s=1e-3, topo=topo)
    eplan = pl.Planner().plan_program(
        plan_ir.CollectiveProgram("train", (site,)), topo)
    d = eplan.decisions["train/grad_sync"]
    rng = np.random.default_rng(2)
    n = 4096
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)
    out = _run(lambda g: planned_psum(g, "data", num_servers=2,
                                      decision=d), gs).reshape(8, n)
    ok = all(np.allclose(out[r], mean, atol=1e-5) for r in range(8))
    check(f"planned_psum[bound:{d.plan}] == mean on every rank", ok)


def run_compressed_within_tolerance():
    rng = np.random.default_rng(3)
    n = 4096
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)
    out = _run(lambda g: planned_psum(g, "data",
                                      reduce_scheme="compressed"),
               gs).reshape(8, n)
    # int8 wire format: two quantization steps of error
    tol = 2 * (np.abs(gs).max() / 127 + np.abs(mean).max() / 127)
    err = np.abs(out[0] - mean).max()
    check(f"planned_psum[compressed] within int8 tolerance "
          f"(err {err:.4f} < tol {tol:.4f})", err < tol)


def run_butterfly_is_exact_sum():
    rng = np.random.default_rng(4)
    n = 512
    gs = rng.normal(size=(8, n)).astype(np.float32)
    out = _run(lambda g: butterfly_psum(g, "data"), gs).reshape(8, n)
    ok = all(np.allclose(out[r], gs.sum(0), atol=1e-4) for r in range(8))
    check("butterfly_psum == exact sum on every rank", ok)


def run_hierarchical_flat_grouping():
    """hierarchical_psum_flat derives (servers x npus) groups from the
    fabric meta: correct on a 2x4 grouping of one flat 8-rank axis, and
    on the degenerate 1-server grouping."""
    rng = np.random.default_rng(5)
    n = 1000                      # non-divisible by P=4: exercises padding
    gs = rng.normal(size=(8, n)).astype(np.float32)
    mean = gs.mean(0)
    for servers in (1, 2, 4):
        out = _run(lambda g, s=servers: hierarchical_psum_flat(
            g, "data", s), gs).reshape(8, n)
        ok = all(np.allclose(out[r], mean, atol=1e-5) for r in range(8))
        check(f"hierarchical_psum_flat[{servers} servers] == mean", ok)


def run_non_pow2_and_unfactorable_fallbacks():
    """tree on a non-pow2 axis and hierarchical on an unfactorable axis
    fall back to the ring — still the exact mean."""
    mesh = jax.make_mesh((8,), ("data",))
    del mesh
    import jax.sharding as shd
    devs = jax.devices()[:6]
    mesh6 = jax.sharding.Mesh(np.array(devs), ("data",))
    rng = np.random.default_rng(6)
    n = 600
    gs = rng.normal(size=(6, n)).astype(np.float32)
    mean = gs.mean(0)
    for scheme, kw in (("tree", {}), ("hierarchical", {"num_servers": 4})):
        f = jax.jit(shard_map(
            lambda g, s=scheme, k=kw: planned_psum(g, "data",
                                                   reduce_scheme=s, **k),
            mesh=mesh6, in_specs=shd.PartitionSpec("data"),
            out_specs=shd.PartitionSpec("data"), check_vma=False))
        out = np.asarray(f(jnp.asarray(gs.reshape(-1)))).reshape(6, n)
        ok = all(np.allclose(out[r], mean, atol=1e-5) for r in range(6))
        check(f"planned_psum[{scheme}] fallback on awkward axis == mean",
              ok)


if __name__ == "__main__":
    assert len(jax.devices()) == 8
    run_every_scheme_matches_psum()
    run_planner_decided_scheme()
    run_bound_decision_scheme()
    run_compressed_within_tolerance()
    run_butterfly_is_exact_sum()
    run_hierarchical_flat_grouping()
    run_non_pow2_and_unfactorable_fallbacks()
    print("ALL OK")
