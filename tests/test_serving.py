"""Serving tier: queue, traffic, planner-informed admission, the
iteration-level scheduler (join/exit between decode steps), bit-exact
continuous-vs-one-shot generation, engine plan memoization, and the
per-request SLO bands."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import batch_bucket
from repro.core.topology import get_fabric, two_server_cluster
from repro.serving import (AdmissionController, BatchScheduler,
                           PlannerProbe, Request, RequestQueue,
                           TrafficConfig, TrafficGenerator)
from repro.telemetry.metrics import reset_default_registry

TOKEN_BYTES = 14336     # bf16 x d_model 7168: the Fig 8 decode payload


@pytest.fixture(scope="module")
def probe():
    return PlannerProbe(get_fabric("2x8"), token_bytes=TOKEN_BYTES)


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------

class TestRequestQueue:
    def test_arrival_gating(self):
        q = RequestQueue()
        q.push(Request(rid=0, arrival_s=0.5, max_new=4))
        q.push(Request(rid=1, arrival_s=0.1, max_new=4))
        assert q.ready_count(0.0) == 0
        assert q.ready_count(0.2) == 1
        assert q.next_arrival_s(0.0) == pytest.approx(0.1)
        assert q.next_arrival_s(0.2) == pytest.approx(0.5)
        got = q.pop_ready(0.2, 8)
        assert [r.rid for r in got] == [1]
        assert len(q) == 1

    def test_class_priority_fifo_within_class(self):
        q = RequestQueue()
        q.push(Request(rid=0, slo_class="batch"))
        q.push(Request(rid=1, slo_class="interactive"))
        q.push(Request(rid=2, slo_class="standard"))
        q.push(Request(rid=3, slo_class="interactive"))
        got = q.pop_ready(0.0, 4)
        assert [r.rid for r in got] == [1, 3, 2, 0]

    def test_oldest_wait(self):
        q = RequestQueue()
        q.push(Request(rid=0, arrival_s=1.0))
        q.push(Request(rid=1, arrival_s=3.0))
        assert q.oldest_wait_s(5.0) == pytest.approx(4.0)
        assert q.oldest_wait_s(0.5) == 0.0


# ---------------------------------------------------------------------------
# traffic
# ---------------------------------------------------------------------------

class TestTraffic:
    def test_deterministic_per_seed(self):
        cfg = TrafficConfig(arrival_rate_rps=100.0, num_requests=32,
                            prompt_lens=(16, 64), prompt_len_probs=(.5, .5),
                            max_news=(4, 8), max_new_probs=(.5, .5),
                            slo_classes=("interactive", "batch"),
                            slo_class_probs=(.5, .5), vocab=128, seed=3)
        a = TrafficGenerator(cfg).requests()
        b = TrafficGenerator(cfg).requests()
        assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
        assert [r.prompt_len for r in a] == [r.prompt_len for r in b]
        assert [r.slo_class for r in a] == [r.slo_class for r in b]
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(ra.prompt, rb.prompt)
        c = TrafficGenerator(
            TrafficConfig(arrival_rate_rps=100.0, num_requests=32,
                          vocab=128, seed=4)).requests()
        assert [r.arrival_s for r in a] != [r.arrival_s for r in c]

    def test_arrivals_monotone_and_open_loop(self):
        reqs = TrafficGenerator(TrafficConfig(
            arrival_rate_rps=50.0, num_requests=200, seed=0)).requests()
        arr = [r.arrival_s for r in reqs]
        assert arr == sorted(arr)
        # mean interarrival ~ 1/rate (law of large numbers, fixed seed)
        assert arr[-1] / len(arr) == pytest.approx(1 / 50.0, rel=0.3)


# ---------------------------------------------------------------------------
# planner-informed admission
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_probe_stale_scheme_penalty(self, probe):
        xover = probe.crossover_batch()
        assert xover != float("inf"), "2x8 must cross at this payload"
        big = int(xover) * 8
        fresh = probe.decode_step_s(big)
        stale = probe.decode_step_s(big, bound_batch=1)
        assert probe.scheme_at(1) != probe.scheme_at(big)
        assert stale > fresh      # the crossover-oblivious cost is real

    def test_crossover_aware_hold_vs_greedy(self, probe):
        xover = int(probe.crossover_batch())
        slo = probe.decode_step_s(xover) * 1.05
        planner = AdmissionController(probe, capacity=4 * xover,
                                      policy="planner", tpot_slo_s=slo,
                                      ttft_slo_s=0.08)
        greedy = AdmissionController(probe, capacity=4 * xover,
                                     policy="greedy", tpot_slo_s=slo)
        dec = planner.decide(in_flight=xover, ready=xover)
        assert dec.reason == "tpot_slo_hold"
        assert dec.admit == 0 and dec.held == xover
        assert dec.target_batch == xover       # held AT the crossover
        assert planner.holds == 1
        gdec = greedy.decide(in_flight=xover, ready=xover)
        assert gdec.reason == "greedy" and gdec.admit == xover

    def test_ttft_pressure_overrides_hold(self, probe):
        xover = int(probe.crossover_batch())
        slo = probe.decode_step_s(xover) * 1.05
        adm = AdmissionController(probe, capacity=4 * xover,
                                  policy="planner", tpot_slo_s=slo,
                                  ttft_slo_s=0.08)
        dec = adm.decide(in_flight=xover, ready=xover,
                         oldest_wait_s=0.05)     # > half the TTFT SLO
        assert dec.reason == "ttft_pressure"
        assert dec.admit == xover

    def test_bucket_crossing_stages_next_plan(self, probe):
        xover = int(probe.crossover_batch())
        adm = AdmissionController(
            probe, capacity=8 * xover, policy="planner",
            tpot_slo_s=probe.decode_step_s(8 * xover) * 2,  # generous
            ttft_slo_s=0.08)
        dec = adm.decide(in_flight=xover // 2, ready=xover // 2,
                         bound_bucket=xover // 2)
        assert dec.admit == xover // 2
        assert dec.stage_bucket == batch_bucket(xover)
        assert dec.reason == "crossover_rebind"   # growth crosses Fig 8
        # same-bucket growth stages nothing
        dec2 = adm.decide(in_flight=1, ready=1, bound_bucket=2)
        assert dec2.stage_bucket is None

    def test_capacity_reject(self, probe):
        reset_default_registry()
        adm = AdmissionController(probe, capacity=4, policy="greedy")
        dec = adm.decide(in_flight=4, ready=3)
        assert dec.admit == 0 and dec.reason == "capacity"
        assert adm.rejected == {"capacity": 3}


# ---------------------------------------------------------------------------
# scheduler (virtual-time simulation: engine=None)
# ---------------------------------------------------------------------------

class TestSchedulerSim:
    def _sched(self, probe, reqs, **kw):
        q = RequestQueue()
        for r in reqs:
            q.push(r)
        kw.setdefault("admission",
                      AdmissionController(probe, capacity=64,
                                          policy="greedy"))
        return BatchScheduler(queue=q, probe=probe, **kw)

    def test_join_and_exit_without_drain_barrier(self, probe):
        reset_default_registry()
        reqs = [Request(rid=0, arrival_s=0.0, prompt_len=16, max_new=2),
                Request(rid=1, arrival_s=0.0, prompt_len=16, max_new=64),
                Request(rid=2, arrival_s=1e-3, prompt_len=16, max_new=4)]
        sched = self._sched(probe, reqs).run_until_drained()
        assert len(sched.completed) == 3 and sched.idle
        by = {r.rid: r for r in sched.completed}
        # rid 0 exits after 2 tokens while rid 1 keeps decoding
        assert by[0].finish_s < by[1].finish_s
        # rid 2 joins mid-decode: first token BEFORE rid 1 finishes
        # (no drain barrier), in its own cohort after its arrival
        assert by[2].arrival_s < by[1].finish_s
        assert by[2].admit_s >= by[2].arrival_s
        assert by[2].first_token_s < by[1].finish_s
        assert sched.max_in_flight >= 2

    def test_static_batching_drains_before_admitting(self, probe):
        reqs = [Request(rid=0, arrival_s=0.0, prompt_len=16, max_new=32),
                Request(rid=1, arrival_s=1e-4, prompt_len=16, max_new=4)]
        sched = self._sched(probe, reqs,
                            static_batching=True).run_until_drained()
        by = {r.rid: r for r in sched.completed}
        assert by[1].admit_s >= by[0].finish_s   # the drain barrier
        assert sched.max_in_flight == 1

    def test_virtual_clock_and_predictions_stamped(self, probe):
        reqs = [Request(rid=0, arrival_s=0.0, prompt_len=128, max_new=4)]
        sched = self._sched(probe, reqs).run_until_drained()
        (r,) = sched.completed
        assert r.predicted_ttft_s == pytest.approx(
            probe.prefill_s(1, 128))
        assert r.predicted_tpot_s == pytest.approx(probe.decode_step_s(1))
        assert r.ttft_s == pytest.approx(probe.prefill_s(1, 128))
        assert r.tpot_s == pytest.approx(probe.decode_step_s(1))
        assert sched.now == pytest.approx(
            probe.prefill_s(1, 128) + 3 * probe.decode_step_s(1))

    def test_bucket_growth_swaps_warm_plan(self, probe):
        reset_default_registry()
        from repro.core import latency_model as lm
        from repro.core import plan as plan_ir
        from repro.core.planner import default_planner
        from repro.parallel.context import PlanBinder
        topo = get_fabric("2x8")

        def plan_for_bucket(bucket):
            sites = plan_ir.moe_sites(
                "decode", num_experts=64, top_k=8, tokens_per_rank=bucket,
                token_bytes=TOKEN_BYTES,
                compute_s=lm.expert_compute_time_s(bucket, 8, 7168, 2048))
            return default_planner().plan_program(
                plan_ir.CollectiveProgram("serve", sites), topo, None)

        binder = PlanBinder(lambda p: {"fp": p.fingerprint},
                            plan=plan_for_bucket(4))
        reqs = [Request(rid=i, arrival_s=0.0, prompt_len=16, max_new=8)
                for i in range(4)]
        reqs += [Request(rid=4 + i, arrival_s=2e-3, prompt_len=16,
                         max_new=8) for i in range(28)]
        sched = self._sched(
            probe, reqs, binder=binder, plan_for_bucket=plan_for_bucket,
            admission=AdmissionController(
                probe, capacity=64, policy="planner",
                tpot_slo_s=probe.decode_step_s(64) * 2.0,
                ttft_slo_s=0.08)).run_until_drained()
        assert len(sched.completed) == 32
        assert sched.prefetch_rebinds >= 1      # 4 -> 32 staged a bucket
        assert sched.bound_bucket == 32
        assert binder.swaps >= 1
        assert binder.cold_retraces == 0        # pointer-flip growth
        from repro.telemetry.metrics import default_registry
        reg = default_registry()
        assert reg["repro_plan_prefetch_total"].value(program="serve") >= 1
        assert reg["repro_requests_total"].value(outcome="admitted") == 32
        assert reg["repro_requests_total"].value(outcome="completed") == 32

    def test_run_for_partial_then_drain(self, probe):
        reqs = TrafficGenerator(TrafficConfig(
            arrival_rate_rps=2000.0, num_requests=40, prompt_lens=(16,),
            max_news=(8,), seed=1)).requests()
        sched = self._sched(probe, reqs)
        sched.run_for(1e-3)
        assert len(sched.completed) < 40
        sched.run_until_drained()
        assert len(sched.completed) == 40
        rep = sched.report(ttft_slo_s=0.08,
                           tpot_slo_s=probe.decode_step_s(64) * 1.15)
        assert rep["completed"] == 40 and rep["pending"] == 0
        assert rep["ttft_p99_s"] >= rep["ttft_p50_s"] >= 0
        assert rep["goodput_rps"] > 0


# ---------------------------------------------------------------------------
# continuous vs one-shot generate: bit-exact on a live engine
# ---------------------------------------------------------------------------

class TestEngineCohorts:
    @pytest.fixture(scope="class")
    def engine(self):
        from repro.configs.base import get_config
        from repro.models.api import build_model
        from repro.runtime.server import ServeConfig, ServeEngine
        cfg = get_config("rwkv6_7b").reduced(n_layers=2, d_model=32,
                                             n_heads=2, d_ff=64, vocab=64)
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        return ServeEngine(model, params, ServeConfig(max_new_tokens=6))

    def test_staggered_continuous_matches_one_shot(self, engine):
        prompts = np.random.default_rng(2).integers(
            0, 64, size=(4, 8)).astype(np.int32)
        ref = engine.generate(prompts)
        q = RequestQueue()
        for i in range(4):
            q.push(Request(rid=i, arrival_s=0.002 * i,
                           prompt=prompts[i], max_new=6))
        sched = BatchScheduler(
            queue=q,
            admission=AdmissionController(capacity=2, policy="greedy"),
            engine=engine, eos_id=engine.cfg.eos_id, seed=0)
        sched.run_until_drained()
        assert len(sched.completed) == 4
        out = np.zeros_like(ref)
        for r in sched.completed:
            out[r.rid, :len(r.tokens[:6])] = r.tokens[:6]
        np.testing.assert_array_equal(out, ref)

    def test_mixed_prompt_lens_form_separate_cohorts(self, engine):
        # cohorts are position-aligned: one shared prompt_len each —
        # staggered arrivals land in separate cohorts and both drain
        q = RequestQueue()
        rng = np.random.default_rng(3)
        q.push(Request(rid=0, prompt=rng.integers(
            0, 64, size=8).astype(np.int32), max_new=3))
        q.push(Request(rid=1, arrival_s=1e-5, prompt=rng.integers(
            0, 64, size=12).astype(np.int32), max_new=3))
        sched = BatchScheduler(
            queue=q,
            admission=AdmissionController(capacity=4, policy="greedy"),
            engine=engine, seed=0)
        sched.run_until_drained()
        assert len(sched.completed) == 2

    def test_mixed_prompt_lens_in_one_wave_rejected(self, engine):
        # a single admission wave cannot mix prompt lengths (padding is
        # the caller's job, as one-shot generate does)
        q = RequestQueue()
        rng = np.random.default_rng(4)
        for rid, size in ((0, 8), (1, 12)):
            q.push(Request(rid=rid, prompt=rng.integers(
                0, 64, size=size).astype(np.int32), max_new=2))
        sched = BatchScheduler(
            queue=q,
            admission=AdmissionController(capacity=4, policy="greedy"),
            engine=engine, seed=0)
        with pytest.raises(ValueError, match="one cohort"):
            sched.run_until_drained()


# ---------------------------------------------------------------------------
# engine plan memoization (per-step queries must not re-plan)
# ---------------------------------------------------------------------------

class TestEnginePlanMemo:
    @pytest.fixture()
    def moe_engine(self):
        from repro.configs.base import get_config
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.context import ParallelContext
        from repro.runtime.server import ServeEngine
        cfg = get_config("dbrx_132b").reduced()
        mesh = make_test_mesh(shape=(1,), axes=("model",))
        pctx = ParallelContext(mesh=mesh, pod_axis=None,
                               data_axis="model", model_axis="model",
                               plan_policy="auto",
                               fabric=two_server_cluster())

        class _Stub:
            def __init__(self, c):
                self.cfg = c
            prefill = staticmethod(lambda *a: None)
            decode = staticmethod(lambda *a: None)

        return ServeEngine(_Stub(cfg), None, pctx=pctx)

    def test_program_and_plan_identity_cached(self, moe_engine):
        p1 = moe_engine.serving_program(8, 32)
        assert p1.sites                        # MoE arch declares sites
        assert moe_engine.serving_program(8, 32) is p1
        assert moe_engine.serving_program(16, 32) is not p1
        pl1 = moe_engine._fresh_plan(8, 32)
        assert pl1 is not None
        assert moe_engine._fresh_plan(8, 32) is pl1
        moe_engine.invalidate_plan_cache()
        assert (8, 32) not in moe_engine._plan_cache
        # re-planning may legitimately return the planner-LRU's identical
        # object; what matters is the memo refills and fingerprints agree
        pl2 = moe_engine._fresh_plan(8, 32)
        assert (8, 32) in moe_engine._plan_cache
        assert pl2.fingerprint == pl1.fingerprint
        assert moe_engine.serving_program(8, 32) is p1  # programs stay

    def test_repeated_plan_report_hits_caches(self, moe_engine):
        from repro.core.planner import default_planner
        moe_engine.plan_report(8, 32)          # warm
        misses0 = default_planner().cache_info()["misses"]
        for _ in range(5):
            moe_engine.plan_report(8, 32)
        assert default_planner().cache_info()["misses"] == misses0

    def test_probe_memoizes_planner_queries(self, probe):
        from repro.core.planner import default_planner
        probe.decode_step_s(32)                # warm
        misses0 = default_planner().cache_info()["misses"]
        for _ in range(20):
            probe.decode_step_s(32)
            probe.decode_step_s(32, bound_batch=1)
            probe.crossover_batch()
        assert default_planner().cache_info()["misses"] == misses0


# ---------------------------------------------------------------------------
# per-request SLO bands
# ---------------------------------------------------------------------------

class TestRequestSLO:
    def test_inclusive_band_edges(self):
        from repro.telemetry.slo import classify_request
        out = classify_request({"ttft": 1.2, "tpot": 2.0},
                               {"ttft": 1.0, "tpot": 1.0})
        assert out["ttft"] == "good"           # exactly 1.2x is good
        assert out["tpot"] == "acceptable"     # exactly 2.0x
        assert out["overall"] == "acceptable"  # worst metric wins

    def test_class_slack_scales_prediction(self):
        from repro.telemetry.slo import classify_request
        tight = classify_request({"ttft": 2.4, "tpot": 1.0},
                                 {"ttft": 1.0, "tpot": 1.0})
        assert tight["ttft"] == "poor"
        batchy = classify_request({"ttft": 2.4, "tpot": 1.0},
                                  {"ttft": 1.0, "tpot": 1.0}, slack=8.0)
        assert batchy["ttft"] == "good"

    def test_missing_prediction_is_unknown(self):
        from repro.telemetry.slo import classify_request
        out = classify_request({"ttft": 1.0}, {})
        assert out["ttft"] == "unknown" and out["overall"] == "unknown"

    def test_observe_request_counts_classes(self):
        from repro.telemetry.metrics import default_registry
        from repro.telemetry.slo import observe_request
        reset_default_registry()
        observe_request({"ttft": 1.0, "tpot": 3.0},
                        {"ttft": 1.0, "tpot": 1.0})
        reg = default_registry()
        assert reg["repro_request_slo_class_total"].value(
            metric="ttft", slo="good") == 1
        assert reg["repro_request_slo_class_total"].value(
            metric="tpot", slo="poor") == 1
