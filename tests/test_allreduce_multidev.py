"""Subprocess wrapper for the 8-device planned gradient-sync checks."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_planned_psum_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "tests/multidev/check_allreduce.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ALL OK" in proc.stdout
