"""Tests for the HLO cost analyzer (launch/hlo_module.py + hlo_analysis).

The analyzer is the dry-run's profiler, so it gets its own correctness
suite: validated against XLA's cost_analysis on non-looped programs, and
against hand-computed values for loops (where XLA:CPU cost_analysis is
wrong — it counts while bodies once).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import MeshLayout, _parse_groups
from repro.launch.hlo_module import analyze_module, parse_module

LAYOUT = MeshLayout(("data", "model"), (16, 16))


def compile_text(fn, *sds):
    return jax.jit(fn).lower(*sds).compile().as_text()


def xla_cost(fn, *sds):
    c = jax.jit(fn).lower(*sds).compile().cost_analysis()
    return c[0] if isinstance(c, (list, tuple)) else c


class TestFlops:
    def test_matmul_matches_xla(self):
        m = k = n = 256
        sds = jax.ShapeDtypeStruct((m, k), jnp.float32)

        def f(a, b):
            return jnp.tanh(a @ b) @ b

        text = compile_text(f, sds, sds)
        mine = analyze_module(text, LAYOUT)
        ref = xla_cost(f, sds, sds)
        assert mine.flops == pytest.approx(float(ref["flops"]), rel=0.01)
        assert mine.hbm_bytes == pytest.approx(
            float(ref["bytes accessed"]), rel=0.05)

    def test_scan_multiplies_flops(self):
        """THE fix: XLA counts a while body once; we multiply by trip."""
        m = k = n = 128
        sds = jax.ShapeDtypeStruct((m, k), jnp.float32)
        trips = 10

        def f(a, b):
            def body(x, _):
                return jnp.tanh(x @ b), None
            y, _ = jax.lax.scan(body, a, None, length=trips)
            return y

        text = compile_text(f, sds, sds)
        mine = analyze_module(text, LAYOUT)
        expected = trips * 2 * m * k * n
        assert mine.flops == pytest.approx(expected, rel=0.02)
        assert list(mine.loops.values()) == [trips]
        # and confirm XLA itself is wrong (if this starts passing, the
        # workaround can be removed):
        ref = xla_cost(f, sds, sds)
        assert float(ref["flops"]) < expected / 2

    def test_nested_scans_multiply(self):
        m = 64
        sds = jax.ShapeDtypeStruct((m, m), jnp.float32)

        def f(a, b):
            def outer(x, _):
                def inner(y, _):
                    return y @ b, None
                y, _ = jax.lax.scan(inner, x, None, length=3)
                return y, None
            y, _ = jax.lax.scan(outer, a, None, length=5)
            return y

        mine = analyze_module(compile_text(f, sds, sds), LAYOUT)
        assert mine.flops == pytest.approx(15 * 2 * m**3, rel=0.02)

    def test_dynamic_slice_counts_window_only(self):
        big = jax.ShapeDtypeStruct((64, 1024, 16), jnp.float32)

        def f(x, i):
            return jax.lax.dynamic_index_in_dim(x, i, 0, False) * 2.0

        mine = analyze_module(
            compile_text(f, big, jax.ShapeDtypeStruct((), jnp.int32)),
            LAYOUT)
        # window = 1024*16*4 = 64KB; full operand would be 4MB
        assert mine.hbm_bytes < 1e6


class TestReplicaGroups:
    def test_braced(self):
        g = _parse_groups("replica_groups={{0,1,2,3},{4,5,6,7}}")
        assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]

    def test_iota(self):
        g = _parse_groups("replica_groups=[2,4]<=[8]")
        assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]

    def test_iota_transposed(self):
        g = _parse_groups("replica_groups=[4,2]<=[2,4]T(1,0)")
        assert g == [[0, 4], [1, 5], [2, 6], [3, 7]]

    def test_source_target_pairs(self):
        g = _parse_groups("source_target_pairs={{0,1},{1,0}}")
        assert g == [[0, 1], [1, 0]]


class TestMeshClassify:
    def test_axis_attribution(self):
        lay = MeshLayout(("pod", "data", "model"), (2, 16, 16))
        assert lay.classify([0, 1, 2, 3]) == "model"        # contiguous
        assert lay.classify([0, 16, 32]) == "data"          # stride 16
        assert lay.classify([0, 256]) == "pod"              # crosses pods
        assert lay.classify([0, 16, 256, 272]) == "pod"     # mixed -> slowest


class TestCollectiveBytes:
    def test_allreduce_in_scan_multiplied(self):
        """Collective inside a scan body gets the trip multiplier."""
        from jax.sharding import PartitionSpec as P

        from repro.parallel.compat import shard_map
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = jax.make_mesh((1,), ("model",))

        def inner(x):
            def body(c, _):
                return jax.lax.psum(c, "model"), None
            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        fn = jax.jit(shard_map(inner, mesh=mesh, in_specs=P(),
                               out_specs=P(), check_vma=False))
        text = fn.lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile().as_text()
        # single-device mesh: psum may lower to no collective; just check
        # the parser doesn't crash and loops are found
        cost = analyze_module(text, MeshLayout(("model",), (1,)))
        assert 7 in cost.loops.values() or cost.loops == {} \
            or 7 in list(cost.loops.values())
