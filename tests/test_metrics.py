"""Tests for the observability plane (ISSUE 8).

Covers:
  * MetricsRegistry: counter/gauge/histogram semantics, label keying,
    idempotent registration, schema-conflict rejection.
  * Prometheus text exposition: render -> parse_text round-trip,
    deterministic ordering, HELP/TYPE headers for zero-sample metrics.
  * Histogram bucket edges: an observation exactly equal to a bucket
    bound lands IN that bucket (le is inclusive), cumulative counts.
  * SLO classification: inclusive band boundaries (exactly 1.2x is
    good, exactly 2.0x is acceptable), missing/invalid predictions are
    "unknown", worst-class aggregation per cell.
  * MetricsExporter: live HTTP scrape on an ephemeral port, snapshot
    determinism (identical state -> byte-identical files).
  * Planner instrumentation: decision counters, cache hit/miss, the
    decision-flip counter, and the decision_log ring buffer (the
    unbounded-growth fix) — including that fit_overlap_eff still sees
    its measurement rows after trimming.
  * Docs-sync: every metric in METRIC_SPECS is documented in METRICS.md
    (mirrors the grep gate in ci.yml).
  * Stress soak smoke: the full injected-degradation loop with all five
    assertions, in-process.
"""

import math
import os

import pytest

from repro.core import latency_model as lm
from repro.core.planner import Planner
from repro.core.topology import get_fabric
from repro.telemetry import metrics as m
from repro.telemetry import slo
from repro.telemetry.exporter import MetricsExporter, scrape, write_snapshot

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)


# ---------------------------------------------------------------------------
# registry + exposition format


def test_counter_basics():
    reg = m.MetricsRegistry()
    c = reg.counter("t_total", "help", ("op",))
    c.inc(op="dispatch")
    c.inc(2.5, op="dispatch")
    c.inc(op="combine")
    assert c.value(op="dispatch") == 3.5
    assert c.value(op="combine") == 1.0
    assert c.value(op="missing") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1.0, op="dispatch")


def test_registration_idempotent_and_conflicts():
    reg = m.MetricsRegistry()
    a = reg.counter("x_total", "help", ("op",))
    b = reg.counter("x_total", "help", ("op",))
    assert a is b
    with pytest.raises(ValueError):
        reg.gauge("x_total", "help", ("op",))        # type conflict
    with pytest.raises(ValueError):
        reg.counter("x_total", "help", ("other",))   # label conflict


def test_render_parse_round_trip():
    reg = m.MetricsRegistry()
    reg.counter("rt_total", "a counter", ("op", "fabric")).inc(
        3, op="dispatch", fabric="2x8")
    reg.gauge("rt_ratio", "a gauge", ("op",)).set(0.25, op="combine")
    h = reg.histogram("rt_seconds", "a histogram", (), buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(5.0)
    parsed = m.parse_text(reg.render())
    assert parsed[("rt_total",
                   (("fabric", "2x8"), ("op", "dispatch")))] == 3.0
    assert parsed[("rt_ratio", (("op", "combine"),))] == 0.25
    assert parsed[("rt_seconds_count", ())] == 2.0
    assert parsed[("rt_seconds_sum", ())] == pytest.approx(5.05)
    assert parsed[("rt_seconds_bucket", (("le", "0.1"),))] == 1.0
    assert parsed[("rt_seconds_bucket", (("le", "+Inf"),))] == 2.0


def test_render_deterministic_and_headers_always_present():
    # zero-sample metrics still render HELP/TYPE: a scraper sees the
    # full schema even before the first event (serve-scrape acceptance)
    reg = m.MetricsRegistry()
    reg.counter("zz_total", "never incremented", ("op",))
    reg.counter("aa_total", "also never", ())
    text = reg.render()
    assert "# HELP zz_total never incremented" in text
    assert "# TYPE zz_total counter" in text
    # metrics sorted by name
    assert text.index("aa_total") < text.index("zz_total")
    assert text == reg.render()


def test_label_escaping_round_trip():
    reg = m.MetricsRegistry()
    c = reg.counter("esc_total", "escapes", ("p",))
    weird = 'a"b\\c\nd'
    c.inc(p=weird)
    parsed = m.parse_text(reg.render())
    assert parsed[("esc_total", (("p", weird),))] == 1.0


def test_histogram_bucket_edge_inclusive():
    reg = m.MetricsRegistry()
    h = reg.histogram("edge_seconds", "h", (), buckets=(1.0, 2.0))
    h.observe(1.0)      # exactly at the bound: lands IN le=1.0
    h.observe(1.0001)   # just above: next bucket
    counts = h.bucket_counts()      # cumulative per le bound
    assert counts[1.0] == 1
    assert counts[2.0] == 2
    assert h.count() == 2
    # cumulative rendering: le=2.0 includes the le=1.0 observation
    parsed = m.parse_text(reg.render())   # le renders minimally: "1"
    assert parsed[("edge_seconds_bucket", (("le", "1"),))] == 1.0
    assert parsed[("edge_seconds_bucket", (("le", "2"),))] == 2.0
    assert parsed[("edge_seconds_bucket", (("le", "+Inf"),))] == 2.0


def test_default_registry_preregisters_all_specs():
    reg = m.default_registry()
    for name in m.METRIC_SPECS:
        assert name in reg
    # every spec'd metric renders headers even with no samples
    text = reg.render()
    for name in m.METRIC_SPECS:
        assert f"# TYPE {name} " in text


# ---------------------------------------------------------------------------
# SLO classification


def test_slo_band_boundaries_inclusive():
    assert slo.classify(1.2, 1.0) == "good"        # exactly 1.2x
    assert slo.classify(1.2000001, 1.0) == "acceptable"
    assert slo.classify(2.0, 1.0) == "acceptable"  # exactly 2.0x
    assert slo.classify(2.0000001, 1.0) == "poor"
    assert slo.classify(0.5, 1.0) == "good"


def test_slo_missing_or_invalid_prediction_is_unknown():
    assert slo.classify(1.0, None) == "unknown"
    assert slo.classify(1.0, 0.0) == "unknown"
    assert slo.classify(1.0, -1.0) == "unknown"
    assert slo.classify(1.0, math.nan) == "unknown"
    assert slo.classify(math.nan, 1.0) == "unknown"


def test_slo_classify_records_takes_worst_per_cell():
    records = [
        {"op": "dispatch", "bucket": 512, "predicted_s": 1.0,
         "measured_s": 1.0},
        {"op": "dispatch", "bucket": 512, "predicted_s": 1.0,
         "measured_s": 5.0},
    ]
    cells = slo.classify_records(records)
    assert cells[("dispatch", 512)] == "poor"


def test_slo_observe_record_zero_payload():
    reg = m.MetricsRegistry()
    for name in ("repro_slo_class_total", "repro_slo_ratio"):
        spec = m.METRIC_SPECS[name]
        getattr(reg, spec["type"])(name, spec["help"], spec["labels"])
    cls = slo.observe_record(
        {"op": "dispatch", "bucket": 0, "fabric_name": "2x8",
         "predicted_s": 1.0, "measured_s": 1.0}, registry=reg)
    assert cls == "good"
    assert reg["repro_slo_class_total"].value(
        op="dispatch", payload_bucket="0", fabric="2x8", slo="good") == 1.0


# ---------------------------------------------------------------------------
# exporter


def test_exporter_live_scrape():
    reg = m.MetricsRegistry()
    reg.counter("live_total", "scraped", ("op",)).inc(7, op="x")
    with MetricsExporter(0, registry=reg) as exp:
        assert exp.port != 0
        text = scrape(exp.url)
    parsed = m.parse_text(text)
    assert parsed[("live_total", (("op", "x"),))] == 7.0


def test_snapshot_deterministic(tmp_path):
    reg = m.MetricsRegistry()
    g = reg.gauge("snap_ratio", "g", ("op",))
    g.set(1.5, op="b")
    g.set(0.5, op="a")
    p1, p2 = str(tmp_path / "s1.prom"), str(tmp_path / "s2.prom")
    write_snapshot(p1, registry=reg)
    write_snapshot(p2, registry=reg)
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        b1, b2 = f1.read(), f2.read()
    assert b1 == b2
    assert b"snap_ratio" in b1


def test_serve_scrape_has_required_metric_families():
    # the acceptance scrape: drift, decision-flip and phase-budget SLO
    # families must be present in any scrape of the default registry
    with MetricsExporter(0) as exp:
        text = scrape(exp.url)
    for name in ("repro_drift_ratio", "repro_planner_decision_flips_total",
                 "repro_phase_budget_ok", "repro_slo_class_total"):
        assert f"# TYPE {name} " in text


# ---------------------------------------------------------------------------
# planner instrumentation + ring buffer (satellite 1)


def test_decision_log_ring_buffer():
    topo = get_fabric("2x8")
    planner = Planner(decision_log_max=4)
    batches = [2 ** i for i in range(14)]   # distinct payload buckets
    for batch in batches:
        planner.choose("dispatch", batch * lm.TOKEN_BYTES, topo,
                       token_bytes=lm.TOKEN_BYTES)
    assert len(planner.decision_log) <= 4
    assert planner.decision_log_dropped > 0
    # newest entries survive (it's a ring, not a truncation); logged
    # payloads are bucketed
    from repro.core.planner import bucket_payload
    assert (planner.decision_log[-1]["payload_bytes"]
            == bucket_payload(batches[-1] * lm.TOKEN_BYTES))


def test_note_measurement_fallback_is_bounded():
    # regression: the note_measurement fallback append used to grow
    # decision_log without bound
    topo = get_fabric("2x8")
    planner = Planner(decision_log_max=16)
    d = planner.choose("dispatch", 64 * lm.TOKEN_BYTES, topo,
                       token_bytes=lm.TOKEN_BYTES)
    for i in range(200):
        # the first call fills the logged row; every later one takes the
        # fallback append path (the row's measured_s is no longer None)
        planner.note_measurement(d, 1e-3 + i * 1e-6)
    assert len(planner.decision_log) <= 16
    assert planner.decision_log_dropped >= 200 - 16
    # fit_overlap_eff still sees measurement rows after trimming
    rows = [r for r in planner.decision_log
            if r.get("measured_s") is not None]
    assert rows, "measured rows must survive the ring buffer"


def test_planner_metrics_decisions_cache_and_flips():
    m.reset_default_registry()
    reg = m.default_registry()
    topo = get_fabric("2x8")
    planner = Planner()
    payload = 64 * lm.TOKEN_BYTES
    d1 = planner.choose("dispatch", payload, topo,
                        token_bytes=lm.TOKEN_BYTES)
    assert reg["repro_planner_cache_misses_total"].value() >= 1.0
    planner.choose("dispatch", payload, topo, token_bytes=lm.TOKEN_BYTES)
    assert reg["repro_planner_cache_hits_total"].value() >= 1.0
    # decision counter labeled by op/fabric
    total = sum(v for (labels, v) in
                reg["repro_planner_decisions_total"].samples()
                if labels["op"] == "dispatch")
    assert total >= 1.0
    # a recalibration that flips the winning scheme bumps the flip
    # counter (same planner instance, refreshed hw)
    links = {k: ln.bw / 4 for k, ln in topo.links.items()
             if topo.server_of(ln.src) != topo.server_of(ln.dst)}
    planner.refresh_hardware(
        planner.hw.recalibrated({"links": links}, topo))
    d2 = planner.choose("dispatch", payload, topo,
                        token_bytes=lm.TOKEN_BYTES)
    assert d2.plan != d1.plan
    flips = sum(v for (_, v) in
                reg["repro_planner_decision_flips_total"].samples())
    assert flips >= 1.0


# ---------------------------------------------------------------------------
# docs-sync (mirrors the ci.yml grep gate)


def test_every_metric_documented_in_metrics_md():
    path = os.path.join(REPO, "METRICS.md")
    assert os.path.exists(path), "METRICS.md missing"
    with open(path) as f:
        doc = f.read()
    missing = [name for name in m.METRIC_SPECS if name not in doc]
    assert not missing, f"undocumented metrics: {missing}"


# ---------------------------------------------------------------------------
# stress soak (smoke shape, in-process)


def test_stress_soak_smoke(tmp_path):
    from repro.launch.stress import run_soak
    out = str(tmp_path / "STRESS_soak.json")
    result = run_soak(epochs=6, smoke=True, out_path=out)
    assert result["ok"], result["assertions"]
    assert os.path.exists(out)
    names = {a["name"] for a in result["assertions"]}
    assert names == {"detection", "convergence", "flips", "stale", "slo"}
    assert all(a["ok"] for a in result["assertions"])
