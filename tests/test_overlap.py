"""Overlap-aware pipelined scoring (ISSUE 4).

What is pinned here:

  * the pipelined scoring MODE of ``score_ledger``: G-chunk overlap
    ledgers pay ``max(stage) + (G-1)*bottleneck`` derated by
    ``hw.overlap_eff`` instead of the serial ``G*sum``, with the
    per-chunk alpha penalty that makes small G optimal;
  * ``Planner.choose`` genuinely selecting ``microbatch > 1`` at
    operating points where the overlap win beats the per-chunk alpha
    (the ISSUE acceptance criterion), and staying at G == 1 both for
    tiny batches and whenever no overlap context is given (so every
    pre-overlap decision is unchanged);
  * the decision cache keying on the compute bucket;
  * the telemetry hook: ``fit_overlap_eff`` recovers an injected true
    efficiency from measured ``Planner.decision_log`` rows, and the
    recalibrated model moves subsequent G choices;
  * ``ParallelContext.moe_pipeline_kwargs`` threading (scheme AND G,
    jointly with the combine half since the ExecutionPlan redesign).
"""

import dataclasses

import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core.topology import two_server_cluster

TOPO = two_server_cluster()
TOKEN = lm.TOKEN_BYTES


def compute_ctx(batch, top_k=8, d_model=7168, f_shard=2048):
    return lm.expert_compute_time_s(batch, top_k, d_model, f_shard)


def dispatch_ledger(batch, microbatch, compute_s=0.0):
    scenario = plan_ir.DispatchScenario(topo=TOPO, compute_s=compute_s)
    return plan_ir.get_plan("dispatch", "multiwrite").simulate(
        scenario, batch * TOKEN, microbatch=microbatch)


# ---------------------------------------------------------------------------
# the scoring mode
# ---------------------------------------------------------------------------

class TestPipelinedScoring:
    def test_no_overlap_context_g_never_wins(self):
        """compute_s == 0: chunking only adds per-chunk alphas, so the
        serial G == 1 score is optimal at every batch (the pre-overlap
        behaviour, byte-for-byte)."""
        for batch in (32, 512, 4096):
            scores = [lm.score_ledger(dispatch_ledger(batch, g))
                      for g in (1, 2, 4, 8)]
            assert scores == sorted(scores)
            assert scores[0] == pytest.approx(
                scores[1] - lm.DEFAULT.alpha_base)

    def test_overlap_beats_serial_past_crossover(self):
        c = compute_ctx(2048)
        serial = lm.score_ledger(dispatch_ledger(2048, 1, c))
        piped = lm.score_ledger(dispatch_ledger(2048, 4, c))
        assert piped < serial

    def test_interpolation_endpoints(self):
        """score(eta) moves linearly between the serial and ideal
        endpoints; overlap_endpoints brackets every mid score."""
        led = dispatch_ledger(1024, 4, compute_ctx(1024))
        serial, ideal = lm.overlap_endpoints(led)
        assert ideal < serial
        mid = lm.score_ledger(
            led, dataclasses.replace(lm.DEFAULT, overlap_eff=0.5))
        assert mid == pytest.approx(0.5 * (serial + ideal))
        assert lm.score_ledger(
            led, dataclasses.replace(lm.DEFAULT, overlap_eff=0.0)) \
            == pytest.approx(serial)

    def test_ideal_pipeline_pays_bottleneck_stage(self):
        """At eta == 1 and large G the score approaches
        fixed + max(wire, compute) — the steady-state bottleneck stage —
        from above (overlap can't hide the bigger stage)."""
        c = compute_ctx(4096)
        led = dispatch_ledger(4096, 8, c)
        hw = dataclasses.replace(lm.DEFAULT, overlap_eff=1.0)
        serial_1 = lm.score_ledger(dispatch_ledger(4096, 1, c), hw)
        wire = serial_1 - lm.DEFAULT.alpha_base - c \
            - dispatch_ledger(4096, 1, c).alpha_extra_s \
            - lm.DEFAULT.alpha_hop
        floor = max(wire, c)
        assert floor < lm.score_ledger(led, hw) < serial_1

    def test_serial_chunks_unchanged_without_overlap_flag(self):
        """A stages > 1 ledger NOT marked overlap keeps the serial
        G*alpha + wire formula (the old lax.map chunk loop)."""
        led = dataclasses.replace(dispatch_ledger(512, 4), overlap=False)
        assert lm.score_ledger(led) == pytest.approx(
            lm.score_ledger(dispatch_ledger(512, 1))
            + 3 * lm.DEFAULT.alpha_base)

    def test_overlap_eff_in_fingerprint_and_recalibrated(self):
        hw = lm.DEFAULT.recalibrated({"overlap_eff": 0.42})
        assert hw.overlap_eff == 0.42
        assert hw.fingerprint() != lm.DEFAULT.fingerprint()


# ---------------------------------------------------------------------------
# the planner picks G (ISSUE acceptance)
# ---------------------------------------------------------------------------

class TestPlannerPicksG:
    def test_choose_selects_microbatch_gt1(self):
        """ACCEPTANCE: at a registered-fabric operating point with
        overlap context the winning knob set carries microbatch > 1, and
        the pipelined score beats the best serial candidate."""
        planner = pl.Planner()
        d = planner.choose("dispatch", 2048 * TOKEN, TOPO,
                           token_bytes=TOKEN,
                           compute_s=compute_ctx(2048))
        assert d.microbatch > 1
        serial_best = min(t for _, kn, t in d.candidates
                          if dict(kn).get("microbatch", 1) == 1)
        assert d.predicted_s < serial_best

    def test_combine_also_picks_g(self):
        planner = pl.Planner()
        d = planner.choose("combine", 2048 * TOKEN, TOPO,
                           token_bytes=TOKEN,
                           compute_s=compute_ctx(2048))
        assert d.microbatch > 1

    def test_small_batch_stays_serial(self):
        """The per-chunk alpha keeps tiny decode batches at G == 1 even
        with overlap context."""
        planner = pl.Planner()
        d = planner.choose("dispatch", 8 * TOKEN, TOPO,
                           token_bytes=TOKEN, compute_s=compute_ctx(8))
        assert d.microbatch == 1

    def test_no_context_decisions_unchanged(self):
        """Without compute_s the widened grid never changes a decision:
        G == 1 wins everywhere (pre-overlap planner behaviour)."""
        planner = pl.Planner()
        for batch in (8, 64, 1024, 4096):
            d = planner.choose("dispatch", batch * TOKEN, TOPO,
                               token_bytes=TOKEN)
            assert d.microbatch == 1

    def test_cache_keyed_on_compute_bucket(self):
        planner = pl.Planner()
        planner.choose("dispatch", 2048 * TOKEN, TOPO, token_bytes=TOKEN,
                       compute_s=compute_ctx(2048))
        misses = planner.cache_misses
        # same bucket -> hit; an order-of-magnitude different compute ->
        # new bucket -> fresh sweep
        planner.choose("dispatch", 2048 * TOKEN, TOPO, token_bytes=TOKEN,
                       compute_s=compute_ctx(2048) * 1.01)
        assert planner.cache_misses == misses
        planner.choose("dispatch", 2048 * TOKEN, TOPO, token_bytes=TOKEN,
                       compute_s=compute_ctx(2048) * 10)
        assert planner.cache_misses == misses + 1

    def test_decision_carries_overlap_endpoints(self):
        planner = pl.Planner()
        d = planner.choose("dispatch", 2048 * TOKEN, TOPO,
                           token_bytes=TOKEN,
                           compute_s=compute_ctx(2048))
        assert d.predicted_ideal_s < d.predicted_s < d.predicted_serial_s
        row = planner.decision_log[-1]
        assert row["predicted_serial_s"] == d.predicted_serial_s
        assert row["predicted_ideal_s"] == d.predicted_ideal_s


# ---------------------------------------------------------------------------
# the telemetry hook (fit_overlap_eff closes the loop)
# ---------------------------------------------------------------------------

class TestOverlapFit:
    def _measured_planner(self, true_eta):
        from repro.telemetry import fit_overlap_eff
        planner = pl.Planner()
        n = 0
        for batch in (512, 1024, 2048, 4096):
            d = planner.choose("dispatch", batch * TOKEN, TOPO,
                               token_bytes=TOKEN,
                               compute_s=compute_ctx(batch))
            if d.microbatch <= 1:
                continue
            measured = d.predicted_serial_s - true_eta * (
                d.predicted_serial_s - d.predicted_ideal_s)
            planner.note_measurement(d, measured)
            n += 1
        return planner, fit_overlap_eff(planner.decision_log), n

    def test_fit_recovers_injected_eta(self):
        for true_eta in (0.3, 0.6, 0.9):
            _, eta, n = self._measured_planner(true_eta)
            assert n >= 3
            assert eta == pytest.approx(true_eta, abs=1e-9)

    def test_fit_needs_enough_pipelined_rows(self):
        from repro.telemetry import fit_overlap_eff
        planner = pl.Planner()
        # serial decisions only: endpoints coincide, no signal
        for batch in (8, 16, 32, 64):
            d = planner.choose("dispatch", batch * TOKEN, TOPO,
                               token_bytes=TOKEN)
            planner.note_measurement(d, d.predicted_s)
        assert fit_overlap_eff(planner.decision_log) is None

    def test_refit_moves_subsequent_g_choice(self):
        """A fitted low efficiency (overlap barely works) must shrink or
        kill the chosen G for the same workload — the closed loop."""
        planner, eta, _ = self._measured_planner(0.05)
        d_before = planner.choose("dispatch", 1024 * TOKEN, TOPO,
                                  token_bytes=TOKEN,
                                  compute_s=compute_ctx(1024))
        planner.refresh_hardware(
            planner.hw.recalibrated({"overlap_eff": eta}))
        d_after = planner.choose("dispatch", 1024 * TOKEN, TOPO,
                                 token_bytes=TOKEN,
                                 compute_s=compute_ctx(1024))
        assert d_after.microbatch < d_before.microbatch

    def test_repeated_measurements_of_cached_decision_feed_fit(self):
        """note_measurement's fallback rows (decision served from cache)
        must carry the overlap endpoints too — steady-state training
        measures ONE operating point repeatedly and that alone has to
        reach OVERLAP_MIN_POINTS."""
        from repro.telemetry import fit_overlap_eff
        planner = pl.Planner()
        true_eta = 0.55
        d = planner.choose("dispatch", 2048 * TOKEN, TOPO,
                           token_bytes=TOKEN, compute_s=compute_ctx(2048))
        assert d.microbatch > 1
        measured = d.predicted_serial_s - true_eta * (
            d.predicted_serial_s - d.predicted_ideal_s)
        for _ in range(4):                       # 1 fill + 3 fallback rows
            planner.note_measurement(d, measured)
        assert fit_overlap_eff(planner.decision_log) == pytest.approx(
            true_eta, abs=1e-9)

    def test_probe_timing_never_fills_pipelined_row(self):
        """A default-knob (G == 1) probe record must not land in a G > 1
        decision row: the collective-only time would masquerade as a
        pipelined end-to-end time and drag overlap_eff toward 1."""
        from repro.telemetry import CalibrationStore, DriftMonitor
        planner = pl.Planner()
        d = planner.choose("dispatch", 2048 * TOKEN, TOPO,
                           token_bytes=TOKEN, compute_s=compute_ctx(2048))
        assert d.microbatch > 1
        monitor = DriftMonitor(planner, CalibrationStore(":memory:"), TOPO)
        monitor.observe({"op": "dispatch", "plan": d.plan,
                         "bucket": d.payload_bytes,
                         "knobs": {"microbatch": 1},
                         "predicted_s": d.predicted_ideal_s * 0.1,
                         "measured_s": d.predicted_ideal_s * 0.1})
        row = planner.decision_log[-1]
        assert dict(row["knobs"])["microbatch"] == d.microbatch
        assert row["measured_s"] is None

    def test_monitor_recalibrate_merges_overlap_fit(self):
        """DriftMonitor.recalibrate folds the decision-log efficiency
        fit into the planner's hardware model alongside the link fits."""
        from repro.telemetry import (CalibrationStore, DriftMonitor,
                                     GroundTruth, SimProbe)
        planner, _, _ = self._measured_planner(0.4)
        store = CalibrationStore(":memory:")
        monitor = DriftMonitor(planner, store, TOPO)
        monitor.run_cycle(SimProbe(GroundTruth(noise=0.01)))
        event = monitor.last_recalibration or monitor.recalibrate(
            force=True)
        assert event["overlap_eff"] == pytest.approx(0.4, abs=1e-9)
        assert planner.hw.overlap_eff == pytest.approx(0.4, abs=1e-9)


# ---------------------------------------------------------------------------
# context threading (scheme AND G reach moe_ffn)
# ---------------------------------------------------------------------------

class TestContextThreading:
    @pytest.fixture()
    def pctx(self):
        import jax

        from repro.launch.mesh import make_test_mesh
        from repro.parallel.context import ParallelContext
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = make_test_mesh(shape=(1,), axes=("model",))
        return ParallelContext(mesh=mesh, pod_axis=None, data_axis="model",
                               model_axis="model", plan_policy="auto",
                               fabric=TOPO)

    def test_moe_pipeline_kwargs_returns_scheme_and_g(self, pctx):
        got = pctx.moe_pipeline_kwargs(64, 8, tokens_per_rank=2048,
                                       token_bytes=TOKEN,
                                       compute_s=compute_ctx(2048))
        assert got["moe_scheme"] in ("hierarchical", "baseline")
        assert got["moe_combine"] in ("hierarchical", "baseline")
        assert got["microbatch"] > 1

    def test_fixed_policy_keeps_declared_knobs(self, pctx):
        fixed = dataclasses.replace(pctx, plan_policy="fixed",
                                    moe_scheme="baseline",
                                    moe_microbatch=4)
        got = fixed.moe_pipeline_kwargs(64, 8, tokens_per_rank=2048,
                                        token_bytes=TOKEN,
                                        compute_s=compute_ctx(2048))
        assert got == {"moe_scheme": "baseline", "moe_combine": "baseline",
                       "microbatch": 4}

    def test_small_batch_stays_serial_without_compute(self, pctx):
        """Alpha-dominated workloads must stay unchunked.  (A LARGE
        batch may now chunk even without compute context: the joint
        pipeline overlaps the dispatch wire of chunk k+1 with the
        combine wire of chunk k — two different link directions — which
        the old dispatch-only resolution could not see.)"""
        got = pctx.moe_pipeline_kwargs(64, 8, tokens_per_rank=8,
                                       token_bytes=TOKEN)
        assert got["microbatch"] == 1
