"""Contention-aware whole-program planning (ISSUE 7).

What is pinned here:

  * ``merge_ledgers``: exact per-link sums across concurrent sites, one
    merged ledger per fabric (disjoint fabrics never add), empty
    ledgers skipped, first-seen fabric order preserved;
  * ``score_phase`` / ``phase_breakdown``: the t_phase = max own score
    + shared-link excess model, zero contention on disjoint fabrics,
    background traffic only ever raises the score;
  * ``Planner._search_phase``: joint search never loses to the greedy
    per-site assignment, contention genuinely flips decisions on shared
    fabrics, beam equals the exhaustive oracle on small programs, the
    wide tpu_2x16 program trips ``auto`` into beam under the
    enumeration budget;
  * phase budgets: validation, the feasibility constraint (a budgeted
    phase rejects other phases' combinations whose background traffic
    busts its cap), and the ``budget_violated`` best-effort fallback;
  * staleness surfacing: ``Planner.plan_is_stale``,
    ``ParallelContext.bound_plan_stale`` and the one-shot
    ``ServeEngine.plan_report`` warning;
  * planner introspection: phase/search stats on
    ``ExecutionPlan.report()`` and the op="program" decision_log row.
"""

import dataclasses

import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core.topology import (get_fabric, split_tp_full_mesh,
                                 two_server_cluster)

TOKEN = lm.TOKEN_BYTES
TP, SEQ = 8, 2048


def compute_ctx(batch, top_k=8, d_model=7168, f_shard=2048):
    return lm.expert_compute_time_s(batch, top_k, d_model, f_shard)


def train_program(batch, n_params, extra=()):
    """MoE (dispatch, combine) pair + gradient sync in ONE phase — the
    canonical contended program of the flip sweep."""
    d, c = plan_ir.moe_sites("train", num_experts=64, top_k=8,
                             tokens_per_rank=batch, token_bytes=TOKEN,
                             compute_s=compute_ctx(batch))
    gs = plan_ir.grad_sync_site(
        "train", payload_bytes=n_params * 4 / TP,
        compute_s=lm.backward_compute_s(n_params, SEQ, tp=TP))
    return plan_ir.CollectiveProgram("train", (d, c, gs) + tuple(extra))


def serve_program(budget=None, *, decode_batch=64, prefill_batch=4096):
    dec = plan_ir.moe_sites("decode", num_experts=64, top_k=8,
                            tokens_per_rank=decode_batch,
                            token_bytes=TOKEN)
    pre = plan_ir.moe_sites("prefill", num_experts=64, top_k=8,
                            tokens_per_rank=prefill_batch,
                            token_bytes=TOKEN,
                            compute_s=compute_ctx(prefill_batch))
    return plan_ir.CollectiveProgram(
        "serve", (*dec, *pre),
        phase_budgets={} if budget is None else {"decode": budget})


def greedy_phase(planner, program, topo, phase="train"):
    """Independent per-site planning re-scored under the phase model:
    every group's own contention-free best."""
    groups = program.phases()[phase]
    bundles = [planner._group_candidates(g, topo, planner.hw, True)
               for g in groups]
    entries = [(b["cands"][0]["score_s"], b["cands"][0]["ledgers"])
               for b in bundles]
    return lm.score_phase(entries, planner.hw)


def demand_ledger(topo, nbytes, link=None):
    """Minimal pure-demand ledger: ``nbytes`` on one directed link."""
    link = link or next(iter(topo.links))
    return plan_ir.Ledger(topo=topo, link_bytes={link: float(nbytes)},
                          relay_bytes={}, flow_counts={link: 1})


# ---------------------------------------------------------------------------
# merge_ledgers / score_phase
# ---------------------------------------------------------------------------

class TestMergeLedgers:
    def test_merged_is_per_link_sum(self):
        topo = get_fabric("2x8")
        scen = plan_ir.default_scenarios(topo)
        ledgers = [
            plan_ir.get_plan("dispatch", "multiwrite").simulate(
                scen["dispatch"], 1 << 20),
            plan_ir.get_plan("allreduce", "ring").simulate(
                scen["allreduce"], 1 << 22),
            plan_ir.get_plan("allreduce", "hierarchical").simulate(
                scen["allreduce"], 1 << 18),
        ]
        merged = lm.merge_ledgers(ledgers)
        assert len(merged) == 1            # one fabric -> one phase ledger
        m = merged[0]
        for field in ("link_bytes", "relay_bytes", "flow_counts"):
            want: dict = {}
            for led in ledgers:
                for k, v in getattr(led, field).items():
                    want[k] = want.get(k, 0) + v
            got = getattr(m, field)
            assert set(got) == set(want)
            for k in want:
                assert got[k] == pytest.approx(want[k])

    def test_disjoint_fabrics_never_add(self):
        ep = two_server_cluster()
        tp_mesh, _ = split_tp_full_mesh(8, tp=4)
        a = demand_ledger(ep, 1 << 20)
        b = demand_ledger(tp_mesh, 1 << 24)
        merged = lm.merge_ledgers([a, b])
        assert len(merged) == 2            # per-fabric, first-seen order
        assert merged[0].topo is ep and merged[1].topo is tp_mesh
        # the phase floor is the max over fabrics, not their sum
        assert lm.phase_wire_s([a, b]) == pytest.approx(
            max(lm.ledger_wire_s(a), lm.ledger_wire_s(b)))

    def test_empty_ledgers_skipped(self):
        topo = two_server_cluster()
        a = demand_ledger(topo, 4096)
        empty = plan_ir.Ledger(topo=topo, link_bytes={}, relay_bytes={},
                               flow_counts={})
        merged = lm.merge_ledgers([empty, a, empty])
        assert len(merged) == 1
        assert merged[0].link_bytes == a.link_bytes
        assert lm.merge_ledgers([empty]) == ()

    def test_merged_ledger_is_pure_demand(self):
        """Merging strips schedule context: one stage, no overlap, no
        compute — score with ledger_wire_s, never score_ledger."""
        topo = two_server_cluster()
        led = dataclasses.replace(demand_ledger(topo, 1 << 20),
                                  stages=8, overlap=True, compute_s=1.0)
        (m,) = lm.merge_ledgers([led, demand_ledger(topo, 1 << 20)])
        assert m.stages == 1 and not m.overlap and m.compute_s == 0.0


class TestScorePhase:
    def test_disjoint_fabric_groups_zero_contention(self):
        ep = two_server_cluster()
        tp_mesh, _ = split_tp_full_mesh(8, tp=4)
        entries = [(5e-4, (demand_ledger(ep, 1 << 24),)),
                   (3e-4, (demand_ledger(tp_mesh, 1 << 24),))]
        rep = lm.phase_breakdown(entries)
        assert rep["contention_s"] == 0.0
        assert rep["score_s"] == pytest.approx(5e-4)   # slowest group

    def test_shared_link_excess_charged_on_top(self):
        topo = two_server_cluster()
        link = next(iter(topo.links))
        a = demand_ledger(topo, 1 << 26, link)
        b = demand_ledger(topo, 1 << 26, link)
        sa, sb = lm.ledger_wire_s(a), lm.ledger_wire_s(b)
        entries = [(sa, (a,)), (sb, (b,))]
        rep = lm.phase_breakdown(entries)
        # both groups on ONE link: merged wire is the sum, the excess
        # over the larger own wire is pure contention
        assert rep["phase_wire_s"] == pytest.approx(sa + sb)
        assert rep["contention_s"] == pytest.approx(min(sa, sb))
        assert rep["score_s"] == pytest.approx(
            rep["solo_s"] + rep["contention_s"])
        assert lm.score_phase(entries) == pytest.approx(rep["score_s"])

    def test_background_only_raises_the_score(self):
        topo = two_server_cluster()
        link = next(iter(topo.links))
        entries = [(1e-4, (demand_ledger(topo, 1 << 22, link),))]
        base = lm.score_phase(entries)
        bg = [demand_ledger(topo, 1 << 26, link)]
        assert lm.score_phase(entries, background=bg) > base
        # background on a foreign fabric is invisible
        other, _ = split_tp_full_mesh(8, tp=4)
        assert lm.score_phase(
            entries, background=[demand_ledger(other, 1 << 28)]
        ) == pytest.approx(base)


# ---------------------------------------------------------------------------
# phase search: joint vs greedy, beam vs oracle
# ---------------------------------------------------------------------------

class TestPhaseSearch:
    def test_contention_flips_the_grad_sync_scheme(self):
        """The tentpole behavior: planned independently, grad sync picks
        the relay-heavy multiwrite reduce; planned jointly with the MoE
        round trip contending for the same rails, the planner moves it
        off the shared bottleneck and strictly wins on the contended
        score."""
        topo = get_fabric("2x8")
        planner = pl.Planner()
        program = train_program(1024, 100_000_000)
        greedy_s = greedy_phase(planner, program, topo)
        eplan = planner.plan_program(program, topo)
        joint_s = eplan.phase_report["train"]["score_s"]
        gs = eplan.decisions["train/grad_sync"]
        assert gs.plan == "hierarchical"   # independent best: multiwrite
        assert joint_s < greedy_s

    def test_joint_never_loses_to_greedy(self):
        topo = get_fabric("tpu_2x16")
        planner = pl.Planner()
        for batch, n_params in ((64, 10**7), (1024, 10**8),
                                (4096, 12 * 10**9)):
            program = train_program(batch, n_params)
            greedy_s = greedy_phase(planner, program, topo)
            eplan = planner.plan_program(program, topo)
            assert (eplan.phase_report["train"]["score_s"]
                    <= greedy_s + 1e-12), (batch, n_params)

    def test_beam_matches_oracle_on_small_programs(self):
        program = train_program(1024, 100_000_000)
        for fname in ("mesh8", "2x8"):
            topo = get_fabric(fname)
            b = pl.Planner(search="beam").plan_program(program, topo)
            o = pl.Planner(search="exhaustive").plan_program(program, topo)
            assert (b.phase_report["train"]["score_s"]
                    == pytest.approx(o.phase_report["train"]["score_s"],
                                     rel=1e-9)), fname
            assert b.planner_stats["search"] == ["beam"]
            assert o.planner_stats["search"] == ["exhaustive"]

    def test_wide_program_trips_auto_into_beam(self):
        """The >=3-group tpu_2x16 program: the candidate product exceeds
        EXHAUSTIVE_LIMIT, auto resolves to beam, and beam enumerates
        under 10% of the product."""
        topo = get_fabric("tpu_2x16")
        program = train_program(
            2048, 12_000_000_000,
            extra=(plan_ir.allgather_site("train", frag_bytes=8 << 20),))
        eplan = pl.Planner().plan_program(program, topo)
        stats = eplan.planner_stats
        assert stats["product"] > pl.Planner.EXHAUSTIVE_LIMIT
        assert stats["search"] == ["beam"]
        assert stats["combos_scored"] < 0.10 * stats["product"]
        assert stats["combos_pruned"] == (stats["product"]
                                          - stats["combos_scored"])

    def test_zero_contention_reproduces_independent_planning(self):
        """Groups on disjoint fabrics cannot contend: the joint search
        must bind exactly what per-site planning binds (the backward-
        compatibility face of the tie-break)."""
        ep = two_server_cluster()
        tp_mesh, _ = split_tp_full_mesh(8, tp=4)
        d, c = plan_ir.moe_sites("train", num_experts=64, top_k=8,
                                 tokens_per_rank=1024, token_bytes=TOKEN,
                                 compute_s=compute_ctx(1024))
        ag = plan_ir.allgather_site("train", frag_bytes=4 << 20,
                                    topo=tp_mesh)
        planner = pl.Planner()
        eplan = planner.plan_program(
            plan_ir.CollectiveProgram("train", (d, c, ag)), ep)
        solo = planner.plan_program(
            plan_ir.CollectiveProgram("train", (d, c)), ep)
        got = eplan.decisions["train/moe_dispatch"]
        want = solo.decisions["train/moe_dispatch"]
        assert (got.plan, got.knobs) == (want.plan, want.knobs)
        direct = planner.choose("allgather", 4 << 20, tp_mesh,
                                executable_only=True, num_domains=2)
        ag_dec = eplan.decisions["train/split_tp_gather"]
        assert (ag_dec.plan, ag_dec.knobs) == (direct.plan, direct.knobs)
        assert eplan.phase_report["train"]["contention_s"] == 0.0


# ---------------------------------------------------------------------------
# _search_phase mechanics (synthetic candidates)
# ---------------------------------------------------------------------------

class TestSearchMechanics:
    def _bundle(self, cands):
        return {"cands": [{"score_s": s, "ledgers": (led,), "row": None}
                          for s, led in cands]}

    def test_contended_combo_loses_to_frugal_one(self):
        """Two groups flooding one link: the all-own-best combo pays the
        shared-link excess, a slightly slower frugal candidate wins the
        phase — and the greedy combo is provably scored too."""
        topo = two_server_cluster()
        link = next(iter(topo.links))
        big = demand_ledger(topo, 1 << 28, link)
        tiny = demand_ledger(topo, 1 << 10, link)
        wire_big = lm.ledger_wire_s(big)
        planner = pl.Planner()
        bundles = [
            self._bundle([(wire_big, big),
                          (wire_big * 1.05, tiny)]),   # frugal, 5% slower
            self._bundle([(wire_big, big)]),
        ]
        combo, stats = planner._search_phase(bundles, planner.hw)
        assert combo == (1, 0)             # not the greedy (0, 0)
        assert stats["search"] == "exhaustive"
        assert stats["combos_scored"] == 2

    def test_budget_rejects_hostile_background_combos(self):
        """An already-planned budgeted phase constrains this one: the
        own-best combo whose background traffic busts the cap is
        rejected in favor of a feasible runner-up."""
        topo = two_server_cluster()
        link = next(iter(topo.links))
        victim = [(1e-5, (demand_ledger(topo, 1 << 12, link),))]
        big = demand_ledger(topo, 1 << 28, link)
        tiny = demand_ledger(topo, 1 << 10, link)
        planner = pl.Planner()
        bundles = [self._bundle([(1e-4, big), (2e-4, tiny)])]
        cap = 1e-3                         # busts under big, holds under tiny
        assert lm.score_phase(victim, planner.hw,
                              background=[big]) > cap
        assert lm.score_phase(victim, planner.hw,
                              background=[tiny]) < cap
        combo, stats = planner._search_phase(
            bundles, planner.hw, constraints=[(victim, cap)])
        assert combo == (1,)
        assert not stats["budget_violated"]
        # nothing feasible: best-effort falls back to the own best
        combo, stats = planner._search_phase(
            bundles, planner.hw, constraints=[(victim, 1e-9)])
        assert combo == (0,)
        assert stats["budget_violated"]

    def test_own_budget_caps_the_phase_score(self):
        topo = two_server_cluster()
        link = next(iter(topo.links))
        led = demand_ledger(topo, 1 << 12, link)
        planner = pl.Planner()
        bundles = [self._bundle([(1e-4, led), (2e-4, led)])]
        combo, stats = planner._search_phase(bundles, planner.hw,
                                             budget=1.5e-4)
        assert combo == (0,) and not stats["budget_violated"]
        combo, stats = planner._search_phase(bundles, planner.hw,
                                             budget=1e-9)
        assert combo == (0,) and stats["budget_violated"]


# ---------------------------------------------------------------------------
# phase budgets end-to-end
# ---------------------------------------------------------------------------

class TestPhaseBudgets:
    def test_unknown_phase_budget_rejected(self):
        with pytest.raises(ValueError, match="unknown phase"):
            train_program(64, 10**7).__class__(
                "p", train_program(64, 10**7).sites,
                phase_budgets={"decode": 1e-3})

    def test_nonpositive_budget_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            serve_program(0.0, decode_batch=64)
        with pytest.raises(ValueError, match="positive"):
            serve_program(-1e-3)

    def test_generous_budget_is_met(self):
        topo = two_server_cluster()
        eplan = pl.Planner().plan_program(serve_program(0.5), topo)
        rep = eplan.phase_report["decode"]
        assert rep["budget_s"] == 0.5
        assert rep["budget_ok"]
        assert rep["contended_score_s"] <= 0.5
        # the contended verdict includes the OTHER phase's traffic
        assert rep["contended_score_s"] >= rep["score_s"]
        assert not eplan.planner_stats["budget_violated"]

    def test_infeasible_budget_binds_best_effort(self):
        """No prefill combination can keep a 1ms decode SLO on 2x8: the
        planner flags the violation and still binds the unconstrained
        best rather than refusing to plan."""
        topo = two_server_cluster()
        planner = pl.Planner()
        tight = planner.plan_program(serve_program(1e-3), topo)
        free = planner.plan_program(serve_program(), topo)
        rep = tight.phase_report["decode"]
        assert not rep["budget_ok"]
        assert tight.planner_stats["budget_violated"]
        for role in ("prefill/moe_dispatch", "prefill/moe_combine"):
            assert (tight.decisions[role].plan
                    == free.decisions[role].plan)

    def test_budget_changes_the_cache_key(self):
        a = serve_program().cache_key()
        b = serve_program(1e-3).cache_key()
        assert a != b
        assert serve_program(1e-3).cache_key() == b


# ---------------------------------------------------------------------------
# staleness surfacing
# ---------------------------------------------------------------------------

def _alpha_bloated(hw):
    """A recalibration that flips microbatch decisions everywhere: a
    200x operator-startup alpha makes chunking unaffordable."""
    return dataclasses.replace(hw, alpha_base=hw.alpha_base * 200)


class TestStaleness:
    def test_plan_is_stale_lifecycle(self):
        topo = two_server_cluster()
        planner = pl.Planner()
        program = train_program(1024, 100_000_000)
        e1 = planner.plan_program(program, topo)
        assert planner.plan_is_stale(e1) is False
        planner.refresh_hardware(_alpha_bloated(planner.hw))
        events = planner.replan_programs()
        ev = next(e for e in events if e["program"] == "train")
        assert ev["changed"]
        assert planner.plan_is_stale(e1) is True
        assert planner.plan_is_stale(ev["plan"]) is False

    def test_foreign_plan_is_unjudgeable(self):
        topo = two_server_cluster()
        e1 = pl.Planner().plan_program(train_program(64, 10**7), topo)
        assert pl.Planner().plan_is_stale(e1) is None
        pinned = plan_ir.pinned_execution_plan(
            serve_program(), {
                role: {"moe_scheme": "baseline",
                       "moe_combine": "baseline", "microbatch": 1}
                for role in ("decode/moe_dispatch", "decode/moe_combine",
                             "prefill/moe_dispatch",
                             "prefill/moe_combine")})
        assert pl.Planner().plan_is_stale(pinned) is None

    def test_bound_plan_stale_and_serve_warning(self, capsys):
        """The launch-surface face: a drift recalibration replans the
        bound program; ``bound_plan_stale`` flips, ``plan_report``
        carries ``stale`` and warns exactly once."""
        import jax

        from repro.core.planner import default_planner
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.context import ParallelContext
        from repro.runtime.server import ServeEngine
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = make_test_mesh(shape=(1,), axes=("model",))
        pctx = ParallelContext(mesh=mesh, pod_axis=None,
                               data_axis="model", model_axis="model",
                               plan_policy="auto",
                               fabric=two_server_cluster())
        assert pctx.bound_plan_stale() is None      # nothing bound
        d, c = pctx.moe_sites("prefill", num_experts=64, top_k=8,
                              tokens_per_rank=4096, token_bytes=TOKEN,
                              compute_s=compute_ctx(4096))
        program = plan_ir.CollectiveProgram("serve", (d, c))
        eplan = pctx.plan_collectives(program)
        pctx = pctx.bind(eplan)
        assert pctx.bound_plan_stale() is False

        class _Stub:
            prefill = staticmethod(lambda *a: None)
            decode = staticmethod(lambda *a: None)

        engine = ServeEngine(_Stub(), None, pctx=pctx)
        dp = default_planner()
        hw0 = dp.hw
        try:
            dp.refresh_hardware(_alpha_bloated(hw0))
            events = dp.replan_programs()
            assert any(e["program"] == "serve" and e["changed"]
                       for e in events)
            assert pctx.bound_plan_stale() is True
            capsys.readouterr()
            rep = engine.plan_report(4096, 1)
            assert rep["stale"] is True
            assert "stale" in capsys.readouterr().out
            rep = engine.plan_report(4096, 1)      # one-shot warning
            assert rep["stale"] is True
            assert "stale" not in capsys.readouterr().out
        finally:
            dp.refresh_hardware(hw0)
            dp.replan_programs()
        assert pctx.bound_plan_stale() is False


# ---------------------------------------------------------------------------
# introspection
# ---------------------------------------------------------------------------

class TestIntrospection:
    def test_execution_plan_report_carries_search_stats(self):
        topo = get_fabric("2x8")
        planner = pl.Planner()
        eplan = planner.plan_program(train_program(1024, 10**8), topo)
        out = eplan.report()
        assert "phases" in out and "planner" in out
        stats = out["planner"]
        for key in ("search", "phases", "candidates", "product",
                    "combos_scored", "combos_pruned", "beam_width",
                    "planning_wall_s", "budget_violated"):
            assert key in stats, key
        assert stats["planning_wall_s"] > 0
        rep = out["phases"]["train"]
        assert rep["search"]["product"] == rep["search"]["combos_scored"]
        assert rep["score_s"] == pytest.approx(
            rep["solo_s"] + rep["contention_s"])

    def test_summary_surfaces_contention(self):
        topo = get_fabric("2x8")
        eplan = pl.Planner().plan_program(train_program(1024, 10**8),
                                          topo)
        assert eplan.phase_report["train"]["contention_s"] > 0
        assert "contention" in eplan.summary()

    def test_program_decision_log_row(self):
        planner = pl.Planner()
        eplan = planner.plan_program(train_program(256, 10**7),
                                     get_fabric("2x8"))
        row = next(r for r in reversed(planner.decision_log)
                   if r["op"] == "program")
        assert row["plan"] == "train"
        assert row["planner"]["combos_scored"] >= 1
        # never mistakable for a measurable op row (fit_overlap_eff
        # filters on predicted_serial_s > 0)
        assert row["predicted_serial_s"] == 0.0
        assert row["predicted_s"] == pytest.approx(
            sum(rep["score_s"]
                for rep in eplan.phase_report.values()))
