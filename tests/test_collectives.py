"""Tests for core/collectives.py.

Multi-device equality runs in a subprocess (so the forced 8-device XLA flag
never leaks into this process); single-device logic (packing, routing) and
hypothesis property tests run inline.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import collectives as cl

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_multidevice_collectives_subprocess():
    """8-device shard_map equality suite (allgather + MoE dispatch)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests/multidev/check_collectives.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ALL OK" in proc.stdout


# ---------------------------------------------------------------------------
# pack_by_bitmap (single device)
# ---------------------------------------------------------------------------

def np_pack_oracle(tokens, bitmap, valid, num_dests, capacity):
    """Straightforward python oracle for pack_by_bitmap."""
    n, h = tokens.shape
    out = np.zeros((num_dests, capacity, h), tokens.dtype)
    idx = np.full((num_dests, capacity), -1, np.int32)
    counts = [0] * num_dests
    for row in range(n):
        if not valid[row]:
            continue
        for d in range(num_dests):
            if (int(bitmap[row]) >> d) & 1:
                if counts[d] < capacity:
                    out[d, counts[d]] = tokens[row]
                    idx[d, counts[d]] = row
                    counts[d] += 1
    return out, idx


class TestPackByBitmap:
    @pytest.mark.parametrize("n,h,d,c", [(16, 4, 3, 16), (32, 8, 8, 5),
                                         (5, 2, 31, 2), (64, 16, 16, 64)])
    def test_matches_oracle(self, n, h, d, c):
        rng = np.random.default_rng(n * 31 + d)
        tokens = rng.normal(size=(n, h)).astype(np.float32)
        bitmap = rng.integers(0, 1 << d, size=n).astype(np.int32)
        valid = rng.random(n) > 0.2
        got_t, got_i = jax.jit(cl.pack_by_bitmap, static_argnums=(3, 4))(
            jnp.asarray(tokens), jnp.asarray(bitmap), jnp.asarray(valid), d, c)
        exp_t, exp_i = np_pack_oracle(tokens, bitmap, valid, d, c)
        np.testing.assert_array_equal(np.asarray(got_i), exp_i)
        np.testing.assert_array_equal(np.asarray(got_t), exp_t)

    def test_priority_is_token_order(self):
        tokens = np.arange(10, dtype=np.float32)[:, None]
        bitmap = np.ones(10, np.int32)
        _, idx = cl.pack_by_bitmap(jnp.asarray(tokens), jnp.asarray(bitmap),
                                   jnp.ones(10, bool), 1, 4)
        np.testing.assert_array_equal(np.asarray(idx)[0], [0, 1, 2, 3])

    if HAVE_HYPOTHESIS:
        @settings(max_examples=40, deadline=None)
        @given(n=st.integers(1, 40), d=st.integers(1, 31),
               c=st.integers(1, 12), seed=st.integers(0, 2**31))
        def test_property_matches_oracle(self, n, d, c, seed):
            rng = np.random.default_rng(seed)
            tokens = rng.normal(size=(n, 3)).astype(np.float32)
            bitmap = rng.integers(0, 1 << d, size=n,
                                  dtype=np.int64).astype(np.int32)
            valid = rng.random(n) > 0.3
            got_t, got_i = cl.pack_by_bitmap(
                jnp.asarray(tokens), jnp.asarray(bitmap), jnp.asarray(valid),
                d, c)
            exp_t, exp_i = np_pack_oracle(tokens, bitmap, valid, d, c)
            np.testing.assert_array_equal(np.asarray(got_i), exp_i)
            np.testing.assert_array_equal(np.asarray(got_t), exp_t)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

class TestRouteTopK:
    def test_topk_properties(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
        gates, ids = cl.route_topk(logits, 4)
        assert gates.shape == (32, 4) and ids.shape == (32, 4)
        # normalized, positive, distinct ids, ids are true argmax set
        np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)
        assert (np.asarray(gates) > 0).all()
        for row in np.asarray(ids):
            assert len(set(row.tolist())) == 4
        top4 = np.argsort(-np.asarray(logits), axis=-1)[:, :4]
        np.testing.assert_array_equal(np.sort(np.asarray(ids), -1),
                                      np.sort(top4, -1))


# ---------------------------------------------------------------------------
# single-chip MoE path (p=1, d=1: all collectives degenerate)
# ---------------------------------------------------------------------------

class TestSingleChipDispatch:
    def test_roundtrip_identity_experts(self):
        mesh = cl.EPMesh(pod_axis=None, ep_axis="ep", num_pods=1, ep_per_pod=1)
        cfg = cl.DispatchConfig(num_experts=8, top_k=2)
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.normal(size=(32, 6)).astype(np.float32))
        logits = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
        gates, ids = cl.route_topk(logits, 2)
        exp_tok, exp_gate, state = cl.hierarchical_dispatch(
            tokens, ids, gates, cfg, mesh)
        assert exp_tok.shape[0] == 8  # all experts local
        out = cl.hierarchical_combine(exp_tok, exp_gate, state)
        # identity experts, gates sum to 1 -> out == tokens
        np.testing.assert_allclose(np.asarray(out), np.asarray(tokens),
                                   atol=1e-5)

    def test_dispatch_pod_bytes_accounting(self):
        """Analytic pod-bytes: multiwrite <= baseline, ratio ~ k_remote."""
        cfg = cl.DispatchConfig(num_experts=64, top_k=8)
        mesh = cl.EPMesh("pod", "ep", num_pods=2, ep_per_pod=16)
        rng = np.random.default_rng(5)
        ids = np.stack([rng.choice(64, 8, replace=False) for _ in range(256)])
        base, mw = cl.dispatch_pod_bytes(ids, cfg, mesh, h=128)
        assert mw < base
        assert base / mw > 2.0  # expected ~4 distinct remote ranks vs ~1 pod
