"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle.

Every kernel is swept over shapes and dtypes per the deliverable spec,
plus hypothesis property tests on the packing kernel's invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - property tests skipped
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.dispatch_pack import dispatch_pack
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_scan import mamba2_scan
from repro.kernels.rwkv6_scan import rwkv6_scan


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("bh,s,d,bq,bk", [
        (2, 128, 64, 64, 64),
        (1, 96, 32, 32, 64),     # padding on q
        (3, 130, 16, 64, 64),    # padding on q and k
        (2, 64, 128, 16, 16),
    ])
    def test_causal_matches_ref(self, bh, s, d, bq, bk, dtype):
        rng = np.random.default_rng(s + d)
        q, k, v = (jnp.asarray(rng.normal(size=(bh, s, d)), dtype)
                   for _ in range(3))
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        exp = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(exp, np.float32), **tol(dtype))

    def test_noncausal(self):
        rng = np.random.default_rng(0)
        q, k, v = (jnp.asarray(rng.normal(size=(2, 80, 32)), jnp.float32)
                   for _ in range(3))
        got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
        exp = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(1)
        q, k, v = (jnp.asarray(rng.normal(size=(2, 128, 32)), jnp.float32)
                   for _ in range(3))
        got = flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32)
        exp = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    def test_softcap(self):
        rng = np.random.default_rng(2)
        q, k, v = (jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.float32)
                   for _ in range(3))
        got = flash_attention(q, k, v, causal=True, softcap=30.0,
                              block_q=32, block_k=32)
        exp = ref.attention_ref(q, k, v, causal=True, softcap=30.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    def test_cross_attention_lengths(self):
        """enc-dec: kv length != q length."""
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(2, 40, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 72, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 72, 32)), jnp.float32)
        got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
        exp = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# mamba2
# ---------------------------------------------------------------------------

class TestMamba2:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("bh,s,dh,ds,chunk", [
        (2, 64, 32, 16, 16),
        (1, 100, 16, 8, 32),     # padding
        (3, 32, 64, 32, 32),
    ])
    def test_matches_scan_ref(self, bh, s, dh, ds, chunk, dtype):
        rng = np.random.default_rng(s * 7 + dh)
        x = jnp.asarray(rng.normal(size=(bh, s, dh)), dtype)
        dt = jnp.asarray(
            np.log1p(np.exp(rng.normal(size=(bh, s)))), jnp.float32) * 0.1
        a = jnp.asarray(-np.abs(rng.normal(size=(bh,))) - 0.1, jnp.float32)
        b = jnp.asarray(rng.normal(size=(bh, s, ds)), dtype)
        c = jnp.asarray(rng.normal(size=(bh, s, ds)), dtype)
        d = jnp.asarray(rng.normal(size=(bh,)), jnp.float32)
        got = mamba2_scan(x, dt, a, b, c, d, chunk=chunk, interpret=True)
        exp = ref.mamba2_ref(x, dt, a, b, c, d)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                                   rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_decode_step_consistent_with_scan(self):
        """Running T decode steps == the scan over T steps."""
        rng = np.random.default_rng(11)
        bh, s, dh, ds = 2, 16, 8, 4
        x = jnp.asarray(rng.normal(size=(bh, s, dh)), jnp.float32)
        dt = jnp.asarray(np.abs(rng.normal(size=(bh, s))) * 0.1 + 0.01,
                         jnp.float32)
        a = jnp.asarray([-0.5, -1.0], jnp.float32)
        b = jnp.asarray(rng.normal(size=(bh, s, ds)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(bh, s, ds)), jnp.float32)
        d = jnp.asarray(rng.normal(size=(bh,)), jnp.float32)
        exp = np.asarray(ref.mamba2_ref(x, dt, a, b, c, d))
        h = jnp.zeros((bh, ds, dh), jnp.float32)
        for t in range(s):
            h, y = ref.mamba2_decode_step(h, x[:, t], dt[:, t], a, b[:, t],
                                          c[:, t], d)
            np.testing.assert_allclose(np.asarray(y), exp[:, t],
                                       atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

class TestRWKV6:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("bh,s,dk,dv,chunk", [
        (2, 64, 16, 16, 16),
        (1, 100, 8, 32, 32),     # padding
        (3, 32, 32, 8, 8),
    ])
    def test_matches_scan_ref(self, bh, s, dk, dv, chunk, dtype):
        rng = np.random.default_rng(s * 13 + dk)
        r = jnp.asarray(rng.normal(size=(bh, s, dk)), dtype)
        k = jnp.asarray(rng.normal(size=(bh, s, dk)), dtype)
        v = jnp.asarray(rng.normal(size=(bh, s, dv)), dtype)
        logw = jnp.asarray(-np.abs(rng.normal(size=(bh, s, dk))) * 0.3 - 0.05,
                           jnp.float32)
        u = jnp.asarray(rng.normal(size=(bh, dk)), jnp.float32)
        got = rwkv6_scan(r, k, v, logw, u, chunk=chunk, interpret=True)
        exp = ref.rwkv6_ref(r, k, v, logw, u)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                                   rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_decode_step_consistent_with_scan(self):
        rng = np.random.default_rng(17)
        bh, s, dk, dv = 2, 12, 8, 8
        r = jnp.asarray(rng.normal(size=(bh, s, dk)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(bh, s, dk)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(bh, s, dv)), jnp.float32)
        logw = jnp.asarray(-np.abs(rng.normal(size=(bh, s, dk))) * 0.2 - 0.05,
                           jnp.float32)
        u = jnp.asarray(rng.normal(size=(bh, dk)), jnp.float32)
        exp = np.asarray(ref.rwkv6_ref(r, k, v, logw, u))
        S = jnp.zeros((bh, dk, dv), jnp.float32)
        for t in range(s):
            S, y = ref.rwkv6_decode_step(S, r[:, t], k[:, t], v[:, t],
                                         logw[:, t], u)
            np.testing.assert_allclose(np.asarray(y), exp[:, t],
                                       atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# dispatch pack
# ---------------------------------------------------------------------------

class TestDispatchPack:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("n,h,d,c,br", [
        (32, 16, 4, 16, 8),
        (17, 8, 8, 3, 4),        # padding + overflow
        (64, 128, 16, 64, 16),
        (8, 4, 31, 2, 8),
    ])
    def test_matches_jnp_oracle(self, n, h, d, c, br, dtype):
        rng = np.random.default_rng(n + d * 3)
        tokens = jnp.asarray(rng.normal(size=(n, h)), dtype)
        bitmap = jnp.asarray(rng.integers(0, 1 << d, size=n), jnp.int32)
        valid = jnp.asarray(rng.random(n) > 0.25)
        got_t, got_i = dispatch_pack(tokens, bitmap, valid, num_dests=d,
                                     capacity=c, block_rows=br,
                                     interpret=True)
        exp_t, exp_i = ref.pack_ref(tokens, bitmap, valid, d, c)
        np.testing.assert_array_equal(np.asarray(got_i), np.asarray(exp_i))
        np.testing.assert_array_equal(np.asarray(got_t, np.float32),
                                      np.asarray(exp_t, np.float32))

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(n=st.integers(1, 48), d=st.integers(1, 12),
               c=st.integers(1, 10),
               br=st.sampled_from([4, 8]), seed=st.integers(0, 10**6))
        def test_property_matches_oracle(self, n, d, c, br, seed):
            rng = np.random.default_rng(seed)
            tokens = jnp.asarray(rng.normal(size=(n, 4)), jnp.float32)
            bitmap = jnp.asarray(rng.integers(0, 1 << d, size=n), jnp.int32)
            valid = jnp.asarray(rng.random(n) > 0.3)
            got_t, got_i = dispatch_pack(tokens, bitmap, valid, num_dests=d,
                                         capacity=c, block_rows=br,
                                         interpret=True)
            exp_t, exp_i = ref.pack_ref(tokens, bitmap, valid, d, c)
            np.testing.assert_array_equal(np.asarray(got_i),
                                          np.asarray(exp_i))
            np.testing.assert_array_equal(np.asarray(got_t),
                                          np.asarray(exp_t))
    else:
        def test_property_matches_oracle(self):
            pytest.skip("hypothesis not installed")


# ---------------------------------------------------------------------------
# ops dispatch layer
# ---------------------------------------------------------------------------

class TestOps:
    def test_ops_pallas_vs_ref_toggle(self):
        rng = np.random.default_rng(5)
        q, k, v = (jnp.asarray(rng.normal(size=(2, 64, 32)), jnp.float32)
                   for _ in range(3))
        a = ops.flash_attention(q, k, v, use_pallas=True,
                                block_q=32, block_k=32)
        b = ops.flash_attention(q, k, v, use_pallas=False)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)
