"""Tests for the telemetry & online-calibration subsystem (ISSUE 3).

Covers:
  * CalibrationStore: JSONL round-trip, schema versioning, fabric/op
    keying, latest-record supersession.
  * SimProbe / GroundTruth: injectable degradation shows up only on the
    affected link class.
  * fit: per-link-class alpha/beta regression round-trip (fitted
    measurements reproduce injected bandwidths within tolerance),
    outlier rejection, confidence floor, and score_ledger ranking flips
    under the fitted model (the recalibrated round-trip satellite).
  * Planner: hw fingerprint in the LRU key (stale-cache regression),
    refresh_hardware invalidation, decision_log rows.
  * THE ACCEPTANCE PROPERTY: with a simulated 4x degradation of
    inter-server links, the monitor re-fits and the planner's dispatch
    decision flips from the pre-degradation choice without process
    restart.
  * Hot-expert (skewed) routing scenarios: traffic concentration,
    scenario cache keying, planner pricing.
  * ParallelContext calibration wiring.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core import schedules as sch
from repro.core.topology import full_mesh, two_server_cluster
from repro.telemetry import (CalibrationStore, DriftMonitor, GroundTruth,
                             SimProbe, calibrated_hw, fit_link_classes,
                             fit_measurements, probe_sweep, topo_key)

TOPO = two_server_cluster()


def healthy_records(noise=0.0, seed=0, hw=lm.DEFAULT):
    return probe_sweep(TOPO, SimProbe(GroundTruth(noise=noise, seed=seed)),
                       hw=hw)


def degraded_records(factor=4.0, noise=0.0, seed=0, hw=lm.DEFAULT):
    truth = GroundTruth(noise=noise, seed=seed).degraded(TOPO, factor)
    return probe_sweep(TOPO, SimProbe(truth), hw=hw)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

class TestCalibrationStore:
    def test_jsonl_round_trip(self, tmp_path):
        path = str(tmp_path / "cal.jsonl")
        store = CalibrationStore(path)
        recs = healthy_records()
        store.extend(recs)
        # fresh instance reads the same records back from disk
        again = CalibrationStore(path)
        assert len(again) == len(recs)
        assert again.records(op="dispatch")
        assert all(r["schema"] == 1 for r in again.records())

    def test_records_filtered_by_fabric_and_op(self):
        store = CalibrationStore(":memory:")
        store.extend(healthy_records())
        other = full_mesh(8)
        store.extend(probe_sweep(other, SimProbe(GroundTruth()),
                                 ops=("allgather",)))
        assert store.records(fabric=topo_key(other), op="dispatch") == []
        mine = store.records(fabric=topo_key(TOPO))
        assert mine and all(r["fabric"] == topo_key(TOPO) for r in mine)
        assert set(store.fabrics()) == {topo_key(TOPO), topo_key(other)}

    def test_latest_record_supersedes(self):
        """A re-probed (op, plan, bucket) replaces its older measurement
        in the fitter's view — degradations don't average against the
        healthy history."""
        store = CalibrationStore(":memory:")
        store.extend(healthy_records())
        store.extend(degraded_records())
        latest = store.latest_by_key(fabric=topo_key(TOPO))
        assert len(latest) < len(store)          # dedup happened
        some = next(r for r in latest.values()
                    if r["op"] == "dispatch" and r["plan"] == "unicast")
        healthy = next(r for r in healthy_records()
                       if r["op"] == "dispatch" and r["plan"] == "unicast"
                       and r["bucket"] == some["bucket"])
        assert some["measured_s"] > 2 * healthy["measured_s"]

    def test_newer_schema_skipped_on_read(self, tmp_path):
        path = str(tmp_path / "cal.jsonl")
        store = CalibrationStore(path)
        store.append(healthy_records()[0])
        with open(path, "a") as f:
            fut = dict(healthy_records()[1], schema=99)
            f.write(json.dumps(fut) + "\n")
            f.write("{torn line\n")
        again = CalibrationStore(path)
        assert len(again) == 1                   # v99 + torn line skipped

    def test_missing_fields_rejected(self):
        with pytest.raises(ValueError, match="missing"):
            CalibrationStore(":memory:").append({"op": "dispatch"})


# ---------------------------------------------------------------------------
# probe
# ---------------------------------------------------------------------------

class TestSimProbe:
    def test_measured_matches_predicted_when_truth_is_model(self):
        """Noise-free truth == calibration -> measured == predicted."""
        for r in healthy_records():
            assert r["measured_s"] == pytest.approx(r["predicted_s"],
                                                    rel=1e-9)

    def test_degradation_hits_only_inter_class(self):
        base = {(r["op"], r["plan"], r["bucket"]): r
                for r in healthy_records()}
        for r in degraded_records(4.0):
            ref = base[(r["op"], r["plan"], r["bucket"])]
            ratio = r["measured_s"] / ref["measured_s"]
            if r["bottleneck_class"] == "inter":
                # baseline plans are rail-serialization-dominated and
                # must slow near-proportionally; multiwrite plans keep
                # their (unaffected) relay-engine terms, so they slow
                # less — but every rail-crossing plan must slow SOME
                floor = 1.5 if r["plan"] in ("unicast", "baseline") else 1.05
                assert ratio > floor, (r["op"], r["plan"], ratio)
            elif r["class_bytes"]["inter"] == 0:
                assert ratio == pytest.approx(1.0, rel=1e-6)

    def test_records_carry_fit_regressors(self):
        for r in healthy_records():
            assert r["bottleneck_class"] in ("intra", "inter")
            assert r["class_bytes"][r["bottleneck_class"]] > 0
            assert r["bucket"] == pl.bucket_payload(r["payload_bytes"])

    def test_noise_is_lognormal_jitter(self):
        a = healthy_records(noise=0.05, seed=3)
        b = healthy_records(noise=0.0)
        ratios = [x["measured_s"] / y["measured_s"] for x, y in zip(a, b)]
        assert any(abs(r - 1) > 0.01 for r in ratios)
        assert all(0.5 < r < 2.0 for r in ratios)


# ---------------------------------------------------------------------------
# fit (recalibrated round-trip satellite)
# ---------------------------------------------------------------------------

class TestFit:
    def test_round_trip_recovers_injected_bandwidths(self):
        """Fitted measurements from a synthetic sweep reproduce the
        injected per-class bandwidths within tolerance."""
        fits = fit_link_classes(healthy_records())
        assert fits["intra"].trusted and fits["inter"].trusted
        assert fits["intra"].bw == pytest.approx(56e9, rel=0.10)
        assert fits["inter"].bw == pytest.approx(25e9, rel=0.10)
        # degrade 4x: the fit must follow the truth, not the datasheet
        fits4 = fit_link_classes(degraded_records(4.0))
        assert fits4["inter"].bw == pytest.approx(25e9 / 4, rel=0.15)
        assert fits4["intra"].bw == pytest.approx(56e9, rel=0.10)

    def test_measurements_feed_recalibrated(self):
        meas, fits = fit_measurements(degraded_records(4.0), TOPO)
        hw = lm.DEFAULT.recalibrated(meas, TOPO)
        inter = [bw for (a, b), bw in hw.measured_link_bw().items()
                 if TOPO.server_of(a) != TOPO.server_of(b)]
        assert inter and all(
            bw == pytest.approx(25e9 / 4, rel=0.15) for bw in inter)
        # alpha_base pinned by the relay-free allgather-baseline sweep
        assert meas["alpha_base"] == pytest.approx(20e-6, rel=0.25)

    def test_score_ledger_rankings_flip_under_fit(self):
        """score_ledger rankings must flip accordingly: at batch 64 the
        unicast dispatch ledger wins nominally but loses under the
        fitted 4x-degraded model."""
        meas, _ = fit_measurements(degraded_records(4.0), TOPO)
        hw_fit = lm.DEFAULT.recalibrated(meas, TOPO)
        scn = plan_ir.DispatchScenario(topo=TOPO)
        payload = 64 * lm.TOKEN_BYTES
        uni = plan_ir.get_plan("dispatch", "unicast").simulate(scn, payload)
        mw = plan_ir.get_plan("dispatch", "multiwrite").simulate(
            scn, payload)
        assert lm.score_ledger(uni) < lm.score_ledger(mw)
        assert lm.score_ledger(uni, hw_fit) > lm.score_ledger(mw, hw_fit)

    def test_outlier_rejection(self):
        recs = healthy_records()
        for r in recs:
            if r["op"] == "dispatch" and r["plan"] == "unicast":
                r["measured_s"] *= 10.0      # one corrupted sweep point
                break
        fits = fit_link_classes(recs)
        assert fits["inter"].trusted
        assert fits["inter"].n_rejected >= 1
        assert fits["inter"].bw == pytest.approx(25e9, rel=0.15)

    def test_confidence_floor_short_sweep(self):
        """Two payload points cannot pin a line: untrusted, and
        fit_measurements emits nothing for that class."""
        recs = [r for r in healthy_records()
                if r["op"] != "allgather"][:2]
        fits = fit_link_classes(recs)
        assert not any(f.trusted for f in fits.values())
        meas, _ = fit_measurements(recs, TOPO)
        assert meas == {}

    def test_confidence_floor_noisy_sweep(self):
        recs = healthy_records(noise=0.8, seed=7)
        fits = fit_link_classes(recs)
        untrusted = [f for f in fits.values() if not f.trusted]
        assert untrusted and all(f.reason for f in untrusted)

    def test_only_baseline_plans_feed_the_regression(self):
        """Multiwrite records carry their own payload-linear relay terms;
        the fitter must regress baselines only."""
        recs = [r for r in healthy_records()
                if r["plan"] in ("multiwrite", "multiwrite_paired")]
        fits = fit_link_classes(recs)
        assert not fits        # nothing to regress: all filtered out

    def test_calibrated_hw_store_surface(self):
        store = CalibrationStore(":memory:")
        assert calibrated_hw(store, TOPO) is lm.DEFAULT   # empty store
        store.extend(degraded_records(4.0))
        hw = calibrated_hw(store, TOPO)
        assert hw.link_bw
        # memoized per (store instance + revision, fabric): same object
        assert calibrated_hw(store, TOPO) is hw

    def test_calibrated_hw_distinct_memory_stores_never_alias(self):
        """Regression: two ':memory:' stores with identical record
        counts must not share memoization entries — the degraded store
        must NOT get the healthy store's cached fit."""
        s_healthy = CalibrationStore(":memory:")
        s_healthy.extend(healthy_records())
        s_degraded = CalibrationStore(":memory:")
        s_degraded.extend(degraded_records(4.0))
        assert len(s_healthy) == len(s_degraded)
        hw_h = calibrated_hw(s_healthy, TOPO)
        hw_d = calibrated_hw(s_degraded, TOPO)
        assert hw_h != hw_d
        rail = next(k for k, ln in TOPO.links.items()
                    if TOPO.server_of(ln.src) != TOPO.server_of(ln.dst))
        assert hw_d.measured_link_bw()[rail] == pytest.approx(25e9 / 4,
                                                              rel=0.15)


# ---------------------------------------------------------------------------
# planner: stale-cache regression + decision log
# ---------------------------------------------------------------------------

class TestPlannerRecalibration:
    def test_in_place_hw_swap_never_serves_stale_decisions(self):
        """Regression (stale-cache hazard): the LRU key carries the hw
        FINGERPRINT, so swapping planner.hw in place — without any
        explicit cache_clear — must re-sweep, not serve the decision
        scored under the old calibration."""
        planner = pl.Planner()
        payload = 64 * lm.TOKEN_BYTES
        d1 = planner.choose("dispatch", payload, TOPO,
                            token_bytes=lm.TOKEN_BYTES)
        assert d1.plan == "unicast"
        links = {k: ln.bw / 4 for k, ln in TOPO.links.items()
                 if TOPO.server_of(ln.src) != TOPO.server_of(ln.dst)}
        planner.hw = planner.hw.recalibrated({"links": links}, TOPO)
        d2 = planner.choose("dispatch", payload, TOPO,
                            token_bytes=lm.TOKEN_BYTES)
        assert d2.plan == "multiwrite"
        assert planner.cache_info()["misses"] == 2

    def test_value_equal_hw_share_cache_entries(self):
        planner = pl.Planner()
        d1 = planner.choose("allgather", 1 << 20, TOPO)
        clone = dataclasses.replace(lm.DEFAULT)
        d2 = planner.choose("allgather", 1 << 20, TOPO, hw=clone)
        assert d2 is d1
        assert planner.cache_info()["hits"] == 1

    def test_refresh_hardware_invalidates_and_counts(self):
        planner = pl.Planner()
        planner.choose("allgather", 1 << 20, TOPO)
        assert planner.cache_info()["size"] == 1
        planner.refresh_hardware(lm.IDEAL)
        assert planner.cache_info()["size"] == 0
        assert planner.recalibrations == 1
        assert planner.hw is lm.IDEAL

    def test_decision_log_rows_and_measurement_fill(self):
        planner = pl.Planner()
        d = planner.choose("dispatch", 64 * lm.TOKEN_BYTES, TOPO,
                           token_bytes=lm.TOKEN_BYTES)
        row = planner.decision_log[-1]
        assert row["plan"] == d.plan
        assert row["predicted_s"] == d.predicted_s
        assert row["measured_s"] is None
        planner.note_measurement(d, 123e-6)
        assert planner.decision_log[-1]["measured_s"] == 123e-6
        # cache hit adds no new row; a second measurement appends one
        planner.choose("dispatch", 64 * lm.TOKEN_BYTES, TOPO,
                       token_bytes=lm.TOKEN_BYTES)
        n = len(planner.decision_log)
        planner.note_measurement(d, 125e-6)
        assert len(planner.decision_log) == n + 1


# ---------------------------------------------------------------------------
# the closed loop (ACCEPTANCE)
# ---------------------------------------------------------------------------

class TestClosedLoop:
    def test_4x_degradation_flips_dispatch_without_restart(self):
        """ISSUE 3 acceptance: simulate a 4x degradation of inter-server
        links; the monitor must detect drift, re-fit, recalibrate the
        planner and flip its dispatch decision in-process."""
        planner = pl.Planner()
        store = CalibrationStore(":memory:")
        monitor = DriftMonitor(planner, store, TOPO, threshold=0.25)
        payload = 64 * lm.TOKEN_BYTES

        # healthy fabric: probes agree with the model, nothing trips
        assert monitor.run_cycle(SimProbe(GroundTruth(noise=0.01))) is None
        assert monitor.drift() < 0.1
        d_pre = planner.choose("dispatch", payload, TOPO,
                               token_bytes=lm.TOKEN_BYTES)
        assert d_pre.plan == "unicast"

        # rails silently degrade 4x (only measured times change)
        truth = GroundTruth(noise=0.01, seed=1).degraded(TOPO, 4.0)
        event = None
        for _ in range(3):
            event = monitor.run_cycle(SimProbe(truth))
            if event:
                break
        assert event is not None, "monitor never tripped"
        assert event["drift"] > monitor.threshold
        assert event["fits"]["inter"]["trusted"]
        assert event["fits"]["inter"]["bw_gbps"] == pytest.approx(
            25 / 4, rel=0.2)

        # same planner object, no restart, no manual cache_clear:
        d_post = planner.choose("dispatch", payload, TOPO,
                                token_bytes=lm.TOKEN_BYTES)
        assert d_post.plan == "multiwrite"
        assert planner.recalibrations >= 1
        # the emergent flip batch moved down accordingly
        assert pl.emergent_flip_batch("dispatch", TOPO,
                                      planner=planner) < 128

    def test_no_drift_no_recalibration(self):
        planner = pl.Planner()
        monitor = DriftMonitor(planner, CalibrationStore(":memory:"),
                               TOPO, threshold=0.25)
        for _ in range(2):
            assert monitor.run_cycle(SimProbe(GroundTruth())) is None
        assert planner.recalibrations == 0
        assert monitor.report()["recalibrations"] == 0

    def test_recovery_recalibrates_back(self):
        """Degrade, recalibrate, then heal: the monitor must walk the
        model back toward nominal (drift is symmetric)."""
        planner = pl.Planner()
        store = CalibrationStore(":memory:")
        monitor = DriftMonitor(planner, store, TOPO, threshold=0.25)
        truth_bad = GroundTruth(seed=1).degraded(TOPO, 4.0)
        for _ in range(2):
            if monitor.run_cycle(SimProbe(truth_bad)):
                break
        assert planner.recalibrations == 1
        # fabric heals: measured times shrink back, model now over-prices
        event = None
        for _ in range(3):
            event = monitor.run_cycle(SimProbe(GroundTruth()))
            if event:
                break
        assert event is not None
        assert event["fits"]["inter"]["bw_gbps"] == pytest.approx(25,
                                                                  rel=0.15)
        d = planner.choose("dispatch", 64 * lm.TOKEN_BYTES, TOPO,
                           token_bytes=lm.TOKEN_BYTES)
        assert d.plan == "unicast"

    def test_monitor_report_shape(self):
        planner = pl.Planner()
        monitor = DriftMonitor(planner, CalibrationStore(":memory:"), TOPO)
        monitor.run_cycle(SimProbe(GroundTruth()))
        rep = monitor.report()
        assert {"drift_pct", "observations", "recalibrations",
                "last_recalibration", "store_records"} <= set(rep)
        assert rep["observations"] > 0

    def test_monitor_fills_planner_decision_log(self):
        """The probe cycle closes the planner's audit trail: a logged
        decision whose plan the probe timed at the same payload bucket
        gets its measured_s filled."""
        planner = pl.Planner()
        d = planner.choose("dispatch", 512 * lm.TOKEN_BYTES, TOPO,
                           token_bytes=lm.TOKEN_BYTES)
        assert planner.decision_log[-1]["measured_s"] is None
        monitor = DriftMonitor(planner, CalibrationStore(":memory:"), TOPO)
        monitor.run_cycle(SimProbe(GroundTruth()))
        row = next(r for r in planner.decision_log
                   if r["plan"] == d.plan
                   and r["payload_bytes"] == d.payload_bytes)
        assert row["measured_s"] is not None and row["measured_s"] > 0


# ---------------------------------------------------------------------------
# hot-expert (skewed) routing scenarios
# ---------------------------------------------------------------------------

class TestPerRoleFits:
    """Per-link (directed ROLE) fits — the asymmetric-fabric debt item:
    ``2x8asym`` must no longer collapse both rail directions to one
    "inter" bandwidth."""

    def test_asym_directions_fit_separately(self):
        from repro.core.topology import get_fabric
        from repro.telemetry import link_role
        topo = get_fabric("2x8asym")        # return rails at half bw
        records = probe_sweep(topo, SimProbe(GroundTruth(noise=0.005)))
        meas, fits = fit_measurements(records, topo)
        links = meas["links"]
        rev = {bw for (a, b), bw in links.items()
               if link_role(topo, a, b) == "inter:1>0"}
        # the degraded (bottleneck) direction is identified near its
        # true 12.5 GB/s ...
        assert rev, f"no reverse-rail fits in {sorted(fits)}"
        for bw in rev:
            assert bw == pytest.approx(12.5e9, rel=0.1)
        # ... and the forward rails do NOT inherit the slow line: the
        # end-to-end times carry no evidence about the direction that
        # never bottlenecks, so it keeps the nominal 25 GB/s (no
        # override) instead of being mislabeled at ~12.5
        fwd = [k for k in topo.links
               if link_role(topo, *k) == "inter:0>1"]
        assert fwd and all(k not in links for k in fwd)

    def test_symmetric_fabric_fits_both_directions(self):
        from repro.telemetry import link_role
        records = healthy_records(noise=0.005)
        meas, fits = fit_measurements(records, TOPO)
        by_role = {}
        for (a, b), bw in meas["links"].items():
            by_role.setdefault(link_role(TOPO, a, b), []).append(bw)
        for role in ("inter:0>1", "inter:1>0"):
            assert role in by_role, sorted(by_role)
            for bw in by_role[role]:
                assert bw == pytest.approx(25e9, rel=0.1)

    def test_role_records_and_fit_surface(self):
        from repro.telemetry import fit_link_roles, ledger_role_bytes
        records = healthy_records()
        for r in records:
            assert "bottleneck_role" in r and "role_bytes" in r
        role_fits = fit_link_roles(records)
        assert any(f.trusted for f in role_fits.values())
        # ledger role bytes refine class bytes: the inter class max is
        # the max over the inter roles
        scenario = plan_ir.DispatchScenario(topo=TOPO)
        led = plan_ir.get_plan("dispatch", "unicast").simulate(
            scenario, 512 * lm.TOKEN_BYTES)
        roles = ledger_role_bytes(led)
        inter_roles = {k: v for k, v in roles.items() if k != "intra"}
        assert inter_roles
        from repro.telemetry import ledger_class_bytes
        assert max(inter_roles.values()) == \
            ledger_class_bytes(led)["inter"]

    def test_uniform_class_degradation_overrides_all_links(self):
        """On a nominally-UNIFORM fabric the class fit still generalizes
        to every link — a 4x inter degradation on 4x8 must override all
        96 inter links even though only a couple of directed roles ever
        set the bottleneck (the closed-loop property must not regress on
        >2-server fabrics)."""
        from repro.core.topology import get_fabric
        from repro.telemetry import link_class
        topo = get_fabric("4x8")
        truth = GroundTruth(noise=0.005).degraded(topo, 4.0)
        records = probe_sweep(topo, SimProbe(truth))
        meas, _ = fit_measurements(records, topo)
        inter = [k for k in topo.links if link_class(topo, *k) == "inter"]
        assert all(k in meas["links"] for k in inter)
        for k in inter:
            assert meas["links"][k] == pytest.approx(25e9 / 4, rel=0.1)

    def test_old_schema_records_fall_back_to_class(self):
        """Records without role fields (pre-role stores) still fit at
        the class level and override every link of the class."""
        records = healthy_records()
        for r in records:
            r.pop("bottleneck_role", None)
            r.pop("role_bytes", None)
        meas, _ = fit_measurements(records, TOPO)
        inter = [k for k in TOPO.links
                 if TOPO.server_of(k[0]) != TOPO.server_of(k[1])]
        assert all(k in meas["links"] for k in inter)


class TestSkewedRouting:
    def test_skew_concentrates_expert_traffic(self):
        flat = sch.make_routing(64, 16, 64, 8, seed=0)
        hot = sch.make_routing(64, 16, 64, 8, seed=0, skew=2.0)

        def npu_load(routing):
            loads = np.zeros(16)
            for dests in routing.token_dests:
                for d in dests:
                    loads[d] += 1
            return loads

        lf, lh = npu_load(flat), npu_load(hot)
        assert lh.max() / lh.mean() > 2 * lf.max() / lf.mean()
        assert int(np.argmax(lh)) == 0     # hot experts live on NPU 0

    def test_scenario_cache_key_includes_skew(self):
        s0 = plan_ir.DispatchScenario(topo=TOPO)
        s1 = plan_ir.DispatchScenario(topo=TOPO, skew=1.5)
        assert s0.cache_key() != s1.cache_key()
        c0 = plan_ir.CombineScenario(topo=TOPO)
        c1 = plan_ir.CombineScenario(topo=TOPO, skew=1.5)
        assert c0.cache_key() != c1.cache_key()

    def test_planner_prices_skew_separately(self):
        """Skewed routing simulates a different ledger (hot rail), so the
        planner must cache and price it separately from balanced."""
        planner = pl.Planner()
        payload = 256 * lm.TOKEN_BYTES
        d_flat = planner.choose("dispatch", payload, TOPO,
                                token_bytes=lm.TOKEN_BYTES)
        d_hot = planner.choose("dispatch", payload, TOPO,
                               token_bytes=lm.TOKEN_BYTES, skew=2.0)
        assert planner.cache_info()["misses"] == 2    # distinct keys
        assert d_hot.predicted_s != d_flat.predicted_s

    def test_skewed_unicast_ledger_has_hotter_rail(self):
        """Hot experts concentrate the unicast dispatch's redundant
        copies onto the hot NPUs' rails: the max/mean inter-link ratio
        must grow with skew."""
        scn_f = plan_ir.DispatchScenario(topo=TOPO)
        scn_h = plan_ir.DispatchScenario(topo=TOPO, skew=2.0)
        plan = plan_ir.get_plan("dispatch", "unicast")
        payload = 512 * lm.TOKEN_BYTES

        def rail_imbalance(ledger):
            rails = [v for (a, b), v in ledger.link_bytes.items()
                     if TOPO.server_of(a) != TOPO.server_of(b)]
            return max(rails) / (sum(rails) / len(rails))

        imb_f = rail_imbalance(plan.simulate(scn_f, payload))
        imb_h = rail_imbalance(plan.simulate(scn_h, payload))
        assert imb_h > 1.5 * imb_f

    def test_moe_decision_helper_accepts_skew(self):
        d = pl.moe_dispatch_decision(
            num_pods=2, ep_per_pod=8, num_experts=64, top_k=8,
            tokens_per_rank=2048, token_bytes=7168, skew=1.0)
        assert d.op == "dispatch"


# ---------------------------------------------------------------------------
# context wiring
# ---------------------------------------------------------------------------

class TestContextCalibration:
    @pytest.fixture()
    def pctx(self):
        import jax

        from repro.launch.mesh import make_test_mesh
        from repro.parallel.context import ParallelContext
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = make_test_mesh(shape=(1,), axes=("model",))
        return ParallelContext(mesh=mesh, pod_axis=None, data_axis="model",
                               model_axis="model", plan_policy="auto",
                               fabric=TOPO)

    def test_calibration_store_changes_resolved_scheme(self, pctx):
        """A calibration store holding 4x-degraded measurements must flip
        the trace-time dispatch resolution for the same workload."""
        base = pctx.moe_pipeline_kwargs(64, 8, tokens_per_rank=64,
                                        token_bytes=lm.TOKEN_BYTES)
        assert base["moe_scheme"] == "baseline"  # batch 64 nominal: unicast
        store = CalibrationStore(":memory:")
        store.extend(degraded_records(4.0))
        cal = dataclasses.replace(pctx, calibration=store)
        got = cal.moe_pipeline_kwargs(64, 8, tokens_per_rank=64,
                                      token_bytes=lm.TOKEN_BYTES)
        assert got["moe_scheme"] == "hierarchical"
        # the combine half resolves under the same fitted model, jointly
        assert got["moe_combine"] == "hierarchical"

    def _site_decision(self, pctx, tokens_per_rank):
        from repro.core import plan as plan_ir
        sites = pctx.moe_sites("t", num_experts=64, top_k=8,
                               tokens_per_rank=tokens_per_rank,
                               token_bytes=lm.TOKEN_BYTES)
        eplan = pctx.plan_collectives(plan_ir.CollectiveProgram("t", sites))
        return eplan.decision("t/moe_dispatch")

    def test_moe_skew_threads_to_planner(self, pctx):
        hot = dataclasses.replace(pctx, moe_skew=2.0)
        d_flat = self._site_decision(pctx, 256)
        d_hot = self._site_decision(hot, 256)
        assert d_hot.predicted_s != d_flat.predicted_s
