"""Dry-run machinery smoke test: one small cell end-to-end in a
subprocess (forced 512-device CPU mesh, lower+compile+analyze) — proves
the deliverable pipeline under pytest without re-running the full sweep.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import json
from repro.launch.dryrun import run_cell
r = run_cell("qwen2_vl_2b", "decode_32k", multi_pod=False, verbose=False)
assert "error" not in r, r.get("traceback", r)
rl = r["roofline"]
assert rl["memory_term_s"] > 0 and rl["dominant"] in (
    "compute", "memory", "collective")
assert r["collectives"]["num_ops"] >= 0
assert r["memory"]["temp_bytes"] is not None
print("DRYRUN_SMOKE_OK", json.dumps(rl["dominant"]))
"""


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DRYRUN_SMOKE_OK" in proc.stdout
