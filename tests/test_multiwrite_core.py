"""Semantic tests for the MultiWrite core: topology, bitmap, simulator.

Covers the paper's §4.3.4 properties: per-destination atomicity,
exactly-once delivery, statelessness (all routing info in packet metadata),
and the §4.1 forwarding-table reuse + metadata rewrite behaviour.
"""

import numpy as np
import pytest

from repro.core import bitmap as bm
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import (
    Link, Topology, full_mesh, same_index_peer, split_tp_full_mesh,
    two_server_cluster, tpu_pods,
)


# ---------------------------------------------------------------------------
# Topology / forwarding table
# ---------------------------------------------------------------------------

class TestTopology:
    def test_full_mesh_direct_routes(self):
        topo = full_mesh(8)
        for a in range(8):
            for b in range(8):
                if a != b:
                    assert topo.next_hop(a, b) == b
                    assert topo.path(a, b) == [a, b]

    def test_two_server_rail_first_routing(self):
        topo = two_server_cluster()
        # cross-server destinations route via the same-index rail peer
        # (peer index derived from the topology's fabric metadata, not a
        # hard-coded npus_per_server=8)
        for i in range(8):
            for j in range(8):
                assert topo.next_hop(i, 8 + j) == same_index_peer(topo, i, 1)
                assert topo.next_hop(8 + i, j) == same_index_peer(topo,
                                                                  8 + i, 0)
        # intra-server stays direct
        assert topo.next_hop(0, 3) == 3
        assert topo.path(0, 8 + 3) == [0, 8, 11]

    def test_metadata_derived_grouping_non8_fabric(self):
        """server_of / same_index_peer derive from ClusterMeta: a 3x4
        fabric groups rails correctly (the old free functions silently
        assumed npus_per_server=8)."""
        from repro.core.topology import ClusterSpec, server_of
        topo = ClusterSpec(num_servers=3, npus_per_server=4).build()
        assert topo.num_nodes == 12
        assert server_of(topo, 7) == 1
        assert same_index_peer(topo, 7, 2) == 11
        for i in range(4):
            for j in range(4):
                assert topo.next_hop(i, 8 + j) == same_index_peer(topo, i, 2)
        assert topo.partition_by_next_hop(0, [5, 6, 7]) == {4: [5, 6, 7]}

    def test_partition_by_next_hop_groups_remote_server(self):
        """§4.3.3 rule 3 over the rail-first table: ALL destinations on a
        remote server share one next hop -> one packet copy on the rail."""
        topo = two_server_cluster()
        groups = topo.partition_by_next_hop(0, [1, 2, 9, 12, 15])
        assert groups == {1: [1], 2: [2], 8: [9, 12, 15]}

    def test_partition_includes_self_delivery(self):
        topo = full_mesh(4)
        groups = topo.partition_by_next_hop(0, [0, 1, 2])
        assert groups == {0: [0], 1: [1], 2: [2]}

    def test_no_route_raises(self):
        topo = Topology(3, [Link(0, 1, 1e9)], name="line")
        with pytest.raises(ValueError):
            topo.next_hop(1, 0)

    def test_multi_hop_path(self):
        topo = Topology(3, [Link(0, 1, 1e9), Link(1, 2, 1e9)], name="line")
        assert topo.path(0, 2) == [0, 1, 2]

    def test_bandwidth_weighted_shortest_path(self):
        # 0->2 direct at 1 GB/s vs 0->1->2 at 100 GB/s each: cost 1/1e9 vs
        # 2/100e9 -> via 1 wins.
        topo = Topology(3, [Link(0, 2, 1e9), Link(0, 1, 100e9),
                            Link(1, 2, 100e9)], name="tri")
        assert topo.next_hop(0, 2) == 1

    def test_tpu_pods_shape(self):
        topo = tpu_pods(chips_per_pod=16, num_pods=2)
        assert topo.num_nodes == 32
        assert topo.next_hop(3, 16 + 9) == 16 + 3  # rail peer


# ---------------------------------------------------------------------------
# Bitmap metadata (§4.1)
# ---------------------------------------------------------------------------

class TestBitmap:
    def test_roundtrip(self):
        dests = [0, 3, 17, 63]
        code = bm.encode(dests, 64)
        assert bm.decode(code, 64) == dests
        assert bm.popcount(code) == 4

    def test_out_of_range(self):
        with pytest.raises(ValueError):
            bm.encode([64], 64)

    def test_metadata_bytes(self):
        assert bm.metadata_bytes(64) == 0          # rides in immediate field
        assert bm.metadata_bytes(128) == 16
        assert bm.metadata_bytes(1024) == 128      # §6.4: 3.13% of 4 KiB
        assert bm.metadata_bytes(1024) / 4096 == pytest.approx(0.03125)

    def test_jnp_encode_decode_roundtrip(self):
        rng = np.random.default_rng(0)
        for num_ranks in (8, 32, 64, 100, 256):
            oh = rng.integers(0, 2, size=(5, num_ranks)).astype(bool)
            words = bm.encode_onehot(oh, num_ranks)
            assert words.shape == (5, bm.num_words(num_ranks))
            back = np.asarray(bm.decode_onehot(words, num_ranks))
            np.testing.assert_array_equal(back, oh)
            np.testing.assert_array_equal(
                np.asarray(bm.popcount_words(words)), oh.sum(-1))

    def test_jnp_matches_numpy_oracle(self):
        rng = np.random.default_rng(1)
        oh = rng.integers(0, 2, size=(7, 70)).astype(bool)
        np.testing.assert_array_equal(
            np.asarray(bm.encode_onehot(oh, 70)), bm.np_encode_rows(oh, 70))

    def test_mask_range_rewrite(self):
        """Relay metadata rewrite (§4.1): keep only the forwarded subset."""
        oh = np.zeros((1, 64), bool)
        oh[0, [2, 20, 40, 60]] = True
        words = bm.encode_onehot(oh, 64)
        masked = bm.mask_range(words, 16, 48, 64)
        back = np.asarray(bm.decode_onehot(masked, 64))[0]
        assert list(np.nonzero(back)[0]) == [20, 40]


# ---------------------------------------------------------------------------
# MultiWrite simulator semantics (§4.3)
# ---------------------------------------------------------------------------

class TestMultiWriteSemantics:
    def test_degenerates_to_write(self):
        """|M| == 1 -> identical ledger to a standard write (§4.3.3 rule 2)."""
        topo = full_mesh(4)
        a, b = MultiWriteSimulator(topo), MultiWriteSimulator(topo)
        data = np.arange(100, dtype=np.uint8)
        a.write(0, 2, "buf", data)
        b.multiwrite(0, {2: "buf"}, data)
        assert a.link_bytes == b.link_bytes
        np.testing.assert_array_equal(a.memory[2]["buf"], b.memory[2]["buf"])

    def test_atomic_delivery_all_destinations(self):
        topo = full_mesh(8)
        sim = MultiWriteSimulator(topo)
        data = np.arange(64, dtype=np.uint8)
        sim.multiwrite(0, {d: "x" for d in [1, 3, 5, 7]}, data)
        for d in [1, 3, 5, 7]:
            np.testing.assert_array_equal(sim.memory[d]["x"], data)
            assert sim.delivery_count[(d, "x")] == 1  # exactly once

    def test_single_copy_on_bottleneck(self):
        """The paper's central property: ONE copy of the payload crosses
        the rail regardless of destination count (§3.2)."""
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        data = np.arange(1000, dtype=np.uint8)
        sim.multiwrite(0, {d: "x" for d in [9, 10, 12, 15]}, data)
        assert sim.link_bytes[(0, 8)] == 1000          # one rail crossing
        assert sim.redundant_bytes()[(0, 8)] == 0
        for d in [9, 10, 12, 15]:
            np.testing.assert_array_equal(sim.memory[d]["x"], data)
        # relay 8 is not a destination: nothing delivered there
        assert (8, "x") not in sim.delivery_count

    def test_unicast_equivalent_is_redundant(self):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        data = np.arange(1000, dtype=np.uint8)
        for d in [9, 10, 12, 15]:
            sim.write(0, d, "x", data)
        assert sim.link_bytes[(0, 8)] == 4000          # 4 redundant copies
        assert sim.redundant_bytes()[(0, 8)] == 3000

    def test_relay_delivery_when_relay_is_destination(self):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        data = np.arange(10, dtype=np.uint8)
        sim.multiwrite(0, {8: "x", 11: "x"}, data)
        np.testing.assert_array_equal(sim.memory[8]["x"], data)
        np.testing.assert_array_equal(sim.memory[11]["x"], data)
        assert sim.link_bytes[(0, 8)] == 10

    def test_relay_hint_forces_first_hop(self):
        """Schedule-level path selection (§3.1 paired relaying)."""
        topo = full_mesh(8)
        sim = MultiWriteSimulator(topo)
        data = np.arange(300, dtype=np.uint8)
        sim.multiwrite(0, {1: "x", 2: "x", 3: "x"}, data, relay=4)
        assert sim.link_bytes[(0, 4)] == 300           # single copy up
        for d in [1, 2, 3]:
            assert sim.link_bytes[(4, d)] == 300       # replicated at relay
            np.testing.assert_array_equal(sim.memory[d]["x"], data)
        assert (0, 1) not in sim.link_bytes            # direct links unused

    def test_conflicting_duplicate_delivery_detected(self):
        topo = full_mesh(4)
        sim = MultiWriteSimulator(topo)
        sim.write(0, 1, "x", np.array([1], np.uint8))
        with pytest.raises(AssertionError):
            sim.write(2, 1, "x", np.array([2], np.uint8))

    def test_relay_byte_accounting(self):
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        data = np.zeros(500, np.uint8)
        sim.multiwrite(0, {9: "x", 10: "x"}, data)
        # relay 8: rx 500 + tx 2x500
        assert sim.relay_bytes[8] == 1500

    def test_metadata_payload_overhead_large_domain(self):
        """§6.4: domains > 64 ranks embed the bitmap in the payload."""
        topo = full_mesh(96, link_bw=1e9)
        sim = MultiWriteSimulator(topo)
        data = np.zeros(1000, np.uint8)
        sim.write(0, 1, "x", data)
        assert sim.link_bytes[(0, 1)] == 1000 + bm.metadata_bytes(96)
