"""Spec-conformance: every architecture config matches the assignment
table literally, and the shape set / skip logic follows the brief."""

import pytest

from repro.configs.base import (
    ARCH_IDS, ALIASES, SHAPES, cell_is_skipped, get_config, shapes_for)

# (n_layers, d_model, n_heads, n_kv, d_ff, vocab) from the assignment table
TABLE = {
    "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
    "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
    "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131072),
    "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
    "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
    "kimi_k2_1t": (61, 7168, 64, 8, 2048, 163840),   # d_ff = expert dim
    "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151936),
    "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
    "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
    "rwkv6_7b": (32, 4096, None, None, 14336, 65536),
}

MOE = {"dbrx_132b": (16, 4), "kimi_k2_1t": (384, 8)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_table_conformance(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = TABLE[arch]
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.vocab == v
    if h is not None:
        assert cfg.n_heads == h
        assert cfg.n_kv_heads == kv
    if arch in MOE:
        e, k = MOE[arch]
        assert (cfg.num_experts, cfg.top_k) == (e, k)
        assert cfg.expert_d_ff == ff
    else:
        assert cfg.d_ff == ff


def test_aliases_cover_assignment_names():
    for dash in ("starcoder2-15b", "kimi-k2-1t-a32b", "qwen2-vl-2b",
                 "seamless-m4t-medium", "zamba2-7b", "rwkv6-7b"):
        assert get_config(dash) is not None


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_skip_logic():
    """long_500k runs ONLY for the sub-quadratic archs."""
    runners = {a for a in ARCH_IDS if "long_500k" in shapes_for(a)}
    assert runners == {"zamba2_7b", "rwkv6_7b"}
    for a in ARCH_IDS:
        reason = cell_is_skipped(a, "long_500k")
        assert (reason is None) == (a in runners)
        assert cell_is_skipped(a, "train_4k") is None


def test_arch_specific_features():
    g = get_config("gemma2_9b")
    assert g.window == 4096 and g.local_global_alternating
    assert g.attn_softcap == 50.0 and g.final_softcap == 30.0
    q = get_config("qwen2_vl_2b")
    assert sum(q.mrope_sections) == q.head_dim // 2
    assert q.input_mode == "embeddings"
    z = get_config("zamba2_7b")
    assert z.ssm_state == 64 and z.shared_attn_every > 0
    k = get_config("kimi_k2_1t")
    assert k.first_k_dense == 1 and k.n_shared_experts == 1
    s = get_config("seamless_m4t_medium")
    assert s.family == "encdec" and s.n_enc_layers == 12
