"""Planned gradient sync: AllReduce / ReduceScatter as planner ops.

Covers the ISSUE-6 acceptance properties:
  * registry: both reduce ops carry a full scheme family; lossy /
    accounting-only variants are never auto-bound (executable=False);
  * the scheme CROSSOVER is emergent: Planner.choose flips between at
    least two allreduce schemes across the payload sweep on a
    multi-server fabric (latency-optimal tree small, relay-reduce
    multiwrite large);
  * gradient sync as a CollectiveSite: the train program carries a
    grad_sync role whose pipelined (chunked, overlap-aware) score beats
    the serial one on 2x8 — the backward pass hides wire time;
  * the trainer's grad_sync hook reduces gradients BEFORE clipping.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core import schedules as sch
from repro.core.topology import get_fabric, full_mesh

TOPO = get_fabric("2x8")

REDUCE_PLANS = {
    ("allreduce", "ring"): True,
    ("allreduce", "tree"): True,
    ("allreduce", "hierarchical"): True,
    ("allreduce", "multiwrite"): True,
    ("allreduce", "compressed"): False,
    ("reduce_scatter", "ring"): True,
    ("reduce_scatter", "a2a"): True,
    ("reduce_scatter", "multiwrite"): False,
}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_all_reduce_plans_registered(self):
        for (op, scheme), executable in REDUCE_PLANS.items():
            plans = {p.name: p for p in plan_ir.plans_for(op)}
            assert scheme in plans, (op, scheme)
            assert plans[scheme].executable == executable, (op, scheme)

    def test_baseline_plan_is_the_flat_ring(self):
        # flat ring == what GSPMD lowers an unannotated psum to
        assert plan_ir.BASELINE_PLAN["allreduce"] == "ring"
        assert plan_ir.BASELINE_PLAN["reduce_scatter"] == "ring"

    def test_default_scenarios_cover_reduce_ops(self):
        scen = plan_ir.default_scenarios(TOPO)
        assert "allreduce" in scen and "reduce_scatter" in scen

    def test_every_plan_simulates_on_every_fabric(self):
        from repro.core.topology import FABRICS
        for fname in FABRICS:
            topo = get_fabric(fname)
            scen = plan_ir.default_scenarios(topo)
            for op in ("allreduce", "reduce_scatter"):
                for p in plan_ir.plans_for(op):
                    led = p.simulate_fn(scen[op], 1 << 20, microbatch=1)
                    t = pl.score_ledger(led, lm.DEFAULT)
                    assert t > 0 and math.isfinite(t), (fname, op, p.name)


# ---------------------------------------------------------------------------
# ledger shape sanity
# ---------------------------------------------------------------------------

class TestLedgers:
    def test_multiwrite_rail_bottleneck_beats_ring(self):
        """The relay-reduce schedule puts 1/P of the payload on each rail
        link where the flat ring puts ~2N — the bottleneck-link saving
        the scheme exists for."""
        n = float(1 << 24)
        mw = sch.reduce_multiwrite_ledger(TOPO, n)
        ring = sch.reduce_ring_ledger(TOPO, n, phases=2)

        def max_rail(led):
            return max((v for (a, b), v in led.link_bytes.items()
                        if TOPO.server_of(a) != TOPO.server_of(b)),
                       default=0.0)
        assert max_rail(mw) < max_rail(ring) / 4

    def test_hierarchical_rail_bytes_are_p_fold_smaller(self):
        n = float(1 << 24)
        meta = TOPO.meta
        led = sch.reduce_hierarchical_ledger(TOPO, n, phases=2)
        rail = [v for (a, b), v in led.link_bytes.items()
                if TOPO.server_of(a) != TOPO.server_of(b)]
        want = 2.0 * (n / meta.npus_per_server) * \
            (meta.num_servers - 1) / meta.num_servers
        assert rail and max(rail) == pytest.approx(want)

    def test_tree_is_log_depth(self):
        assert sch.reduce_tree_depth(TOPO) == 4  # ceil(log2 8) + ceil(log2 2)
        assert sch.reduce_tree_depth(full_mesh(8)) == 3

    def test_compressed_quarters_the_wire(self):
        scen = plan_ir.default_scenarios(TOPO)["allreduce"]
        plans = {p.name: p for p in plan_ir.plans_for("allreduce")}
        full = plans["ring"].simulate_fn(scen, 1 << 22, microbatch=1)
        quarter = plans["compressed"].simulate_fn(scen, 1 << 22, microbatch=1)
        assert sum(quarter.link_bytes.values()) == pytest.approx(
            sum(full.link_bytes.values()) / 4)

    def test_single_server_degrades_cleanly(self):
        topo = full_mesh(8)
        for (op, scheme) in REDUCE_PLANS:
            led = sch._REDUCE_LEDGERS[(op, scheme)](topo, float(1 << 20))
            assert all(v >= 0 for v in led.link_bytes.values()), (op, scheme)
            assert led.link_bytes, (op, scheme)


# ---------------------------------------------------------------------------
# emergent scheme crossover (acceptance criterion)
# ---------------------------------------------------------------------------

class TestCrossover:
    def test_at_least_two_schemes_win_across_sweep(self):
        planner = pl.Planner()
        winners = {}
        for log2 in range(16, 28, 2):
            d = planner.choose("allreduce", float(1 << log2), TOPO,
                               executable_only=True)
            winners[log2] = d.plan
        assert len(set(winners.values())) >= 2, winners

    def test_latency_optimal_small_bandwidth_optimal_large(self):
        planner = pl.Planner()
        small = planner.choose("allreduce", float(1 << 16), TOPO,
                               executable_only=True)
        large = planner.choose("allreduce", float(1 << 26), TOPO,
                               executable_only=True)
        assert small.plan == "tree"
        assert large.plan == "multiwrite"
        assert large.delta_vs_baseline > 0

    def test_crossover_moves_with_fabric(self):
        """A slower inter-server fabric pulls the tree->multiwrite flip
        to a smaller payload (rail bandwidth matters earlier)."""
        def flip(topo):
            planner = pl.Planner()
            for log2 in range(14, 30):
                if planner.choose("allreduce", float(1 << log2), topo,
                                  executable_only=True).plan != "tree":
                    return log2
            return 30
        assert flip(get_fabric("tpu_2x16")) < flip(get_fabric("2x8"))

    def test_lossy_scheme_never_auto_bound(self):
        planner = pl.Planner()
        for log2 in (16, 20, 24, 28):
            d = planner.choose("allreduce", float(1 << log2), TOPO,
                               executable_only=True)
            assert d.plan != "compressed"

    def test_reduce_scatter_has_a_winner(self):
        d = pl.Planner().choose("reduce_scatter", float(1 << 22), TOPO,
                                executable_only=True)
        assert d.plan in ("ring", "a2a")
        assert d.predicted_s > 0


# ---------------------------------------------------------------------------
# gradient sync as a collective site (acceptance criterion)
# ---------------------------------------------------------------------------

class TestGradSyncProgram:
    def _program(self, payload=512 * 2 ** 20, compute_s=None):
        if compute_s is None:
            # ~8B params, 2k tokens/rank backward — the tail the chunked
            # sync hides behind
            compute_s = lm.backward_compute_s(8_000_000_000, 2048)
        site = plan_ir.grad_sync_site("train", payload_bytes=payload,
                                      compute_s=compute_s, topo=TOPO)
        return plan_ir.CollectiveProgram("train", (site,))

    def test_site_role_and_op(self):
        prog = self._program()
        (site,) = prog.sites
        assert site.op == "allreduce"
        assert site.role == "train/grad_sync"

    def test_pipelined_beats_serial_on_2x8(self):
        eplan = pl.Planner().plan_program(self._program(), TOPO)
        d = eplan.decisions["train/grad_sync"]
        assert d.shard_map_kwargs["microbatch"] > 1
        assert d.predicted_s < d.predicted_serial_s
        assert d.predicted_s < d.baseline_s

    def test_bound_kwargs_carry_scheme_and_chunks(self):
        eplan = pl.Planner().plan_program(self._program(), TOPO)
        kw = eplan.site_kwargs("train/grad_sync")
        assert kw["reduce_scheme"] in ("ring", "tree", "hierarchical",
                                       "multiwrite")
        assert kw["microbatch"] >= 1

    def test_no_compute_context_means_no_overlap_win(self):
        """With zero backward compute to hide behind, chunking only adds
        launch overhead — G stays at 1."""
        eplan = pl.Planner().plan_program(
            self._program(compute_s=0.0), TOPO)
        d = eplan.decisions["train/grad_sync"]
        assert d.shard_map_kwargs["microbatch"] == 1

    def test_backward_compute_model(self):
        t = lm.backward_compute_s(1_000_000_000, 1024)
        assert t > 0
        assert lm.backward_compute_s(2_000_000_000, 1024) == \
            pytest.approx(2 * t)
        assert lm.backward_compute_s(1_000_000_000, 1024, tp=8) == \
            pytest.approx(t / 8)


# ---------------------------------------------------------------------------
# trainer hook
# ---------------------------------------------------------------------------

class TestTrainerHook:
    def _setup(self):
        from repro.configs.base import get_config
        from repro.data.pipeline import DataConfig, SyntheticLM, \
            batch_for_model
        from repro.models.api import build_model
        cfg = get_config("mistral_nemo_12b").reduced(
            n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab=128)
        model = build_model(cfg, dtype=jnp.float32)
        data = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=2))
        return model, batch_for_model(cfg, data.batch(0))

    def test_grad_sync_applied_before_clipping(self):
        from repro.optim import sgd
        from repro.runtime.trainer import TrainState, make_train_step
        model, batch = self._setup()
        params = model.init(jax.random.key(0))
        opt = sgd(lr=1e-2)
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32))
        # a sync that zeroes every gradient: the visible grad_norm must
        # be 0 (hook runs before clip) and the sgd update must be a no-op
        zero_sync = lambda g: jax.tree_util.tree_map(      # noqa: E731
            jnp.zeros_like, g)
        step = make_train_step(model, opt, donate=False,
                               grad_sync=zero_sync)
        new_state, metrics = step(state, batch)
        assert float(metrics["grad_norm"]) == 0.0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_identity_sync_matches_default_step(self):
        from repro.optim import sgd
        from repro.runtime.trainer import TrainState, make_train_step
        model, batch = self._setup()
        params = model.init(jax.random.key(0))
        opt = sgd(lr=1e-2)
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32))
        s1, m1 = make_train_step(model, opt, donate=False)(state, batch)
        s2, m2 = make_train_step(model, opt, donate=False,
                                 grad_sync=lambda g: g)(state, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]))
        for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                        jax.tree_util.tree_leaves(s2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
