"""Declarative collective programs + jointly-planned ExecutionPlans
(ISSUE 5).

What is pinned here:

  * the program IR: site keys, role uniqueness, coupling validation,
    cache-key stability;
  * ``Planner.plan_program``: uncoupled sites match ``choose``; the
    coupled MoE (dispatch, combine) product sweep respects the
    executable-pairing constraint and shares ONE microbatch G;
  * the ISSUE acceptance point: the jointly-planned (dispatch G,
    combine G) pair DIFFERS from the PR-4 dispatch-first choice at some
    operating point and strictly beats it on the combined
    shared-pipeline score;
  * ExecutionPlan identity (fingerprints) and binding
    (``ParallelContext.bind`` -> trace-time lookup, miss fallback,
    fabric mismatch guard);
  * whole-program replanning after a re-calibration
    (``Planner.replan_programs`` / ``DriftMonitor.recalibrate``);
  * the deprecated ``resolve_*`` shims: one release of warning +
    agreement with the new joint path;
  * ``StepAttribution``: live step wall times reach
    ``fit_overlap_eff`` through ``Planner.note_measurement``;
  * the directed linkprobe: never-bottlenecking rail directions get
    fitted instead of staying nominal.
"""

import dataclasses
import os
import re

import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core.topology import get_fabric, two_server_cluster

TOKEN = lm.TOKEN_BYTES


def compute_ctx(batch, top_k=8, d_model=7168, f_shard=2048):
    return lm.expert_compute_time_s(batch, top_k, d_model, f_shard)


def moe_program(batch, *, phase="train", token_bytes=TOKEN,
                compute_s=None, skew=0.0):
    if compute_s is None:
        compute_s = compute_ctx(batch)
    sites = plan_ir.moe_sites(phase, num_experts=64, top_k=8,
                              tokens_per_rank=batch,
                              token_bytes=token_bytes,
                              compute_s=compute_s, skew=skew)
    return plan_ir.CollectiveProgram(phase, sites)


# ---------------------------------------------------------------------------
# the program IR
# ---------------------------------------------------------------------------

class TestProgramIR:
    def test_site_key_matches_trace_side_construction(self):
        d, c = plan_ir.moe_sites("train", num_experts=64, top_k=8,
                                 tokens_per_rank=512, token_bytes=TOKEN,
                                 compute_s=1e-3, skew=0.5)
        assert d.key() == plan_ir.site_key(
            "dispatch", 512 * TOKEN, skew=0.5, compute_s=1e-3,
            num_experts=64, top_k=8, token_bytes=TOKEN)
        assert c.coupled_with == d.role
        # nearby payloads/compute share the bucketed key
        assert d.key() == plan_ir.site_key(
            "dispatch", 512 * TOKEN - 7, skew=0.5, compute_s=1.01e-3,
            num_experts=64, top_k=8, token_bytes=TOKEN)

    def test_duplicate_roles_rejected(self):
        s = plan_ir.allgather_site("p", frag_bytes=1024)
        with pytest.raises(ValueError, match="duplicate site roles"):
            plan_ir.CollectiveProgram("p", (s, s))

    def test_dangling_coupling_rejected(self):
        s = plan_ir.CollectiveSite(op="combine", role="c",
                                   payload_bytes=1.0, coupled_with="ghost")
        with pytest.raises(ValueError, match="unknown role"):
            plan_ir.CollectiveProgram("p", (s,))

    def test_coupling_chain_rejected(self):
        a = plan_ir.CollectiveSite(op="dispatch", role="a",
                                   payload_bytes=1.0, coupled_with="b")
        b = plan_ir.CollectiveSite(op="combine", role="b",
                                   payload_bytes=1.0, coupled_with="c")
        c = plan_ir.CollectiveSite(op="dispatch", role="c",
                                   payload_bytes=1.0)
        with pytest.raises(ValueError, match="chain"):
            plan_ir.CollectiveProgram("p", (a, b, c))

    def test_groups_partition(self):
        prog = moe_program(256)
        ag = plan_ir.allgather_site("train", frag_bytes=1 << 20)
        prog2 = plan_ir.CollectiveProgram("p", (*prog.sites, ag))
        groups = prog2.groups()
        assert [len(g) for g in groups] == [2, 1]
        assert groups[0][0].op == "dispatch"

    def test_cache_key_stable_and_workload_sensitive(self):
        assert moe_program(256).cache_key() == moe_program(256).cache_key()
        assert moe_program(256).cache_key() != moe_program(512).cache_key()


# ---------------------------------------------------------------------------
# plan_program: joint sweep
# ---------------------------------------------------------------------------

class TestPlanProgram:
    @pytest.fixture()
    def planner(self):
        return pl.Planner()

    def test_single_site_matches_choose(self, planner):
        topo, _ = __import__(
            "repro.core.topology", fromlist=["split_tp_full_mesh"]
        ).split_tp_full_mesh(8, tp=4)
        site = plan_ir.allgather_site("t", frag_bytes=4 << 20)
        prog = plan_ir.CollectiveProgram("t", (site,))
        eplan = planner.plan_program(prog, topo)
        direct = planner.choose("allgather", 4 << 20, topo,
                                executable_only=True, num_domains=2)
        got = eplan.decision("t/split_tp_gather")
        assert (got.plan, got.knobs) == (direct.plan, direct.knobs)

    def test_joint_sweep_shares_one_microbatch(self, planner):
        topo = two_server_cluster()
        eplan = planner.plan_program(moe_program(1024), topo)
        joint = eplan.joint["train/moe_dispatch"]
        kw = eplan.site_kwargs("train/moe_dispatch")
        assert kw["microbatch"] == joint.microbatch
        assert kw == eplan.site_kwargs("train/moe_combine")
        # every joint candidate shares its G across both halves by
        # construction; the pairing constraint holds: no candidate pairs
        # a unicast dispatch with a relay-reduced combine
        for name, _, _ in joint.candidates:
            d_name, c_name = name.split("+")
            if d_name == "unicast":
                assert c_name == "unicast"

    def test_joint_beats_dispatch_first(self, planner):
        """ISSUE acceptance: the jointly-planned (dispatch G, combine G)
        pair differs from the PR-4 dispatch-first choice at some
        fabric/batch point and strictly beats it on the combined
        modeled score."""
        topo = two_server_cluster()
        hw = planner.hw
        differed = []
        for batch in (128, 256, 512, 1024, 2048):
            compute_s = compute_ctx(batch)
            eplan = planner.plan_program(
                moe_program(batch, compute_s=compute_s), topo)
            joint = eplan.joint["train/moe_dispatch"]
            # PR-4 path: dispatch sweeps alone, combine compared at the
            # EXECUTED dispatch G
            d = planner.choose("dispatch", batch * TOKEN, topo,
                               token_bytes=TOKEN, compute_s=compute_s)
            g = d.microbatch
            c_at_g = min(
                (t, name) for name, kn, t in planner.choose(
                    "combine", batch * TOKEN, topo, token_bytes=TOKEN,
                    compute_s=compute_s).candidates
                if dict(kn).get("microbatch", 1) == g)
            c_name = c_at_g[1]
            if d.plan == "unicast":
                c_name = "unicast"          # executable pairing
            # combined score of the dispatch-first configuration under
            # the SAME shared-pipeline model
            scen_kw = dict(num_experts=64, top_k=8, token_bytes=TOKEN,
                           skew=0.0, compute_s=compute_s)
            d_scen = pl.Planner._scenario("dispatch", topo, scen_kw)
            c_scen = pl.Planner._scenario("combine", topo, scen_kw)
            bucket = pl.bucket_payload(batch * TOKEN)
            ld = plan_ir.get_plan("dispatch", d.plan).simulate(
                d_scen, bucket, microbatch=g)
            lc = plan_ir.get_plan("combine", c_name).simulate(
                c_scen, bucket, microbatch=g)
            first_t = lm.score_pipeline((ld, lc), hw)
            pair_first = (
                "hierarchical" if d.plan == "multiwrite" else "baseline", g,
                "hierarchical" if c_name == "multiwrite" else "baseline", g)
            pair_joint = (joint.shard_map_kwargs["moe_scheme"],
                          joint.microbatch,
                          joint.shard_map_kwargs["moe_combine"],
                          joint.microbatch)
            # the joint sweep optimizes over a superset that includes
            # the dispatch-first configuration
            assert joint.predicted_s <= first_t + 1e-12, (batch,)
            if pair_joint != pair_first:
                differed.append((batch, pair_first, pair_joint))
                assert joint.predicted_s < first_t, (batch,)
        assert differed, ("joint sweep never changed a decision vs the "
                          "dispatch-first path over the sweep")

    def test_program_cache_and_fingerprints(self, planner):
        topo = two_server_cluster()
        prog = moe_program(512)
        a = planner.plan_program(prog, topo)
        b = planner.plan_program(prog, topo)
        assert a is b                      # LRU hit
        assert a.fingerprint == b.fingerprint
        degraded = planner.hw.recalibrated(
            {"links": {k: ln.bw / 4 for k, ln in topo.links.items()
                       if topo.server_of(k[0]) != topo.server_of(k[1])}})
        c = planner.plan_program(prog, topo, degraded)
        assert c.hw_fingerprint != a.hw_fingerprint

    def test_replan_programs_after_recalibration(self, planner):
        topo = two_server_cluster()
        prog = moe_program(64)             # small batch: unicast pair
        before = planner.plan_program(prog, topo)
        degraded = planner.hw.recalibrated(
            {"links": {k: ln.bw / 8 for k, ln in topo.links.items()
                       if topo.server_of(k[0]) != topo.server_of(k[1])}})
        planner.refresh_hardware(degraded)
        events = planner.replan_programs()
        ev = next(e for e in events if e["program"] == "train")
        assert ev["changed"]
        assert ev["plan"].fingerprint != before.fingerprint
        # the degradation flips the small-batch pair off unicast
        assert before.site_kwargs("train/moe_dispatch")["moe_scheme"] == \
            "baseline"
        assert ev["plan"].site_kwargs(
            "train/moe_dispatch")["moe_scheme"] == "hierarchical"


# ---------------------------------------------------------------------------
# binding into the ParallelContext
# ---------------------------------------------------------------------------

def _mesh_pctx(**kw):
    import jax

    from repro.launch.mesh import make_test_mesh
    from repro.parallel.context import ParallelContext
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_test_mesh(shape=(1,), axes=("model",))
    return ParallelContext(mesh=mesh, pod_axis=None, data_axis="model",
                           model_axis="model", **kw)


class TestBinding:
    def test_bound_lookup_serves_joint_kwargs(self):
        pctx = _mesh_pctx(plan_policy="auto", fabric=two_server_cluster())
        cs = compute_ctx(1024)
        sites = pctx.moe_sites("train", num_experts=64, top_k=8,
                               tokens_per_rank=1024, token_bytes=TOKEN,
                               compute_s=cs)
        eplan = pctx.plan_collectives(
            plan_ir.CollectiveProgram("train", sites))
        bound = pctx.bind(eplan)
        got = bound.moe_pipeline_kwargs(64, 8, tokens_per_rank=1024,
                                        token_bytes=TOKEN, compute_s=cs)
        want = eplan.site_kwargs("train/moe_dispatch")
        assert got == bound._norm_moe_kwargs(want)

    def test_bound_miss_falls_back_to_policy(self):
        pctx = _mesh_pctx(plan_policy="fixed", moe_scheme="baseline",
                          moe_microbatch=2)
        prog = plan_ir.CollectiveProgram(
            "train", pctx.moe_sites("train", num_experts=64, top_k=8,
                                    tokens_per_rank=4096,
                                    token_bytes=TOKEN))
        bound = pctx.bind(plan_ir.pinned_execution_plan(
            prog, {"train/moe_dispatch": {"moe_scheme": "hierarchical",
                                          "moe_combine": "hierarchical",
                                          "microbatch": 8}}))
        # a workload the program never declared: declared knobs win
        got = bound.moe_pipeline_kwargs(64, 8, tokens_per_rank=32,
                                        token_bytes=TOKEN)
        assert got == {"moe_scheme": "baseline", "moe_combine": "baseline",
                       "microbatch": 2}
        # the declared workload resolves from the pinned plan
        hit = bound.moe_pipeline_kwargs(64, 8, tokens_per_rank=4096,
                                        token_bytes=TOKEN)
        assert hit == {"moe_scheme": "hierarchical",
                       "moe_combine": "hierarchical", "microbatch": 8}

    def test_bind_rejects_foreign_fabric(self):
        pctx_a = _mesh_pctx(plan_policy="auto",
                            fabric=two_server_cluster())
        pctx_b = _mesh_pctx(plan_policy="auto", fabric=get_fabric("4x8"))
        eplan = pctx_a.plan_collectives(moe_program(256))
        with pytest.raises(ValueError, match="replan the program"):
            pctx_b.bind(eplan)

    def test_executed_g_constraint_reresolves_schemes(self):
        """When moe_ffn's divisibility clamp moves G off the planned
        value, the configuration is re-resolved AT the executed G: the
        returned pair is the best joint candidate at that depth, not
        the planned-G pair run at a depth the sweep scored worse."""
        pctx = _mesh_pctx(plan_policy="auto", fabric=two_server_cluster())
        batch, cs = 2048, compute_ctx(2048)
        kw = dict(num_experts=64, top_k=8, tokens_per_rank=batch,
                  token_bytes=TOKEN, compute_s=cs)
        free = pctx.moe_pipeline_kwargs(**kw)
        assert free["microbatch"] > 1
        sites = pctx.moe_sites("auto", **kw)
        joint = pctx.plan_collectives(
            plan_ir.CollectiveProgram("moe/auto", sites)).joint[
                sites[0].role]
        for g in (1, 2):
            got = pctx.moe_pipeline_kwargs(**kw, microbatch=g)
            assert got["microbatch"] == g
            best_t, best_name = min(
                (t, name) for name, kn, t in joint.candidates
                if dict(kn).get("microbatch", 1) == g)
            d_name, _, c_name = best_name.partition("+")
            assert got["moe_scheme"] == (
                "hierarchical" if d_name == "multiwrite" else "baseline")
            assert got["moe_combine"] == (
                "hierarchical" if c_name == "multiwrite" else "baseline")

    def test_allgather_site_binding(self):
        from repro.core.topology import split_tp_full_mesh
        pctx = _mesh_pctx(plan_policy="auto")
        topo, _ = split_tp_full_mesh(8, tp=4)
        site = plan_ir.allgather_site("train", frag_bytes=8 << 20,
                                      num_domains=2, topo=topo)
        eplan = pctx.plan_collectives(
            plan_ir.CollectiveProgram("train", (site,)))
        bound = pctx.bind(eplan)
        d = bound.allgather_plan(8 << 20, num_domains=2)
        assert (d.plan, d.knobs) == \
            (eplan.decision("train/split_tp_gather").plan,
             eplan.decision("train/split_tp_gather").knobs)


# ---------------------------------------------------------------------------
# deprecated shims (one release)
# ---------------------------------------------------------------------------

class TestDeprecatedShims:
    @pytest.fixture()
    def pctx(self):
        return _mesh_pctx(plan_policy="auto", fabric=two_server_cluster())

    def test_shims_warn_and_agree_with_joint_path(self, pctx):
        kw = pctx.moe_pipeline_kwargs(64, 8, 2048, TOKEN)
        with pytest.warns(DeprecationWarning, match="resolve_moe_scheme"):
            assert pctx.resolve_moe_scheme(64, 8, 2048, TOKEN) == \
                kw["moe_scheme"]
        with pytest.warns(DeprecationWarning,
                          match="resolve_combine_scheme"):
            assert pctx.resolve_combine_scheme(64, 8, 2048, TOKEN) == \
                kw["moe_combine"]
        with pytest.warns(DeprecationWarning,
                          match="resolve_moe_dispatch"):
            got = pctx.resolve_moe_dispatch(64, 8, 2048, TOKEN)
        assert got == {"moe_scheme": kw["moe_scheme"],
                       "microbatch": kw["microbatch"]}
        with pytest.warns(DeprecationWarning, match="moe_dispatch_plan"):
            d = pctx.moe_dispatch_plan(64, 8, 2048, TOKEN)
        assert d.op == "dispatch"
        with pytest.warns(DeprecationWarning, match="moe_combine_plan"):
            c = pctx.moe_combine_plan(64, 8, 2048, TOKEN)
        assert c.op == "combine"

    def test_no_internal_callers_of_shims(self):
        """The deprecation window is for EXTERNAL callers: nothing under
        src/repro may call the shimmed APIs (backed by the pyproject
        filterwarnings rule that escalates repro-internal shim warnings
        to errors in tier-1)."""
        root = os.path.join(os.path.dirname(__file__), "..", "src",
                            "repro")
        pat = re.compile(
            r"\.(resolve_moe_scheme|resolve_moe_dispatch|"
            r"resolve_combine_scheme|moe_dispatch_plan|moe_combine_plan)"
            r"\s*\(")
        offenders = []
        for dirpath, _, files in os.walk(root):
            for fname in files:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                if path.endswith(os.path.join("parallel", "context.py")):
                    continue               # the shims themselves
                with open(path) as f:
                    for i, line in enumerate(f, 1):
                        if pat.search(line):
                            offenders.append(f"{path}:{i}")
        assert not offenders, offenders


# ---------------------------------------------------------------------------
# live step-time attribution -> overlap-efficiency fit
# ---------------------------------------------------------------------------

class TestStepAttribution:
    def _joint(self, planner, batch=2048):
        topo = two_server_cluster()
        eplan = planner.plan_program(moe_program(batch), topo)
        joint = eplan.joint["train/moe_dispatch"]
        assert joint.microbatch > 1
        return joint

    def test_explicit_overhead_recovers_true_eta(self):
        from repro.telemetry import StepAttribution, fit_overlap_eff
        planner = pl.Planner()
        joint = self._joint(planner)
        true_eta = 0.55
        t_true = (joint.predicted_serial_s
                  - true_eta * (joint.predicted_serial_s
                                - joint.predicted_ideal_s))
        layers, overhead = 4, 3e-3
        att = StepAttribution(planner, joint, n_layers=layers,
                              overhead_s=overhead, warmup=2)
        for _ in range(8):
            att.observe_step(overhead + layers * t_true)
        assert att.fed == 6                # warmup steps excluded
        eta = fit_overlap_eff(planner.decision_log)
        assert eta is not None
        assert abs(eta - true_eta) < 0.05

    def test_min_anchored_mode_feeds_rows(self):
        from repro.telemetry import StepAttribution
        planner = pl.Planner()
        joint = self._joint(planner)
        att = StepAttribution(planner, joint, n_layers=2, warmup=1)
        rows = [att.observe_step(1e-2 + 1e-4 * i) for i in range(5)]
        assert rows[0] is None
        fed = [r for r in rows if r is not None]
        assert fed and all(r["measured_s"] > 0 for r in fed)

    def test_trainer_step_hook_reaches_decision_log(self):
        """End-to-end: a Trainer step_hook wired like train.py's feeds
        wall times into the planner's joint decision rows."""
        from repro.telemetry import StepAttribution
        planner = pl.Planner()
        joint = self._joint(planner)
        att = StepAttribution(planner, joint, n_layers=1,
                              overhead_s=0.0, warmup=0)

        def step_hook(step, row):
            att.observe_step(row["wall"])

        for step in range(3):
            step_hook(step, {"wall": joint.predicted_s})
        measured = [r for r in planner.decision_log
                    if r.get("measured_s") is not None
                    and r["op"] == "dispatch+combine"]
        assert len(measured) == 3


# ---------------------------------------------------------------------------
# directed linkprobe: never-bottlenecking directions get fitted
# ---------------------------------------------------------------------------

class TestDirectionProbes:
    def test_forward_rails_fitted_on_asymmetric_fabric(self):
        from repro.core.planner import Planner
        from repro.telemetry import (CalibrationStore, DriftMonitor,
                                     GroundTruth, SimProbe,
                                     fit_measurements, topo_key)
        topo = get_fabric("2x8asym")
        truth = GroundTruth().degraded(topo, 2.0, "inter")
        store = CalibrationStore(":memory:")
        monitor = DriftMonitor(Planner(), store, topo)
        monitor.run_cycle(SimProbe(truth))
        recs = list(store.latest_by_key(fabric=topo_key(topo)).values())
        measurements, fits = fit_measurements(recs, topo)
        fwd = {k: v for k, v in measurements.get("links", {}).items()
               if topo.server_of(k[0]) == 0 and topo.server_of(k[1]) == 1}
        rev = {k: v for k, v in measurements.get("links", {}).items()
               if topo.server_of(k[0]) == 1 and topo.server_of(k[1]) == 0}
        # forward rails (nominal 25, truly 12.5 after 2x degradation)
        # were previously UNFITTABLE: no collective ever bottlenecks
        # there.  The directed probes pin them.
        assert fwd and all(abs(v - 12.5e9) < 1.5e9 for v in fwd.values())
        assert rev and all(abs(v - 6.25e9) < 1e9 for v in rev.values())
        assert fits["inter:0>1"].trusted and fits["inter:1>0"].trusted

    def test_direction_records_are_per_direction_in_store(self):
        from repro.telemetry import (CalibrationStore, GroundTruth,
                                     SimProbe, probe_link_directions)
        topo = two_server_cluster()
        recs = probe_link_directions(topo, SimProbe(GroundTruth()))
        store = CalibrationStore(":memory:")
        store.extend(recs)
        latest = store.latest_by_key()
        roles = {r["bottleneck_role"] for r in latest.values()}
        assert roles == {"inter:0>1", "inter:1>0"}
        assert len(latest) == len(recs)    # directions never supersede
        #   each other (only re-probes of the SAME direction do)
