"""Tests for the benchmark harness + roofline builder."""

import json
import os

import pytest

from benchmarks import paper_figures, roofline


class TestPaperFigures:
    def test_all_figures_produce_rows(self):
        for name, fn in paper_figures.ALL.items():
            rows = fn()
            assert rows, name

    def test_fig6_reduction_matches_paper(self):
        rows = paper_figures.fig6_allgather()
        mw = [r for r in rows if r["scheme"] == "multiwrite_paired"][0]
        assert abs(mw["reduction_pct"] - 30.0) < 3.0

    def test_table1_errors_within_tolerance(self):
        for r in paper_figures.table1_cross():
            assert abs(r["w_err_pct"]) < 12
            assert abs(r["wo_err_pct"]) < 8


class TestRoofline:
    def test_load_and_markdown(self, tmp_path, monkeypatch):
        fake = {
            "arch": "x", "shape": "train_4k", "mesh": "single",
            "variant": "mw", "chips": 256, "kind": "train",
            "cost": {"flops_per_device": 1e12, "bytes_per_device": 1e11},
            "roofline": {"compute_term_s": 1e12 / 197e12,
                         "memory_term_s": 1e11 / 819e9,
                         "collective_term_s": 0.001,
                         "dominant": "memory",
                         "model_flops_global": 5e13,
                         "useful_flops_ratio": 0.5},
            "memory": {}, "collectives": {"by_axis": {}},
        }
        d = tmp_path / "dryrun"
        d.mkdir()
        with open(d / "x__train_4k__single__mw.json", "w") as f:
            json.dump(fake, f)
        monkeypatch.setattr(roofline, "RESULTS", str(d))
        monkeypatch.setattr(roofline, "model_flops",
                            lambda a, s: 5e13)
        rows = roofline.load()
        assert len(rows) == 1
        md = roofline.markdown(rows)
        assert "train_4k" in md and "memory" in md

    def test_real_results_if_present(self):
        rows = roofline.load()
        if not rows:
            pytest.skip("no dry-run results present")
        ok = [r for r in rows if "error" not in r and "skipped" not in r]
        assert ok, "all cells errored"
        # every runnable cell has the three terms
        for r in ok:
            rl = r["roofline"]
            assert rl["compute_term_s"] >= 0
            assert rl["memory_term_s"] > 0
            assert rl["dominant"] in ("compute", "memory", "collective")

    def test_skip_records_present_for_full_attention_archs(self):
        rows = roofline.load()
        if not rows:
            pytest.skip("no dry-run results present")
        skipped = [r for r in rows if "skipped" in r]
        if skipped:
            assert all(r["shape"] == "long_500k" for r in skipped)
