"""Fault-tolerant planning: failure model, feasibility masking, the
degradation ladder, detection, and hot plan re-bind.

Pins the ISSUE-9 acceptance surface:
  * ``FailureState`` composes onto a topology (``with_failures``),
    changes the fingerprint, and routes around dead links/relays;
  * planner candidates whose ledgers charge a dead link (or whose
    forwarding engine sits on a dead relay) are masked as infeasible —
    multiwrite degrades down the ladder instead of scoring garbage, and
    a fully partitioned fabric raises the typed ``NoFeasiblePlanError``;
  * ``PlanBinder`` double-buffers plan swaps with a fingerprint-keyed
    traced-lowering cache (zero cold retraces at swap time);
  * probe hardening (bounded retry, timeouts counted not fatal) and the
    ``FailureDetector`` strike/revive hysteresis;
  * the ``DriftMonitor`` failover arc: detection retargets registered
    programs, staleness surfaces, recovery flips back.
"""

import dataclasses

import pytest

from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core import schedules  # noqa: F401 — registers plans
from repro.core.topology import (FailureState, NO_FAILURES, get_fabric,
                                 same_fabric_fingerprint)
from repro.parallel.context import PlanBinder
from repro.telemetry import (CalibrationStore, DriftMonitor,
                             FailureDetector, GroundTruth, ProbePolicy,
                             ProbeTimeout, SimProbe,
                             attributed_bottleneck, default_registry,
                             measure_safely, rail_probe_ledger,
                             reset_default_registry)

TOKEN_BYTES = 7168
BIG = 8 << 20     # payload where multiwrite wins on a healthy 2x8


@pytest.fixture(autouse=True)
def _fresh_metrics():
    reset_default_registry()
    yield
    reset_default_registry()


def moe_program(name="ft_serve"):
    return plan_ir.CollectiveProgram(
        name=name,
        sites=plan_ir.moe_sites("prefill", num_experts=64, top_k=8,
                                tokens_per_rank=64,
                                token_bytes=TOKEN_BYTES))


# ---------------------------------------------------------------------------
# failure model (core/topology)
# ---------------------------------------------------------------------------

class TestFailureState:
    def test_empty_state_is_falsy_and_identity(self):
        topo = get_fabric("2x8")
        assert not NO_FAILURES
        assert topo.with_failures(NO_FAILURES) is topo

    def test_fingerprint_changes_under_failures(self):
        topo = get_fabric("2x8")
        fs = FailureState(dead_links={(0, 8)})
        failed = topo.with_failures(fs)
        assert failed.fingerprint() != topo.fingerprint()
        # healthy fingerprints never gain a failure element: recovery
        # flips back to the ORIGINAL identity (cache keys line up)
        assert topo.fingerprint() == get_fabric("2x8").fingerprint()

    def test_same_fabric_fingerprint_spans_failure_variants(self):
        topo = get_fabric("2x8")
        failed = topo.with_failures(FailureState(dead_links={(0, 8)}))
        assert same_fabric_fingerprint(topo.fingerprint(),
                                       failed.fingerprint())
        other = get_fabric("4x8")
        assert not same_fabric_fingerprint(topo.fingerprint(),
                                           other.fingerprint())

    def test_dead_link_routes_around(self):
        topo = get_fabric("2x8")
        failed = topo.with_failures(FailureState(dead_links={(0, 8)}))
        assert (0, 8) not in failed.links
        path = failed.path(0, 8)
        assert path[0] == 0 and path[-1] == 8
        assert (0, 8) not in zip(path, path[1:])

    def test_dead_relay_not_transited(self):
        topo = get_fabric("2x8")
        # node 1 (the healthy detour's first hop for 0->8 with rail
        # (0,8) dead) refuses to forward: the route must avoid it
        fs = FailureState(dead_links={(0, 8)}, dead_relays={1, 9})
        failed = topo.with_failures(fs)
        path = failed.path(0, 8)
        assert 1 not in path[1:-1] and 9 not in path[1:-1]

    def test_degraded_factor_multiplies_and_composes(self):
        topo = get_fabric("2x8")
        half = topo.with_failures(
            FailureState(degraded_links={(0, 8): 0.5}))
        assert half.link(0, 8).bw == pytest.approx(
            topo.link(0, 8).bw * 0.5)
        quarter = half.with_failures(
            FailureState(degraded_links={(0, 8): 0.5}))
        assert quarter.link(0, 8).bw == pytest.approx(
            topo.link(0, 8).bw * 0.25)
        # the merged identity carries the COMPOSED factor
        assert dict(quarter.failures.degraded_links)[(0, 8)] \
            == pytest.approx(0.25)

    def test_lost_npu_loses_every_link(self):
        topo = get_fabric("2x8")
        failed = topo.with_failures(FailureState(lost_npus={0}))
        assert not [k for k in failed.links if 0 in k]
        # node count is preserved (ClusterMeta invariant): the NPU is
        # lost, not renumbered
        assert failed.num_nodes == topo.num_nodes

    def test_factor_validation(self):
        with pytest.raises(ValueError):
            FailureState(degraded_links={(0, 1): 0.0})


# ---------------------------------------------------------------------------
# planner feasibility masking + the degradation ladder
# ---------------------------------------------------------------------------

class TestFeasibilityMasking:
    def test_ledger_infeasible_checks(self):
        topo = get_fabric("2x8")
        led = plan_ir.Ledger(topo=topo, link_bytes={(0, 8): 1.0},
                             relay_bytes={}, flow_counts={(0, 8): 1})
        assert pl.ledger_infeasible(led, NO_FAILURES) is None
        assert "dead link" in pl.ledger_infeasible(
            led, FailureState(dead_links={(0, 8)}))

    def test_dead_rail_reroutes_not_raises(self):
        topo = get_fabric("2x8")
        failed = topo.with_failures(FailureState(dead_links={(0, 8),
                                                             (8, 0)}))
        planner = pl.Planner()
        eplan = planner.plan_program(moe_program(), failed)
        truth_fs = failed.failures
        for role, led in pl.plan_site_ledgers(eplan, failed).items():
            assert pl.ledger_infeasible(led, truth_fs) is None, role

    def test_relay_ladder_multiwrite_to_unicast(self):
        topo = get_fabric("2x8")
        planner = pl.Planner()
        healthy = planner.choose("combine", BIG, topo,
                                 executable_only=True)
        assert healthy.plan == "multiwrite"
        # the sending server's forwarding engines dead: multiwrite's
        # ledger charges a dead relay engine and masks, plain unicast
        # (relay_bytes but no engine dependence) survives
        failed = topo.with_failures(
            FailureState(dead_relays=set(range(8))))
        degraded = planner.choose("combine", BIG, failed,
                                  executable_only=True)
        assert degraded.plan == "unicast"
        reg = default_registry()
        assert reg["repro_plan_infeasible_total"].value(
            op="combine", fabric=failed.name) >= 1

    def test_relay_ladder_allreduce_to_hierarchical(self):
        topo = get_fabric("2x8")
        planner = pl.Planner()
        assert planner.choose("allreduce", BIG, topo,
                              executable_only=True).plan == "multiwrite"
        failed = topo.with_failures(
            FailureState(dead_relays=set(range(8))))
        degraded = planner.choose("allreduce", BIG, failed,
                                  executable_only=True)
        # the middle rung: hierarchical beats raw unicast-style rings
        # when only the relay engines (not the rails) are gone
        assert degraded.plan == "hierarchical"
        reg = default_registry()
        assert reg["repro_plan_infeasible_total"].value(
            op="allreduce", fabric=failed.name) >= 1

    def test_partition_raises_typed_error(self):
        topo = get_fabric("2x8")
        rails = {k for k in topo.links
                 if topo.server_of(k[0]) != topo.server_of(k[1])}
        failed = topo.with_failures(FailureState(dead_links=rails))
        planner = pl.Planner()
        with pytest.raises(pl.NoFeasiblePlanError) as ei:
            planner.choose("dispatch", BIG, failed,
                           executable_only=True)
        assert ei.value.op == "dispatch"
        assert ei.value.masked

    def test_partition_raises_for_programs_too(self):
        topo = get_fabric("2x8")
        rails = {k for k in topo.links
                 if topo.server_of(k[0]) != topo.server_of(k[1])}
        failed = topo.with_failures(FailureState(dead_links=rails))
        with pytest.raises(pl.NoFeasiblePlanError):
            pl.Planner().plan_program(moe_program(), failed)

    def test_healthy_errors_still_propagate(self):
        # masking only softens failures when a FailureState is present;
        # a healthy-fabric sweep keeps its exceptions loud
        topo = get_fabric("2x8")
        assert topo.failures is NO_FAILURES or not topo.failures
        with pytest.raises(ValueError):
            pl.Planner().choose("no_such_op", BIG, topo)


# ---------------------------------------------------------------------------
# hot plan re-bind (PlanBinder)
# ---------------------------------------------------------------------------

class _FakePlan:
    def __init__(self, fp):
        self.fingerprint = fp
        self.program = dataclasses.make_dataclass("P", ["name"])("prog")


class TestPlanBinder:
    def _binder(self):
        log = []

        def trace(plan):
            log.append(plan.fingerprint if plan else None)
            return ("lowered", plan.fingerprint if plan else None)

        return PlanBinder(trace, plan=_FakePlan("A")), log

    def test_initial_bind_traces_once(self):
        binder, log = self._binder()
        assert log == ["A"]
        assert binder.artifact == ("lowered", "A")
        assert binder.swaps == 0

    def test_stage_builds_off_path_swap_is_pointer_flip(self):
        binder, log = self._binder()
        assert binder.stage(_FakePlan("B")) is True
        assert log == ["A", "B"]          # built at STAGE time
        assert binder.plan.fingerprint == "A"   # not yet active
        assert binder.swap_if_pending() is True
        assert binder.plan.fingerprint == "B"
        assert log == ["A", "B"]          # swap built nothing
        assert binder.swaps == 1 and binder.cold_retraces == 0

    def test_flip_back_is_cache_hit(self):
        binder, log = self._binder()
        binder.stage(_FakePlan("B"))
        binder.swap_if_pending()
        binder.stage(_FakePlan("A"))      # recovery: back to original
        binder.swap_if_pending()
        assert binder.plan.fingerprint == "A"
        assert log == ["A", "B"]          # no retrace at all
        assert binder.cache_hits == 1 and binder.cold_retraces == 0

    def test_stage_active_plan_is_noop(self):
        binder, log = self._binder()
        assert binder.stage(_FakePlan("A")) is False
        assert binder.swap_if_pending() is False
        assert binder.swaps == 0

    def test_unstaged_swap_counts_cold_retrace(self):
        binder, log = self._binder()
        binder._pending = _FakePlan("C")  # bypass stage: no cache entry
        binder.swap_if_pending()
        assert binder.cold_retraces == 1
        reg = default_registry()
        assert reg["repro_rebind_cold_retrace_total"].value(
            program="prog") == 1

    def test_rebind_metrics(self):
        binder, _ = self._binder()
        binder.stage(_FakePlan("B"))
        binder.swap_if_pending()
        reg = default_registry()
        assert reg["repro_plan_rebind_total"].value(
            program="prog", fingerprint="B") == 1
        assert reg["repro_lowering_cache_misses_total"].value(
            program="prog") == 2


# ---------------------------------------------------------------------------
# probe hardening
# ---------------------------------------------------------------------------

class TestProbePolicy:
    def test_retries_then_succeeds(self):
        sleeps = []
        policy = ProbePolicy(retries=2, backoff_s=0.01, jitter=0.0,
                             sleep=sleeps.append)
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise ProbeTimeout("dark")
            return 42.0

        assert policy.run(flaky) == 42.0
        assert len(calls) == 3
        assert sleeps == pytest.approx([0.01, 0.02])  # exponential

    def test_exhausted_reraises(self):
        policy = ProbePolicy(retries=1, backoff_s=0.0, jitter=0.0,
                             sleep=lambda s: None)
        with pytest.raises(ProbeTimeout):
            policy.run(lambda: (_ for _ in ()).throw(ProbeTimeout("x")))

    def test_measure_safely_counts_dead_link_timeouts(self):
        topo = get_fabric("2x8")
        truth = GroundTruth().with_dead([(0, 8)])
        probe = SimProbe(truth)
        led = rail_probe_ledger(topo, (0, 8))
        policy = ProbePolicy(retries=1, backoff_s=0.0, jitter=0.0,
                             sleep=lambda s: None)
        out = measure_safely(probe, "linkprobe", "p2p", 1 << 20, topo,
                             policy=policy, ledger=led, knobs={},
                             src_node=0, dst_node=8)
        assert out is None
        reg = default_registry()
        assert reg["repro_probe_failures_total"].value(
            reason="timeout", fabric=topo.name) == 1
        # a healthy rail still measures
        led_ok = rail_probe_ledger(topo, (1, 9))
        assert measure_safely(probe, "linkprobe", "p2p", 1 << 20, topo,
                              policy=policy, ledger=led_ok, knobs={},
                              src_node=1, dst_node=9) > 0


class TestAttributedBottleneck:
    def test_measured_bandwidths_pick_the_truly_slow_direction(self):
        topo = get_fabric("2x8")
        # healthy direction carries MORE bytes — nominal attribution
        # would blame it; under measured bandwidths the 4x-slower
        # reverse direction dominates the time
        led = plan_ir.Ledger(topo=topo,
                             link_bytes={(0, 8): 1000.0, (8, 0): 1100.0},
                             relay_bytes={},
                             flow_counts={(0, 8): 1, (8, 0): 1})
        assert attributed_bottleneck(led, None) == (8, 0)
        hw = SimProbe(GroundTruth()).truth.hw.recalibrated(
            {"links": {(0, 8): topo.link(0, 8).bw / 4.0}})
        assert attributed_bottleneck(led, hw) == (0, 8)


# ---------------------------------------------------------------------------
# detection + the monitor failover arc
# ---------------------------------------------------------------------------

def _fast_policy():
    return ProbePolicy(retries=0, backoff_s=0.0, jitter=0.0,
                       sleep=lambda s: None)


class TestFailureDetector:
    def test_strike_hysteresis_and_revival(self):
        topo = get_fabric("2x8")
        det = FailureDetector(topo, strikes=2, policy=_fast_policy())
        dark = SimProbe(GroundTruth().with_dead([(0, 8)]))
        assert det.scan(dark) is False          # strike 1: not yet dead
        assert det.scan(dark) is True           # strike 2: declared
        assert det.dead_links() == frozenset({(0, 8)})
        assert det.failures().link_is_dead((0, 8))
        healthy = SimProbe(GroundTruth())
        assert det.scan(healthy) is True        # one success revives
        assert not det.dead_links()
        kinds = [e["kind"] for e in det.events]
        assert kinds == ["link_dead", "link_recovered"]

    def test_monitor_retargets_and_flips_back(self):
        topo = get_fabric("2x8")
        planner = pl.Planner()
        det = FailureDetector(topo, strikes=1, policy=_fast_policy())
        monitor = DriftMonitor(planner, CalibrationStore(":memory:"),
                               topo, detector=det)
        program = moe_program()
        eplan = planner.plan_program(program, topo)
        assert planner.plan_is_stale(eplan) is False

        dark = SimProbe(GroundTruth(seed=1).with_dead([(0, 8), (8, 0)]))
        monitor.run_cycle(dark)
        assert monitor.topo.fingerprint() != topo.fingerprint()
        # the bound plan is now stale: the program was retargeted
        assert planner.plan_is_stale(eplan) is True
        staged = monitor.staged_plan(program.name)
        assert staged is not None
        assert staged.fingerprint != eplan.fingerprint
        fs = FailureState(dead_links={(0, 8), (8, 0)})
        for role, led in pl.plan_site_ledgers(staged,
                                              monitor.topo).items():
            assert pl.ledger_infeasible(led, fs) is None, role
        assert monitor.events[-1]["kind"] == "failover"

        healthy = SimProbe(GroundTruth(seed=2))
        monitor.run_cycle(healthy)
        assert monitor.topo.fingerprint() == topo.fingerprint()
        assert monitor.events[-1]["kind"] == "failback"
        back = monitor.staged_plan(program.name)
        decisions = lambda p: {r: (p.decisions[r].plan,          # noqa: E731
                                   tuple(p.decisions[r].knobs))
                               for r in sorted(p.decisions)}
        assert decisions(back) == decisions(eplan)
