"""Tests for optimizers, data pipeline, checkpointing, and the FT trainer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM, batch_for_model
from repro.models.api import build_model
from repro.optim import adamw, adafactor, lion, sgd, chain_clip, \
    cosine_schedule
from repro.optim.optimizers import apply_updates, global_norm
from repro.runtime.trainer import (
    Trainer, TrainerConfig, TransientFault, make_train_step, StragglerLedger)
from repro.runtime.server import ServeEngine, ServeConfig


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def quadratic_params():
    return {"a": jnp.asarray([2.0, -3.0]), "b": {"c": jnp.asarray([[1.5]])}}


@pytest.mark.parametrize("make_opt,steps,tol", [
    (lambda: adamw(lr=0.1), 200, 1e-2),
    (lambda: adafactor(lr=0.3), 800, 5e-2),   # relative-update optimizer
    (lambda: lion(lr=0.05), 200, 1e-2),
    (lambda: sgd(lr=0.3, momentum=0.9), 200, 1e-2),
])
def test_optimizers_minimize_quadratic(make_opt, steps, tol):
    opt = make_opt()
    params = quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(x))
                   for x in jax.tree_util.tree_leaves(p))

    for step in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, step)
        params = apply_updates(params, upd)
    assert float(loss(params)) < tol


def test_adamw_bf16_state():
    opt = adamw(lr=0.1, opt_dtype=jnp.bfloat16)
    params = quadratic_params()
    state = opt.init(params)
    assert state["m"]["a"].dtype == jnp.bfloat16
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    upd, state = opt.update(g, state, params, 0)
    assert np.isfinite(np.asarray(upd["a"])).all()


def test_clip_and_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)
    opt = chain_clip(sgd(lr=1.0), max_norm=1.0)
    params = {"a": jnp.zeros(4)}
    state = opt.init(params)
    upd, _ = opt.update({"a": jnp.full((4,), 100.0)}, state, params, 0)
    assert float(global_norm(upd)) == pytest.approx(1.0, rel=1e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic_in_step(self):
        d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=4))
        a = d.batch(7)
        b = d.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_host_sharding_reconstructs_global(self):
        d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=8))
        full = d.batch(3, 0, 1)
        parts = [d.batch(3, h, 4)["tokens"] for h in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(DataConfig(vocab=100, seq_len=16, global_batch=2))
        b = d.batch(0)
        # labels[t] continues tokens[t] (same underlying stream)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_model_batch_adapters(self):
        d = SyntheticLM(DataConfig(vocab=512, seq_len=8, global_batch=2))
        raw = d.batch(0)
        for arch in ("qwen2_vl_2b", "seamless_m4t_medium", "rwkv6_7b"):
            cfg = get_config(arch).reduced()
            batch = batch_for_model(cfg, raw)
            assert "labels" in batch


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def make_tree(self, x=0.0):
        return {"w": jnp.full((4, 3), x), "nested": {"b": jnp.arange(5.0)},
                "step": jnp.asarray(7, jnp.int32)}

    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        tree = self.make_tree(1.5)
        cm.save(10, tree, extra={"note": "hi"})
        got, extra = cm.restore(10, jax.tree_util.tree_map(jnp.zeros_like,
                                                           tree))
        assert extra["note"] == "hi"
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_commit_no_partial_visible(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, self.make_tree())
        names = os.listdir(tmp_path)
        assert names == ["step_00000001"]

    def test_keep_last_k_gc(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last_k=2)
        for s in (1, 2, 3, 4):
            cm.save(s, self.make_tree())
        assert cm.all_steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(5, self.make_tree())
        # flip bytes in the shard
        shard = tmp_path / "step_00000005" / "shard_00000.npz"
        data = dict(np.load(shard))
        data["w"] = data["w"] + 1
        np.savez(shard, **data)
        with pytest.raises(IOError):
            cm.restore(5, self.make_tree())

    def test_async_write(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), async_write=True)
        cm.save(2, self.make_tree())
        cm.wait()
        assert cm.all_steps() == [2]


# ---------------------------------------------------------------------------
# fault-tolerant trainer
# ---------------------------------------------------------------------------

def tiny_setup(tmp_path=None, total=12, ckpt_every=4):
    cfg = get_config("mistral_nemo_12b").reduced(
        n_layers=1, d_model=32, n_heads=2, d_ff=64, vocab=128)
    model = build_model(cfg, dtype=jnp.float32)
    data = SyntheticLM(DataConfig(vocab=128, seq_len=16, global_batch=4))
    tcfg = TrainerConfig(total_steps=total, checkpoint_every=ckpt_every,
                         checkpoint_dir=str(tmp_path) if tmp_path else None,
                         log_every=1000)
    make_batch = lambda s: batch_for_model(cfg, data.batch(s))  # noqa: E731
    return model, data, tcfg, make_batch


class TestTrainer:
    def test_loss_decreases(self, tmp_path):
        model, data, tcfg, mb = tiny_setup(tmp_path, total=30)
        tr = Trainer(model, adamw(lr=3e-3), mb, tcfg)
        hist = tr.run()
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.1, (first, last)

    def test_checkpoint_resume_bitexact(self, tmp_path):
        model, data, tcfg, mb = tiny_setup(tmp_path, total=8, ckpt_every=4)
        tr1 = Trainer(model, adamw(lr=1e-3), mb, tcfg,
                      init_rng=jax.random.key(1))
        tr1.run()
        final1 = jax.tree_util.tree_leaves(tr1.state.params)

        # second trainer: resumes from step 8 checkpoint, runs 0 more steps
        tr2 = Trainer(model, adamw(lr=1e-3), mb, tcfg,
                      init_rng=jax.random.key(999))  # init overwritten
        assert int(tr2.state.step) == 8
        final2 = jax.tree_util.tree_leaves(tr2.state.params)
        for a, b in zip(final1, final2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_interrupted_run_resumes_and_matches_uninterrupted(self, tmp_path):
        """Gold FT test: crash at step 6, resume, final params == a run
        that never crashed (deterministic data replay)."""
        model, data, tcfg, mb = tiny_setup(tmp_path / "a", total=10,
                                           ckpt_every=2)

        class Crash(Exception):
            pass

        boom = {"armed": True}

        def fault(step):
            if step == 6 and boom["armed"]:
                boom["armed"] = False
                raise Crash()

        tr = Trainer(model, sgd(lr=1e-2), mb, tcfg,
                     init_rng=jax.random.key(3), fault_hook=fault)
        with pytest.raises(Crash):
            tr.run()
        # "new process": fresh trainer, same dir -> resumes at step 6
        tr2 = Trainer(model, sgd(lr=1e-2), mb, tcfg,
                      init_rng=jax.random.key(3))
        assert int(tr2.state.step) == 6
        tr2.run()

        model3, _, tcfg3, mb3 = tiny_setup(tmp_path / "b", total=10,
                                           ckpt_every=2)
        tr3 = Trainer(model3, sgd(lr=1e-2), mb3, tcfg3,
                      init_rng=jax.random.key(3))
        tr3.run()
        for a, b in zip(jax.tree_util.tree_leaves(tr2.state.params),
                        jax.tree_util.tree_leaves(tr3.state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)

    def test_transient_fault_retried(self, tmp_path):
        model, data, tcfg, mb = tiny_setup(tmp_path, total=6, ckpt_every=2)
        fails = {"n": 0}

        def flaky(step):
            if step == 3 and fails["n"] < 1:
                fails["n"] += 1
                raise TransientFault("injected")

        tr = Trainer(model, sgd(lr=1e-2), mb, tcfg, fault_hook=flaky)
        hist = tr.run()
        assert fails["n"] == 1
        assert len(hist) == 6          # all steps completed

    def test_straggler_detection(self):
        led = StragglerLedger(threshold=3.0)
        outliers = []
        for step in range(30):
            dt = 0.1 if step != 20 else 2.0
            if led.record(step, dt):
                outliers.append(step)
        assert outliers == [20]


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

class TestServer:
    @pytest.mark.parametrize("arch", ["mistral_nemo_12b", "rwkv6_7b",
                                      "zamba2_7b"])
    def test_generate_shapes_and_determinism(self, arch):
        cfg = get_config(arch).reduced(n_layers=2, d_model=32, n_heads=2,
                                       d_ff=64, vocab=64)
        model = build_model(cfg, dtype=jnp.float32)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(model, params, ServeConfig(max_new_tokens=5))
        prompts = np.random.default_rng(0).integers(
            0, 64, size=(2, 8)).astype(np.int32)
        out1 = eng.generate(prompts)
        eng2 = ServeEngine(model, params, ServeConfig(max_new_tokens=5))
        out2 = eng2.generate(prompts)
        assert out1.shape == (2, 5)
        np.testing.assert_array_equal(out1, out2)   # greedy deterministic
