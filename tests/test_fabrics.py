"""Tests for the parametric fabric family + combine-path planning (ISSUE 2).

Covers:
  * ClusterSpec generation: multi-rail striping, N-server rail-first
    routing, per-rail / asymmetric bandwidths, fabric metadata.
  * parse_fabric / get_fabric (the --fabric CLI surface).
  * The "combine" planner op: executable kwargs, Fig 8-style flip,
    independence from the dispatch decision, ledger mirror property.
  * HardwareModel.recalibrated round-trip (measured bandwidths fold back
    into scoring) and Topology.with_link_bws.
  * moe_ffn tracing with planner-chosen dispatch AND combine schemes
    under plan_policy="auto", and the hierarchical_combine_unicast
    lowering agreeing with hierarchical_combine.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core import latency_model as lm
from repro.core import plan as plan_ir
from repro.core import planner as pl
from repro.core import schedules as sch
from repro.core.multiwrite import MultiWriteSimulator
from repro.core.topology import (
    FABRICS, ClusterSpec, Topology, full_mesh, get_fabric, parse_fabric,
    two_server_cluster,
)


# ---------------------------------------------------------------------------
# fabric family generation
# ---------------------------------------------------------------------------

class TestClusterSpec:
    def test_two_server_is_single_rail_instance(self):
        """two_server_cluster() == ClusterSpec(2, 8, 1): same links, same
        forwarding decisions."""
        a = two_server_cluster()
        b = ClusterSpec(num_servers=2, npus_per_server=8,
                        name="two_server").build()
        assert set(a.links) == set(b.links)
        for src in range(16):
            for dst in range(16):
                if src != dst:
                    assert a.next_hop(src, dst) == b.next_hop(src, dst)

    def test_meta_attached(self):
        t = ClusterSpec(num_servers=3, npus_per_server=4,
                        rails_per_npu=2).build()
        assert t.meta.num_servers == 3
        assert t.meta.npus_per_server == 4
        assert t.meta.rails_per_npu == 2
        assert t.server_of(9) == 2
        assert t.server_nodes(1) == [4, 5, 6, 7]
        assert t.rail_peers(1, 2) == [9, 10]
        # full mesh gets a single-server meta
        m = full_mesh(6)
        assert m.meta.num_servers == 1 and m.meta.npus_per_server == 6

    def test_multi_rail_links_and_striping(self):
        """r rails per NPU per remote server; the forwarding override
        stripes a remote server's destinations over the r rails."""
        t = ClusterSpec(rails_per_npu=2).build()          # 2x8r2
        assert t.has_link(0, 8) and t.has_link(0, 9)      # rails of node 0
        assert not t.has_link(0, 10)
        groups = t.partition_by_next_hop(0, list(range(8, 16)))
        assert set(groups) == {8, 9}                      # 2 busy rails
        assert sorted(groups[8]) == [8, 10, 12, 14]       # even stripe
        assert sorted(groups[9]) == [9, 11, 13, 15]       # odd stripe

    def test_n_server_rail_first(self):
        """Every server pair is rail-connected; cross-server routes go
        rail-first (one hop onto the destination server, then intra)."""
        t = ClusterSpec(num_servers=4, npus_per_server=8).build()
        assert t.num_nodes == 32
        for sv in (1, 2, 3):
            path = t.path(3, sv * 8 + 5)
            assert len(path) == 3                          # rail + intra hop
            assert path[1] == sv * 8 + 3                   # own-index rail
        groups = t.partition_by_next_hop(0, list(range(8, 32)))
        assert set(groups) == {8, 16, 24}                  # one rail/server

    def test_per_rail_bandwidths(self):
        t = ClusterSpec(rails_per_npu=2, inter_bw=(25e9, 12.5e9)).build()
        assert t.link(0, 8).bw == 25e9                     # rail 0
        assert t.link(0, 9).bw == 12.5e9                   # rail 1

    def test_asymmetry_scales_links(self):
        t = get_fabric("2x8asym")
        assert t.link(8, 0).bw == pytest.approx(t.link(0, 8).bw * 0.5)
        # asymmetric fabrics fingerprint differently from symmetric ones
        assert t.fingerprint() != two_server_cluster().fingerprint()

    def test_degenerate_specs_rejected(self):
        with pytest.raises(ValueError):
            ClusterSpec(num_servers=2, npus_per_server=4, rails_per_npu=5)
        with pytest.raises(ValueError):
            ClusterSpec(num_servers=0)

    def test_with_link_bws_recalibration(self):
        t = two_server_cluster()
        t2 = t.with_link_bws({"0->8": 20e9, (8, 0): 10e9})
        assert t2.link(0, 8).bw == 20e9
        assert t2.link(8, 0).bw == 10e9
        assert t.link(0, 8).bw == 25e9                     # original intact
        assert t2.fingerprint() != t.fingerprint()         # cache keys split
        with pytest.raises(KeyError):
            t.with_link_bws({(0, 9): 1e9})                 # no such link


class TestParseFabric:
    def test_basic_shapes(self):
        s = parse_fabric("4x8")
        assert (s.num_servers, s.npus_per_server, s.rails_per_npu) == (4, 8, 1)
        s = parse_fabric("2x8r2")
        assert s.rails_per_npu == 2

    def test_bandwidths(self):
        s = parse_fabric("2x8r2@25,12.5:56")
        assert s.inter_bw == (25e9, 12.5e9)
        assert s.intra_bw == 56e9
        assert parse_fabric("2x8@12.5").inter_bw == 12.5e9

    def test_bad_specs_raise(self):
        for bad in ("x8", "2x", "2x8r", "2x8@abc", "mesh"):
            with pytest.raises(ValueError):
                parse_fabric(bad)

    def test_registry_and_acceptance_fabrics(self):
        """A 4-server and a 2-rail fabric are registered scenarios."""
        assert "4x8" in FABRICS and "2x8r2" in FABRICS
        t = get_fabric("4x8")
        assert t.meta.num_servers == 4
        assert get_fabric("2x8r2").meta.rails_per_npu == 2
        # inline specs resolve too
        assert get_fabric("3x4").num_nodes == 12


# ---------------------------------------------------------------------------
# combine as a planner op
# ---------------------------------------------------------------------------

class TestCombinePlanning:
    def test_combine_plans_registered(self):
        assert {p.name for p in plan_ir.plans_for("combine")} >= \
            {"unicast", "multiwrite"}
        assert plan_ir.BASELINE_PLAN["combine"] == "unicast"

    def test_choose_combine_returns_executable_kwargs(self):
        """Acceptance: Planner.choose("combine", ...) yields a decision
        with executable shard_map kwargs."""
        planner = pl.Planner()
        topo = two_server_cluster()
        d = planner.choose("combine", 2048 * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES)
        assert d.op == "combine"
        assert d.shard_map_kwargs["moe_combine"] in ("hierarchical",
                                                     "baseline")
        assert d.plan == "multiwrite"
        assert d.delta_vs_baseline > 0

    def test_combine_fig8_flip(self):
        """Small batches stay on the unicast return, large flip to the
        relay-reduced return (Fig 8 mirrored onto the combine path)."""
        planner = pl.Planner()
        topo = two_server_cluster()
        small = planner.choose("combine", 8 * lm.TOKEN_BYTES, topo,
                               token_bytes=lm.TOKEN_BYTES)
        large = planner.choose("combine", 2048 * lm.TOKEN_BYTES, topo,
                               token_bytes=lm.TOKEN_BYTES)
        assert small.plan == "unicast"
        assert large.plan == "multiwrite"

    def test_dispatch_and_combine_flip_independently(self):
        """On a high-bandwidth-rail fabric the dispatch keeps its unicast
        plan while the combine still flips: the two halves face different
        redundancy structures, hence different crossovers — the reason
        combine is a first-class op."""
        planner = pl.Planner()
        topo = get_fabric("2x8@50")
        dflip = pl.emergent_flip_batch("dispatch", topo, planner=planner)
        cflip = pl.emergent_flip_batch("combine", topo, planner=planner)
        assert cflip < dflip
        d = planner.choose("dispatch", 2048 * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES)
        c = planner.choose("combine", 2048 * lm.TOKEN_BYTES, topo,
                           token_bytes=lm.TOKEN_BYTES)
        assert d.plan == "unicast" and c.plan == "multiwrite"

    def test_flip_moves_with_inter_bw(self):
        """Acceptance: the Fig 8-style flip point moves with inter-server
        bandwidth (slower rails -> earlier flip)."""
        planner = pl.Planner()
        flips = [pl.emergent_flip_batch("dispatch", get_fabric(s),
                                        planner=planner)
                 for s in ("2x8@6.25", "2x8@12.5", "2x8", "2x8@50")]
        assert flips == sorted(flips)
        assert flips[0] < flips[-1]

    def test_combine_mirror_of_dispatch_single_rail(self):
        """Symmetric single-rail fabric: the multiwrite combine ledger is
        the exact link-reverse of the multiwrite dispatch ledger."""
        topo = two_server_cluster()
        routing = sch.make_routing(8, 16, 64, 8, seed=11)
        disp, comb = MultiWriteSimulator(topo), MultiWriteSimulator(topo)
        sch.dispatch_multiwrite(disp, routing, 512)
        sch.combine_multiwrite(comb, routing, 512)
        sch.check_combine(comb, routing, 512)
        assert dict(comb.link_bytes) == \
            {(b, a): v for (a, b), v in disp.link_bytes.items()}

    def test_combine_dedup_on_rail(self):
        """Multiwrite combine puts fewer return bytes on every rail than
        unicast combine (the §3.2 single-copy property, mirrored)."""
        topo = two_server_cluster()
        routing = sch.make_routing(16, 16, 64, 8, seed=2)
        uni, mw = MultiWriteSimulator(topo), MultiWriteSimulator(topo)
        sch.combine_unicast(uni, routing, 256)
        sch.combine_multiwrite(mw, routing, 256)
        sch.check_combine(uni, routing, 256)

        def rail_total(sim):
            return sum(v for (a, b), v in sim.link_bytes.items()
                       if topo.server_of(a) != topo.server_of(b))

        assert rail_total(mw) < rail_total(uni)
        assert 2.5 <= rail_total(uni) / rail_total(mw) <= 6.0

    def test_multi_rail_combine_stripes(self):
        """On a 2-rail fabric the combine relays stripe the reverse rails
        like the dispatch stripes the forward rails."""
        topo = get_fabric("2x8r2")
        routing = sch.make_routing(8, 16, 64, 8, seed=5)
        disp, comb = MultiWriteSimulator(topo), MultiWriteSimulator(topo)
        sch.dispatch_multiwrite(disp, routing, 512)
        sch.combine_multiwrite(comb, routing, 512)

        def cross(sim, pred):
            return sum(v for (a, b), v in sim.link_bytes.items()
                       if topo.server_of(a) != topo.server_of(b)
                       and pred(a, b))

        total_fwd = cross(disp, lambda a, b: True)
        total_back = cross(comb, lambda a, b: True)
        assert total_back == total_fwd                    # same crossings
        # both directions use both stripes of node 0's rail pair
        used_fwd = {k for k in disp.link_bytes if k[0] == 0 and k[1] >= 8}
        assert used_fwd == {(0, 8), (0, 9)}

    def test_every_plan_simulates_on_every_registered_fabric(self):
        """The CI gate's property, pinned as a test: no registered plan
        raises on any registered fabric's default scenarios."""
        for fname in sorted(FABRICS):
            topo = get_fabric(fname)
            scenarios = plan_ir.default_scenarios(topo)
            for (op, pname), plan in sorted(plan_ir.PLAN_REGISTRY.items()):
                ledger = plan.simulate(scenarios[op], 1 << 16)
                assert lm.score_ledger(ledger) >= 0.0, (fname, op, pname)


# ---------------------------------------------------------------------------
# HardwareModel.recalibrated
# ---------------------------------------------------------------------------

class TestRecalibration:
    def test_roundtrip_through_benchmark_json(self, tmp_path):
        """Measured bandwidths written to a benchmark JSON fold back into
        the model and change scoring; a no-op recalibration is identity."""
        meas = {"alpha_hop": 5e-6, "copy_bw": 1.2e12,
                "links": {"0->8": 12.5e9, "8->0": 20e9}}
        path = tmp_path / "measured.json"
        path.write_text(json.dumps(meas))
        hw = lm.DEFAULT.recalibrated(json.loads(path.read_text()))
        assert hw.alpha_hop == 5e-6
        assert hw.copy_bw == 1.2e12
        assert dict(hw.link_bw) == {(0, 8): 12.5e9, (8, 0): 20e9}
        assert hw.alpha_base == lm.DEFAULT.alpha_base      # untouched
        assert lm.DEFAULT.recalibrated({}) == lm.DEFAULT
        # models stay hashable (they key the planner cache)
        hash(hw)

    def test_recalibrated_validates_links_against_topology(self):
        topo = two_server_cluster()
        hw = lm.DEFAULT.recalibrated({"links": {"0->8": 20e9}}, topo=topo)
        assert dict(hw.link_bw) == {(0, 8): 20e9}
        with pytest.raises(KeyError):
            lm.DEFAULT.recalibrated({"links": {"0->80": 20e9}}, topo=topo)

    def test_measured_bw_drives_scoring(self):
        """A measured slowdown on the rail shows up in score_ledger."""
        topo = two_server_cluster()
        sim = MultiWriteSimulator(topo)
        sim.multiwrite(0, {d: "x" for d in (9, 10, 12)},
                       np.zeros(1 << 20, np.uint8))
        ledger = plan_ir.Ledger.from_sim(sim)
        base = lm.score_ledger(ledger, lm.DEFAULT)
        slow = lm.DEFAULT.recalibrated({"links": {"0->8": 25e9 / 10}})
        assert lm.score_ledger(ledger, slow) > base * 5

    def test_recalibrated_model_invalidates_planner_cache(self):
        planner = pl.Planner()
        topo = two_server_cluster()
        planner.choose("dispatch", 2 ** 20, topo)
        hw = lm.DEFAULT.recalibrated({"alpha_hop": 1e-6})
        planner.choose("dispatch", 2 ** 20, topo, hw)
        assert planner.cache_info()["misses"] == 2


# ---------------------------------------------------------------------------
# end-to-end: moe_ffn resolves dispatch AND combine through the planner
# ---------------------------------------------------------------------------

def _mesh_pctx(**kw):
    import jax

    from repro.launch.mesh import make_test_mesh
    from repro.parallel.context import ParallelContext
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = make_test_mesh(shape=(1, 1), axes=("data", "model"))
    return ParallelContext(mesh=mesh, pod_axis=None, **kw)


class TestContextCombine:
    def test_combine_fixed_follows_dispatch(self):
        pctx = _mesh_pctx()
        assert pctx.moe_pipeline_kwargs(
            64, 8, 1024, 7168)["moe_combine"] == "hierarchical"
        pctx2 = dataclasses.replace(pctx, moe_scheme="baseline")
        assert pctx2.moe_pipeline_kwargs(
            64, 8, 1024, 7168)["moe_combine"] == "baseline"
        pctx3 = dataclasses.replace(pctx, moe_combine="baseline")
        assert pctx3.moe_pipeline_kwargs(
            64, 8, 1024, 7168)["moe_combine"] == "baseline"

    def test_auto_policy_with_fabric_resolves_both(self):
        """Acceptance: under plan_policy="auto" both halves come from the
        planner (jointly, one shared pipeline); an explicit fabric moves
        the decisions."""
        fabric = two_server_cluster()
        pctx = _mesh_pctx(plan_policy="auto", fabric=fabric)
        big = pctx.moe_pipeline_kwargs(64, 8, 2048, 7168)
        assert big["moe_scheme"] == "hierarchical"
        assert big["moe_combine"] == "hierarchical"
        small = pctx.moe_pipeline_kwargs(64, 8, 8, 7168)
        assert small["moe_scheme"] == "baseline"
        assert small["moe_combine"] == "baseline"
        # the per-site combine view of the joint plan
        sites = pctx.moe_sites("t", num_experts=64, top_k=8,
                               tokens_per_rank=2048, token_bytes=7168)
        eplan = pctx.plan_collectives(
            plan_ir.CollectiveProgram("t", sites))
        d = eplan.decision("t/moe_combine")
        assert d.op == "combine"
        assert d.shard_map_kwargs["moe_combine"] == "hierarchical"

    def test_moe_ffn_traces_with_planner_combine(self):
        """moe_ffn runs under plan_policy="auto" with a fabric, resolving
        dispatch and combine through the planner, and the
        hierarchical_combine_unicast lowering agrees numerically with the
        relay-reduced combine."""
        import types

        import jax
        import jax.numpy as jnp

        from repro.models import moe

        cfg = types.SimpleNamespace(num_experts=8, top_k=2, act="silu",
                                    moe_capacity=2.0)
        key = jax.random.key(0)
        params = moe.init_moe(key, d=8, f=16, num_experts=8)
        x = jax.random.normal(jax.random.key(1), (2, 8, 8), jnp.float32)

        pctx_auto = _mesh_pctx(plan_policy="auto",
                               fabric=two_server_cluster())
        out_auto, aux = moe.moe_ffn(params, x, cfg, pctx_auto)
        assert out_auto.shape == x.shape
        assert np.isfinite(np.asarray(out_auto)).all()

        # fixed hierarchical dispatch, both combine lowerings
        pctx_h = _mesh_pctx(moe_scheme="hierarchical")
        pctx_hu = dataclasses.replace(pctx_h, moe_combine="baseline")
        out_h, _ = moe.moe_ffn(params, x, cfg, pctx_h)
        out_hu, _ = moe.moe_ffn(params, x, cfg, pctx_hu)
        np.testing.assert_allclose(np.asarray(out_h), np.asarray(out_hu),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# split-TP allgather in the model layers (tp_subgroups path)
# ---------------------------------------------------------------------------

class TestSplitTPAllgatherLayer:
    def test_degenerate_single_domain(self):
        """tp_subgroups == 1: plain full gather, no planner consulted."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P

        from repro.models import layers as L
        from repro.parallel.compat import shard_map
        pctx = _mesh_pctx()
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4)

        fn = shard_map(lambda a: L.split_tp_allgather(a, pctx),
                       mesh=pctx.mesh, in_specs=P("model"),
                       out_specs=P("model"), check_vma=False)
        with pctx.mesh:
            out = jax.jit(fn)(x)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x[None]))

    def test_planner_routed_branch_selection(self):
        """Under "auto" the layer goes through planned_allgather — the
        planner decision (not a hard-coded mode) selects the lowering."""
        from repro.core.topology import split_tp_full_mesh
        topo, _ = split_tp_full_mesh(8, tp=4)
        planner = pl.Planner()
        small = planner.choose("allgather", 64 * 2 ** 10, topo,
                               executable_only=True)
        big = planner.choose("allgather", 16 * 2 ** 20, topo,
                             executable_only=True)
        assert small.shard_map_kwargs["mode"] is None
        assert big.shard_map_kwargs["mode"] in ("paired", "full")
