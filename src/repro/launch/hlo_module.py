"""Optimized-HLO module parser with loop-multiplicity-aware costing.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts a ``while``
body ONCE regardless of trip count — fatal for scanned-layer models (a
40-layer scan under-reports by 40x).  This module re-derives the roofline
inputs directly from the optimized HLO text, weighting every computation
by the product of enclosing loop trip counts (parsed from the while op's
``backend_config known_trip_count``, falling back to the condition's
``compare(counter, constant(N)) direction=LT``):

  * FLOPs      — 2*M*N*K per dot (operand shapes + contracting dims),
                 counted in every reachable computation;
  * HBM bytes  — per instruction in EXECUTION computations (entry, while
                 bodies, called branches): output + operand bytes.
                 Instructions inside fusion/reduce-lambda computations are
                 fused — no standalone HBM traffic.  Post-fusion HLO
                 granularity == XLA's own traffic model;
  * collective wire bytes per mesh axis (ring/pairwise factors), including
    collectives inside scanned bodies.

Validated against cost_analysis on non-looped programs in
tests/test_hlo_analysis.py.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

from repro.launch.hlo_analysis import (
    COLLECTIVE_OPS, DTYPE_BYTES, MeshLayout, _parse_groups)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPND = re.compile(r"%([\w.\-]+)")
_TRIP_BC = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
_CONSTANT_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")

FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
            "constant", "after-all", "iota", "while", "optimization-barrier",
            "partition-id", "replica-id",
            # `call` is transparent: its callee is visited as an execution
            # computation, so counting the call site too would double-count
            # (XLA:CPU wraps thread-parallel ops in %parallel_* calls).
            "call"}

# ops whose to_apply/calls computations are scalar lambdas or fused bodies:
# their internals produce no standalone HBM traffic
_LAMBDA_CALLERS = {"fusion", "reduce", "scatter", "sort", "map",
                   "reduce-window", "select-and-scatter", "all-reduce",
                   "reduce-scatter"}


def _shapes_in(text: str):
    out = []
    for m in _SHAPE.finditer(text):
        if m.group(1) in DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",") if d] \
                if m.group(2) else []
            out.append((m.group(1), dims))
    return out


def _nbytes(shapes) -> int:
    return sum(DTYPE_BYTES[d] * (math.prod(s) if s else 1)
               for d, s in shapes)


def _split_type_op(rhs: str):
    """rhs = '<type> <opname>(<args>), <attrs>'.  Types may be tuples
    '(f32[..], s32[])'.  Returns (type_str, opname, rest_after_paren)."""
    s = rhs.lstrip()
    if s.startswith("("):                 # tuple type: skip balanced parens
        depth = 0
        for i, ch in enumerate(s):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = s[:i + 1]
                    rest = s[i + 1:].lstrip()
                    break
        else:
            return None
    else:
        # type ends at the last space before the first '('
        paren = s.find("(")
        if paren <= 0:
            return None
        type_str = s[:paren].rsplit(None, 1)[0] if " " in s[:paren] else ""
        rest = s[len(type_str):].lstrip()
    paren = rest.find("(")
    if paren <= 0:
        return None
    op = rest[:paren].strip().strip("%")
    if not op or not re.fullmatch(r"[\w\-]+", op):
        return None
    return type_str, op, rest[paren:]


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    op: str
    out_shapes: list
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symbols: dict


def parse_module(text: str):
    comps: dict[str, Computation] = {}
    fused_names: set[str] = set()
    entry_name = None
    cur: Computation | None = None
    for line in text.splitlines():
        ls = line.strip()
        if not ls:
            continue
        if ls.endswith("{") and "->" in ls:
            m = _COMP_HDR.match(ls)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry_name = cur.name
                continue
        if ls == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(ls)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        parsed = _split_type_op(rhs)
        if parsed is None:
            continue
        type_str, op, args = parsed
        out_shapes = _shapes_in(type_str)
        # operands: %refs inside the first balanced arg parens
        depth = 0
        end = 0
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPND.findall(args[:end + 1])
        attrs = args[end + 1:]
        # mark lambda/fusion-called computations
        if op in _LAMBDA_CALLERS:
            for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", attrs):
                fused_names.add(cm.group(1))
        ins = Instr(name, rhs, op, out_shapes, operands)
        cur.instrs.append(ins)
        cur.symbols[name] = out_shapes
    return comps, fused_names, entry_name


def _while_parts(ins: Instr):
    bm = re.search(r"body=%?([\w.\-]+)", ins.rhs)
    cm = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
    tm = _TRIP_BC.search(ins.rhs)
    return (bm.group(1) if bm else None, cm.group(1) if cm else None,
            int(tm.group(1)) if tm else None)


def _trip_from_cond(cond: Computation) -> int:
    bound = None
    for ins in cond.instrs:
        m = _CONSTANT_S32.search(ins.rhs)
        if m:
            bound = int(m.group(1))
    return bound or 1


@dataclasses.dataclass
class ModuleCost:
    flops: float
    hbm_bytes: float
    collective_by_axis: dict
    collective_by_kind: dict
    collective_ops: int
    loops: dict
    hbm_tagged: dict = dataclasses.field(default_factory=dict)
    # ^ bytes attributed to source regions by metadata op_name match —
    #   used to discount intermediates a Pallas kernel keeps in VMEM
    #   (flash scores, scan chunk matrices) from the TPU-target roofline.

    @property
    def collective_total(self):
        return sum(self.collective_by_axis.values())


# HLO metadata op_name patterns whose fusion traffic a fused TPU kernel
# would keep in VMEM (tag -> regex).  Transformed (bwd/remat) ops resolve
# only to the CALLER frame, so caller names are included; the discount is
# applied to fusion/copy ops only — dot products (the MXU work, whose
# operands a kernel does stream) remain fully counted (conservative).
VMEM_TAGS = {
    "flash_intermediate": re.compile(
        r"flash_attention_jnp|decode_attention_ref|_cross_attention"
        r"|(?:^|[ .])attention\b"),
    "scan_chunk_intermediate": re.compile(
        r"mamba2_chunked_jnp|rwkv6_chunked_jnp|mamba2_block|time_mix"),
}
_VMEM_DISCOUNT_OPS = {"fusion", "copy", "select", "broadcast", "transpose",
                      "convert", "compare", "reduce", "exponential"}
_METADATA_RE = re.compile(r'op_name="([^"]*)"')
_FRAME_ID_RE = re.compile(r"stack_frame_id=(\d+)")


def parse_stack_tables(text: str):
    """Parse the FunctionNames / FileLocations / StackFrames prelude into
    frame_id -> set of python function names in the frame's ancestor chain.
    """
    fn_names: dict[int, str] = {}
    floc_fn: dict[int, int] = {}
    frames: dict[int, tuple[int, int]] = {}   # id -> (file_loc, parent)
    section = None
    for line in text.splitlines():
        ls = line.strip()
        if ls in ("FunctionNames", "FileLocations", "StackFrames",
                  "FileNames"):
            section = ls
            continue
        if not ls or ls.startswith(("HloModule", "%", "ENTRY", "}")):
            if ls and not ls[0].isdigit():
                section = None
            continue
        if section == "FunctionNames":
            m = re.match(r'(\d+)\s+"(.*)"', ls)
            if m:
                fn_names[int(m.group(1))] = m.group(2)
        elif section == "FileLocations":
            m = re.match(r"(\d+)\s+\{.*function_name_id=(\d+)", ls)
            if m:
                floc_fn[int(m.group(1))] = int(m.group(2))
        elif section == "StackFrames":
            m = re.match(
                r"(\d+)\s+\{file_location_id=(\d+)"
                r"(?:\s+parent_frame_id=(\d+))?", ls)
            if m:
                frames[int(m.group(1))] = (int(m.group(2)),
                                           int(m.group(3) or 0))
    resolved: dict[int, set] = {}

    def chain(fid: int, depth=0) -> set:
        if fid in resolved:
            return resolved[fid]
        if fid not in frames or depth > 64:
            return set()
        floc, parent = frames[fid]
        names = set()
        fn_id = floc_fn.get(floc)
        if fn_id is not None and fn_id in fn_names:
            names.add(fn_names[fn_id])
        if parent and parent != fid:
            names |= chain(parent, depth + 1)
        resolved[fid] = names
        return names

    for fid in list(frames):
        chain(fid)
    return resolved


def analyze_module(text: str, layout: MeshLayout,
                   default_axis: str = "model",
                   collect_rows: list | None = None,
                   vmem_elem_counts: set | None = None) -> ModuleCost:
    """collect_rows: optional list to append (weighted_bytes, mult, op,
    name, out_bytes, comp) per instruction — the debug_bytes view.

    vmem_elem_counts: element counts of kernel-resident intermediates
    (flash score blocks, scan chunk matrices).  Fusion metadata picks an
    arbitrary representative source op, so SHAPE is the reliable
    discriminator: any discountable op whose output element count matches
    is tagged "shape_vmem"."""
    comps, fused_names, entry_name = parse_module(text)
    if entry_name is None:
        return ModuleCost(0, 0, {}, {}, 0, {})
    vmem_elem_counts = vmem_elem_counts or set()

    mult: dict[str, float] = defaultdict(float)
    loops: dict[str, float] = {}

    def visit(cname: str, m: float, stack: tuple):
        if cname in stack or cname not in comps:
            return
        mult[cname] += m
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op == "while":
                body, cond, trip = _while_parts(ins)
                if trip is None and cond in comps:
                    trip = _trip_from_cond(comps[cond])
                trip = trip or 1
                loops[ins.name] = trip
                if body:
                    visit(body, m * trip, stack + (cname,))
            elif ins.op in ("call", "conditional", "async-start"):
                for cm in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                      ins.rhs):
                    visit(cm.group(1), m, stack + (cname,))
            elif ins.op in _LAMBDA_CALLERS:
                for cm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                      ins.rhs):
                    visit(cm.group(1), m, stack + (cname,))

    visit(entry_name, 1.0, ())

    flops = 0.0
    hbm = 0.0
    by_axis = defaultdict(float)
    by_kind = defaultdict(float)
    hbm_tagged = defaultdict(float)
    frames = parse_stack_tables(text)
    n_coll = 0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        fused = cname in fused_names
        for ins in comp.instrs:
            if ins.op == "dot":
                flops += m * _dot_flops(ins, comp.symbols)
            if fused:
                continue
            if ins.op in FREE_OPS:
                continue
            out_b = _nbytes(ins.out_shapes)
            base_kind = ins.op.replace("-start", "")
            if base_kind in COLLECTIVE_OPS:
                n_coll += 1
                wire, axis = _collective_wire(ins, layout, default_axis)
                by_axis[axis] += m * wire
                by_kind[base_kind] += m * wire
                hbm += m * out_b
                continue
            if ins.op.endswith("-done") or ins.op == "copy-done":
                continue
            if ins.op in ("dynamic-slice", "gather"):
                # reads only the sliced window (= output); the consumer's
                # operand accounting covers the second touch
                cost = m * out_b
            elif ins.op in ("dynamic-update-slice", "scatter") or (
                    ins.op == "fusion"
                    and "dynamic-update-slice" in ins.name):
                # in-place window write (TPU aliases the buffer): traffic =
                # the non-aliased operands twice (read update, write
                # window); operand reads are window-aware (gather rows)
                if ins.op == "fusion":
                    opnds = sorted(_fusion_operand_list(ins, comp, comps),
                                   reverse=True)
                else:
                    opnds = sorted((_nbytes(comp.symbols.get(o, []))
                                    for o in ins.operands), reverse=True)
                small = sum(opnds[1:]) if len(opnds) > 1 else out_b
                cost = m * 2 * min(small, out_b)
            else:
                if ins.op == "fusion":
                    opnd_b = _fusion_operand_bytes(ins, comp, comps)
                else:
                    opnd_b = sum(_nbytes(comp.symbols.get(o, []))
                                 for o in ins.operands)
                cost = m * (out_b + opnd_b)
            hbm += cost
            if ins.op in _VMEM_DISCOUNT_OPS:
                out_elems = sum(math.prod(s) if s else 1
                                for _, s in ins.out_shapes)
                if out_elems in vmem_elem_counts:
                    hbm_tagged["shape_vmem"] += cost
                else:
                    fid_m = _FRAME_ID_RE.search(ins.rhs)
                    if fid_m:
                        names = frames.get(int(fid_m.group(1)), ())
                        if names:
                            joined = " ".join(names)
                            for tag, rx in VMEM_TAGS.items():
                                if rx.search(joined):
                                    hbm_tagged[tag] += cost
                                    break
            if collect_rows is not None:
                collect_rows.append((cost, m, ins.op, ins.name, out_b,
                                     cname))
    return ModuleCost(flops=flops, hbm_bytes=hbm,
                      collective_by_axis=dict(by_axis),
                      collective_by_kind=dict(by_kind),
                      collective_ops=n_coll, loops=loops,
                      hbm_tagged=dict(hbm_tagged))


def _fusion_operand_bytes(ins: Instr, comp: Computation,
                          comps: dict) -> float:
    return sum(_fusion_operand_list(ins, comp, comps))


def _fusion_operand_list(ins: Instr, comp: Computation,
                         comps: dict) -> list:
    """Window-aware read bytes per fusion operand.  An operand whose
    in-fusion consumer is a (dynamic-)slice is read only through the
    sliced window (layer-sliced stacked weights, gather rows!); everything
    else reads fully."""
    fm = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
    fused = comps.get(fm.group(1)) if fm else None
    windows: dict[int, int] = {}
    if fused is not None:
        pidx: dict[str, int] = {}
        for fins in fused.instrs:
            if fins.op == "parameter":
                pm = re.search(r"parameter\((\d+)\)", fins.rhs)
                if pm:
                    pidx[fins.name] = int(pm.group(1))
        passthrough = {"convert", "bitcast", "copy", "transpose", "reshape"}
        for pname, pi in pidx.items():
            # follow single-operand elementwise chains to a slice:
            # convert(param) -> slice(...) reads only the window
            cur = {pname}
            for _ in range(6):
                nxt = set()
                for fins in fused.instrs:
                    if fins.operands and fins.operands[0] in cur:
                        if fins.op in ("slice", "dynamic-slice"):
                            w = _nbytes(fins.out_shapes)
                            windows[pi] = min(windows.get(pi, w), w)
                        elif fins.op in passthrough:
                            nxt.add(fins.name)
                if pi in windows or not nxt:
                    break
                cur = nxt
    out = []
    for i, o in enumerate(ins.operands):
        full = _nbytes(comp.symbols.get(o, []))
        out.append(min(windows[i], full) if i in windows else full)
    return out


def _dot_flops(ins: Instr, symbols: dict) -> float:
    out_elems = sum(math.prod(s) if s else 1 for _, s in ins.out_shapes)
    if not ins.operands:
        return 0.0
    lhs = symbols.get(ins.operands[0])
    if not lhs:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    k = 1
    if m and lhs:
        dims = [int(x) for x in m.group(1).split(",") if x]
        shape = lhs[0][1]
        for d in dims:
            if d < len(shape):
                k *= shape[d]
    return 2.0 * out_elems * k


def _collective_wire(ins: Instr, layout: MeshLayout, default_axis: str):
    out_b = _nbytes(ins.out_shapes)
    groups = _parse_groups(ins.rhs)
    kind = ins.op.replace("-start", "")
    if groups:
        g = max(len(gr) for gr in groups)
        axis = layout.classify(max(groups, key=len))
    else:
        g, axis = 2, default_axis
    if g <= 1:
        return 0.0, axis
    if kind == "all-gather":
        wire = out_b * (g - 1) / g
    elif kind == "reduce-scatter":
        wire = out_b * (g - 1)
    elif kind == "all-reduce":
        wire = 2 * out_b * (g - 1) / g
    elif kind == "all-to-all":
        wire = out_b * (g - 1) / g
    else:
        wire = out_b
    return wire, axis
