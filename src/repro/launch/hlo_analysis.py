"""Parse compiled (SPMD) HLO text for collective byte accounting.

``cost_analysis()`` does not report collective traffic, so the roofline's
collective term comes from here: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute call site is parsed for
its (per-device, post-SPMD) shapes and its replica groups, wire bytes are
estimated with standard ring/pairwise factors, and each op is attributed
to the slowest mesh axis its groups span (the paper's bottleneck-link
view, §3.3):

  pod    groups span multiple pods          -> crosses DCN
  data   single pod, multiple data rows     -> intra-pod ICI
  model  single data row                    -> intra-pod ICI

Wire-byte model (per device, per op):
  all-gather      out_bytes * (g-1)/g          (ring)
  reduce-scatter  in_bytes  * (g-1)/g  = out_bytes*(g-1)
  all-reduce      2 * bytes * (g-1)/g          (ring RS+AG)
  all-to-all      bytes * (g-1)/g
  collective-permute  bytes
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACED = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?")
_SOURCE_TARGET = re.compile(
    r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


def _parse_shapes(text: str) -> list[tuple[str, list[int]]]:
    """All dtype[dims] shapes in a type string."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        out.append((dtype, shape))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dtype, dims in shapes:
        total += DTYPE_BYTES[dtype] * math.prod(dims) if dims else \
            DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str):
    """Returns list of device-id groups, or None."""
    m = _GROUPS_BRACED.search(line)
    if m:
        groups = []
        for g in re.findall(r"\{([^}]*)\}", m.group(1)):
            ids = [int(x) for x in g.split(",") if x.strip()]
            if ids:
                groups.append(ids)
        return groups or None
    m = _GROUPS_IOTA.search(line)
    if m:
        ng, sz = int(m.group(1)), int(m.group(2))
        reshape_dims = [int(x) for x in m.group(3).split(",")]
        n = math.prod(reshape_dims)
        ids = list(range(n))
        if m.group(5):      # transpose permutation
            perm = [int(x) for x in m.group(5).split(",")]
            import numpy as np
            arr = np.arange(n).reshape(reshape_dims).transpose(perm).reshape(-1)
            ids = arr.tolist()
        return [ids[i * sz:(i + 1) * sz] for i in range(ng)]
    m = _SOURCE_TARGET.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", "{" + m.group(1) + "}")
        return [[int(a), int(b)] for a, b in pairs] or None
    return None


@dataclasses.dataclass
class MeshLayout:
    """Row-major device-id layout of the mesh axes."""
    axes: tuple          # e.g. ("pod", "data", "model")
    sizes: tuple         # e.g. (2, 16, 16)

    def coords(self, dev: int):
        out = []
        rem = dev
        for s in reversed(self.sizes):
            out.append(rem % s)
            rem //= s
        return tuple(reversed(out))

    def classify(self, group: list[int]) -> str:
        """Slowest axis this group spans."""
        coords = [self.coords(d) for d in group]
        for i, ax in enumerate(self.axes):     # axes ordered slow->fast
            if len({c[i] for c in coords}) > 1:
                return ax
        return self.axes[-1]


@dataclasses.dataclass
class CollectiveStats:
    ops: list                      # per-op dicts
    bytes_by_axis: dict            # axis -> wire bytes per device
    bytes_by_kind: dict

    def total(self) -> int:
        return sum(self.bytes_by_axis.values())


def analyze_collectives(hlo_text: str, layout: MeshLayout,
                        default_axis: str = "model") -> CollectiveStats:
    ops = []
    by_axis = defaultdict(int)
    by_kind = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in COLLECTIVE_OPS:
            # match op name at the instruction position: "= <type> opname("
            if f" {k}(" in stripped or f" {k}-start(" in stripped:
                kind = k
                break
        if kind is None:
            continue
        if stripped.startswith("ROOT"):
            stripped = stripped[5:]
        # output type(s): between '=' and the op name
        try:
            lhs, rhs = stripped.split("=", 1)
        except ValueError:
            continue
        type_str = rhs.split(kind)[0]
        shapes = _parse_shapes(type_str)
        if not shapes:
            continue
        out_bytes = _shape_bytes(shapes)
        groups = _parse_groups(stripped)
        if groups:
            g = max(len(gr) for gr in groups)
            axis = layout.classify(max(groups, key=len))
        else:
            g = 2
            axis = default_axis
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = out_bytes * (g - 1) // g
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif kind == "all-reduce":
            wire = 2 * out_bytes * (g - 1) // g
        elif kind == "all-to-all":
            wire = out_bytes * (g - 1) // g
        else:  # collective-permute
            wire = out_bytes
        ops.append({"kind": kind, "bytes": out_bytes, "wire": wire,
                    "group_size": g, "axis": axis})
        by_axis[axis] += wire
        by_kind[kind] += wire
    return CollectiveStats(ops=ops, bytes_by_axis=dict(by_axis),
                           bytes_by_kind=dict(by_kind))
