"""Serving launcher: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --smoke \
      --prompts 4 --max-new 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def serve_continuous(args, cfg, engine, pctx):
    """Drain a seeded Poisson arrival stream through the continuous-
    batching scheduler against the live engine; returns the scheduler
    report."""
    from repro.serving import (AdmissionController, BatchScheduler,
                               PlannerProbe, RequestQueue, TrafficConfig,
                               TrafficGenerator)

    itemsize = 4 if args.smoke else 2
    probe = engine.plan_probe(itemsize)
    if probe is None:
        # pctx-free host (CPU smoke): the admission controller still
        # gets a planner oracle, scored on the requested fabric
        from repro.core.topology import get_fabric
        probe = PlannerProbe(
            get_fabric(args.fabric or "2x8"),
            token_bytes=cfg.d_model * itemsize,
            num_experts=getattr(cfg, "num_experts", 0) or 64,
            top_k=getattr(cfg, "top_k", 0) or 8)
    xover = probe.crossover_batch()
    anchor = int(xover) if xover != float("inf") else max(1, args.prompts)
    tpot_slo_s = (args.tpot_slo_us * 1e-6 if args.tpot_slo_us
                  else probe.decode_step_s(anchor) * 1.15)
    ttft_slo_s = (args.ttft_slo_us * 1e-6 if args.ttft_slo_us else 0.08)
    queue = RequestQueue()
    traffic = TrafficConfig(
        arrival_rate_rps=args.arrival_rate, num_requests=args.requests,
        prompt_lens=(args.prompt_len,), max_news=(args.max_new,),
        vocab=cfg.vocab, seed=args.seed)
    for req in TrafficGenerator(traffic).requests():
        queue.push(req)
    admission = AdmissionController(
        probe, capacity=args.prompts, policy="planner",
        tpot_slo_s=tpot_slo_s, ttft_slo_s=ttft_slo_s)
    sched = BatchScheduler(
        queue=queue, admission=admission, engine=engine, probe=probe,
        binder=engine.plan_binder if pctx is not None else None,
        plan_for_bucket=lambda b: engine.bucket_plan(b, args.prompt_len),
        eos_id=None, seed=args.seed)
    sched.run_until_drained()
    print(f"continuous serving: capacity {args.prompts}, crossover batch "
          f"{anchor if xover != float('inf') else 'none'}, TPOT SLO "
          f"{tpot_slo_s * 1e6:.0f}us, TTFT SLO {ttft_slo_s * 1e3:.0f}ms")
    return sched.report(ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plan-policy", choices=["auto", "fixed"],
                    default="auto",
                    help="auto: MoE dispatch+combine plans per phase from "
                         "the latency-model planner (decode vs prefill "
                         "can differ, Fig 8)")
    ap.add_argument("--fabric", default=None,
                    help="fabric the planner scores against: a registered "
                         "name (2x8, 4x8, 2x8r2, 2x8asym) or an inline "
                         "spec 'SxP[rR][@INTER[:INTRA]]' in GB/s")
    ap.add_argument("--calibrate", choices=["off", "startup"],
                    default="off",
                    help="telemetry: probe sweep + fit before serving so "
                         "planner decisions are scored under measured "
                         "link bandwidths; plan_report then carries the "
                         "predicted-vs-measured drift and the last "
                         "re-calibration")
    ap.add_argument("--calibration-store", default=None,
                    help="calibration JSONL path (default "
                         "results/calibration/calibration.jsonl)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching serving tier: seeded open-"
                         "loop Poisson arrivals drain through the "
                         "iteration-level BatchScheduler (finished "
                         "sequences exit / queued requests join between "
                         "decode steps) under planner-informed admission, "
                         "instead of the one-shot batched generate")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous mode: total requests in the arrival "
                         "stream")
    ap.add_argument("--arrival-rate", type=float, default=100.0,
                    help="continuous mode: open-loop Poisson arrival rate "
                         "(requests/s on the scheduler's virtual clock)")
    ap.add_argument("--ttft-slo-us", type=float, default=None,
                    help="continuous mode: time-to-first-token SLO (us) "
                         "for admission pressure + per-request SLO "
                         "classes (default 80000)")
    ap.add_argument("--tpot-slo-us", type=float, default=None,
                    help="continuous mode: time-per-output-token SLO "
                         "(us); admission holds the decode batch at the "
                         "largest size whose planner-predicted step meets "
                         "this (default: 1.15x the predicted step at the "
                         "scheme-crossover batch)")
    ap.add_argument("--decode-slo-us", type=float, default=None,
                    help="decode-phase latency budget (us): the planner "
                         "rejects prefill plan combinations whose shared-"
                         "link traffic would push the decode round trip "
                         "past this cap (contention-aware sweep)")
    ap.add_argument("--seed", type=int, default=0)
    from repro.telemetry.exporter import (add_metrics_args,
                                          finish_exporter_from_args,
                                          start_exporter_from_args)
    add_metrics_args(ap)
    args = ap.parse_args(argv)
    exporter = start_exporter_from_args(args)

    from repro.configs.base import get_config
    from repro.models.api import build_model
    from repro.runtime.server import ServeConfig, ServeEngine

    cfg = get_config(args.arch)
    pctx = None
    if args.smoke:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, d_ff=256,
                          vocab=2048)
    else:
        # production mesh only when this host actually has it; otherwise
        # keep the historical pctx-free single-host path
        need = 512 if args.multi_pod else 256
        if len(jax.devices()) == need:
            import dataclasses

            from repro.launch.mesh import make_pctx
            pctx = make_pctx(multi_pod=args.multi_pod, fsdp=False)
            pctx = dataclasses.replace(pctx, plan_policy=args.plan_policy)
        else:
            print(f"({len(jax.devices())} device(s), production mesh "
                  f"needs {need}: serving without a ParallelContext)")
    # resolve --fabric into the context BEFORE telemetry: probe records
    # and trace-time planner lookups must share ONE fabric fingerprint
    # (a monitor keyed to the mesh-derived topology would store records
    # the --fabric decisions never find)
    if pctx is not None and args.fabric:
        import dataclasses

        from repro.core.topology import get_fabric
        pctx = dataclasses.replace(pctx, fabric=get_fabric(args.fabric))
    monitor = None
    store = None
    if args.calibrate != "off":
        from repro.core.planner import _ep_topology
        from repro.core.topology import get_fabric
        from repro.telemetry import startup_calibration
        if pctx is not None:
            topo = _ep_topology(pctx.num_pods, pctx.data_size, pctx.fabric)
        else:
            topo = get_fabric(args.fabric or "2x8")
        # simulated probe (the default) stands in when there is no
        # fabric to time (CPU smoke); live deployments pass a LiveProbe
        store, monitor, event = startup_calibration(
            topo, args.calibration_store)
        print(f"calibration: {len(store)} records, "
              f"recalibrated={bool(event)}"
              + (f", drift at fit {100 * event['drift']:.1f}%"
                 if event else ""))
    # Declare both serving phases' collective sites as ONE program and
    # bind the jointly-planned ExecutionPlan BEFORE building the model:
    # the jitted prefill/decode traces then resolve their MoE round trips
    # by site lookup (prefill and decode sites differ by payload, so one
    # bound plan serves both phases).
    if pctx is not None and pctx.plan_policy == "auto":
        from repro.parallel.context import build_collective_program
        # itemsize must match the activation dtype build_model uses
        # below (site keys embed the payload bucket)
        budgets = ({"decode": args.decode_slo_us * 1e-6}
                   if args.decode_slo_us else None)
        program = build_collective_program(
            cfg, pctx, "serve", {"prefill": (args.prompts, args.prompt_len),
                                 "decode": (args.prompts, 1)},
            itemsize=4 if args.smoke else 2, phase_budgets=budgets)
        if program.sites:
            eplan = pctx.plan_collectives(program)
            pctx = pctx.bind(eplan)
            print(eplan.summary())
            dec = eplan.phase_report.get("decode", {})
            if dec.get("budget_s"):
                verdict = ("met" if dec.get("budget_ok")
                           else "VIOLATED (no feasible combination; "
                                "best-effort plan bound)")
                print(f"decode SLO {dec['budget_s'] * 1e6:.0f}us: "
                      f"{verdict} — contended decode "
                      f"{dec.get('contended_score_s', 0.0) * 1e6:.1f}us")
    model = build_model(cfg, pctx, dtype=jnp.float32 if args.smoke
                        else jnp.bfloat16)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         ServeConfig(max_new_tokens=args.max_new,
                                     temperature=args.temperature),
                         pctx=pctx, calibration=store, monitor=monitor)
    if args.continuous:
        rep = serve_continuous(args, cfg, engine, pctx)
        print(f"served {rep['completed']}/{args.requests} request(s) in "
              f"{rep['iterations']} iteration(s), horizon "
              f"{rep['horizon_s'] * 1e3:.0f}ms, max in-flight "
              f"{rep['max_in_flight']}")
        print(f"TTFT p50/p99 {rep['ttft_p50_s'] * 1e3:.1f}/"
              f"{rep['ttft_p99_s'] * 1e3:.1f}ms, TPOT p50/p99 "
              f"{rep['tpot_p50_s'] * 1e6:.0f}/{rep['tpot_p99_s'] * 1e6:.0f}"
              f"us, queue-wait p99 {rep['queue_wait_p99_s'] * 1e3:.1f}ms")
        print(f"admission: holds={rep['admission_holds']} "
              f"rejects={sum(rep['admission_rejects'].values())}; "
              f"plan prefetches={rep['prefetch_rebinds']} "
              f"swaps={rep.get('plan_swaps', 0)} "
              f"cold retraces={rep.get('cold_retraces', 0)}; SLO-good "
              f"{rep['slo_good']}/{rep['completed']} "
              f"(goodput {rep['goodput_rps']:.1f}/s)")
        finish_exporter_from_args(args, exporter)
        return 0
    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab, size=(args.prompts, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, seed=args.seed)
    print(f"generated {out.shape}; "
          f"prefill {engine.stats['prefill_s']*1e3:.0f}ms, "
          f"decode {engine.stats['decode_s']*1e3:.0f}ms")
    for phase, per_op in engine.stats.get("plans", {}).items():
        if phase == "execution_plan":
            print(f"execution plan fingerprint: {per_op}")
            continue
        if phase == "stale":
            print(f"bound plan stale: {per_op}")
            continue
        if phase == "planner":
            print(f"planner: {'/'.join(per_op['search'])} search, "
                  f"{per_op['combos_scored']}/{per_op['product']} "
                  f"combination(s) scored across {per_op['phases']} "
                  f"phase(s) in {per_op['planning_wall_s'] * 1e3:.1f}ms")
            continue
        if phase == "phases":
            for ph, rep in per_op.items():
                line = (f"phase[{ph}]: {rep['score_s'] * 1e6:.1f}us "
                        f"(contention +{rep['contention_s'] * 1e6:.1f}us)")
                if rep.get("budget_s"):
                    line += (f", budget {rep['budget_s'] * 1e6:.0f}us "
                             f"{'ok' if rep.get('budget_ok') else 'VIOLATED'}")
                print(line)
            continue
        if phase == "calibration":
            last = per_op.get("last_recalibration")
            print(f"calibration: drift {per_op['drift_pct']:.1f}% over "
                  f"{per_op['observations']} probe(s), "
                  f"{per_op['recalibrations']} recalibration(s)"
                  + (f", last refit {last['measured_links']} links"
                     if last else ""))
            continue
        for op, rep in per_op.items():
            if not rep:
                continue
            print(f"planner[{phase}/{op}]: {rep['plan']} "
                  f"predicted={rep['predicted_us']:.1f}us "
                  f"vs baseline={rep['baseline_us']:.1f}us "
                  f"({rep['speedup_pct']:+.1f}%)")
    print(out[:, :16])
    finish_exporter_from_args(args, exporter)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
