"""Serving launcher: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --smoke \
      --prompts 4 --max-new 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plan-policy", choices=["auto", "fixed"],
                    default="auto",
                    help="auto: MoE dispatch+combine plans per phase from "
                         "the latency-model planner (decode vs prefill "
                         "can differ, Fig 8)")
    ap.add_argument("--fabric", default=None,
                    help="fabric the planner scores against: a registered "
                         "name (2x8, 4x8, 2x8r2, 2x8asym) or an inline "
                         "spec 'SxP[rR][@INTER[:INTRA]]' in GB/s")
    ap.add_argument("--calibrate", choices=["off", "startup"],
                    default="off",
                    help="telemetry: probe sweep + fit before serving so "
                         "planner decisions are scored under measured "
                         "link bandwidths; plan_report then carries the "
                         "predicted-vs-measured drift and the last "
                         "re-calibration")
    ap.add_argument("--calibration-store", default=None,
                    help="calibration JSONL path (default "
                         "results/calibration/calibration.jsonl)")
    ap.add_argument("--decode-slo-us", type=float, default=None,
                    help="decode-phase latency budget (us): the planner "
                         "rejects prefill plan combinations whose shared-"
                         "link traffic would push the decode round trip "
                         "past this cap (contention-aware sweep)")
    ap.add_argument("--seed", type=int, default=0)
    from repro.telemetry.exporter import (add_metrics_args,
                                          finish_exporter_from_args,
                                          start_exporter_from_args)
    add_metrics_args(ap)
    args = ap.parse_args(argv)
    exporter = start_exporter_from_args(args)

    from repro.configs.base import get_config
    from repro.models.api import build_model
    from repro.runtime.server import ServeConfig, ServeEngine

    cfg = get_config(args.arch)
    pctx = None
    if args.smoke:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, d_ff=256,
                          vocab=2048)
    else:
        # production mesh only when this host actually has it; otherwise
        # keep the historical pctx-free single-host path
        need = 512 if args.multi_pod else 256
        if len(jax.devices()) == need:
            import dataclasses

            from repro.launch.mesh import make_pctx
            pctx = make_pctx(multi_pod=args.multi_pod, fsdp=False)
            pctx = dataclasses.replace(pctx, plan_policy=args.plan_policy)
        else:
            print(f"({len(jax.devices())} device(s), production mesh "
                  f"needs {need}: serving without a ParallelContext)")
    # resolve --fabric into the context BEFORE telemetry: probe records
    # and trace-time planner lookups must share ONE fabric fingerprint
    # (a monitor keyed to the mesh-derived topology would store records
    # the --fabric decisions never find)
    if pctx is not None and args.fabric:
        import dataclasses

        from repro.core.topology import get_fabric
        pctx = dataclasses.replace(pctx, fabric=get_fabric(args.fabric))
    monitor = None
    store = None
    if args.calibrate != "off":
        from repro.core.planner import _ep_topology
        from repro.core.topology import get_fabric
        from repro.telemetry import startup_calibration
        if pctx is not None:
            topo = _ep_topology(pctx.num_pods, pctx.data_size, pctx.fabric)
        else:
            topo = get_fabric(args.fabric or "2x8")
        # simulated probe (the default) stands in when there is no
        # fabric to time (CPU smoke); live deployments pass a LiveProbe
        store, monitor, event = startup_calibration(
            topo, args.calibration_store)
        print(f"calibration: {len(store)} records, "
              f"recalibrated={bool(event)}"
              + (f", drift at fit {100 * event['drift']:.1f}%"
                 if event else ""))
    # Declare both serving phases' collective sites as ONE program and
    # bind the jointly-planned ExecutionPlan BEFORE building the model:
    # the jitted prefill/decode traces then resolve their MoE round trips
    # by site lookup (prefill and decode sites differ by payload, so one
    # bound plan serves both phases).
    if pctx is not None and pctx.plan_policy == "auto":
        from repro.parallel.context import build_collective_program
        # itemsize must match the activation dtype build_model uses
        # below (site keys embed the payload bucket)
        budgets = ({"decode": args.decode_slo_us * 1e-6}
                   if args.decode_slo_us else None)
        program = build_collective_program(
            cfg, pctx, "serve", {"prefill": (args.prompts, args.prompt_len),
                                 "decode": (args.prompts, 1)},
            itemsize=4 if args.smoke else 2, phase_budgets=budgets)
        if program.sites:
            eplan = pctx.plan_collectives(program)
            pctx = pctx.bind(eplan)
            print(eplan.summary())
            dec = eplan.phase_report.get("decode", {})
            if dec.get("budget_s"):
                verdict = ("met" if dec.get("budget_ok")
                           else "VIOLATED (no feasible combination; "
                                "best-effort plan bound)")
                print(f"decode SLO {dec['budget_s'] * 1e6:.0f}us: "
                      f"{verdict} — contended decode "
                      f"{dec.get('contended_score_s', 0.0) * 1e6:.1f}us")
    model = build_model(cfg, pctx, dtype=jnp.float32 if args.smoke
                        else jnp.bfloat16)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         ServeConfig(max_new_tokens=args.max_new,
                                     temperature=args.temperature),
                         pctx=pctx, calibration=store, monitor=monitor)
    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab, size=(args.prompts, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, seed=args.seed)
    print(f"generated {out.shape}; "
          f"prefill {engine.stats['prefill_s']*1e3:.0f}ms, "
          f"decode {engine.stats['decode_s']*1e3:.0f}ms")
    for phase, per_op in engine.stats.get("plans", {}).items():
        if phase == "execution_plan":
            print(f"execution plan fingerprint: {per_op}")
            continue
        if phase == "stale":
            print(f"bound plan stale: {per_op}")
            continue
        if phase == "planner":
            print(f"planner: {'/'.join(per_op['search'])} search, "
                  f"{per_op['combos_scored']}/{per_op['product']} "
                  f"combination(s) scored across {per_op['phases']} "
                  f"phase(s) in {per_op['planning_wall_s'] * 1e3:.1f}ms")
            continue
        if phase == "phases":
            for ph, rep in per_op.items():
                line = (f"phase[{ph}]: {rep['score_s'] * 1e6:.1f}us "
                        f"(contention +{rep['contention_s'] * 1e6:.1f}us)")
                if rep.get("budget_s"):
                    line += (f", budget {rep['budget_s'] * 1e6:.0f}us "
                             f"{'ok' if rep.get('budget_ok') else 'VIOLATED'}")
                print(line)
            continue
        if phase == "calibration":
            last = per_op.get("last_recalibration")
            print(f"calibration: drift {per_op['drift_pct']:.1f}% over "
                  f"{per_op['observations']} probe(s), "
                  f"{per_op['recalibrations']} recalibration(s)"
                  + (f", last refit {last['measured_links']} links"
                     if last else ""))
            continue
        for op, rep in per_op.items():
            if not rep:
                continue
            print(f"planner[{phase}/{op}]: {rep['plan']} "
                  f"predicted={rep['predicted_us']:.1f}us "
                  f"vs baseline={rep['baseline_us']:.1f}us "
                  f"({rep['speedup_pct']:+.1f}%)")
    print(out[:, :16])
    finish_exporter_from_args(args, exporter)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
