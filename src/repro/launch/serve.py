"""Serving launcher: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6_7b --smoke \
      --prompts 4 --max-new 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plan-policy", choices=["auto", "fixed"],
                    default="auto",
                    help="auto: MoE dispatch+combine plans per phase from "
                         "the latency-model planner (decode vs prefill "
                         "can differ, Fig 8)")
    ap.add_argument("--fabric", default=None,
                    help="fabric the planner scores against: a registered "
                         "name (2x8, 4x8, 2x8r2, 2x8asym) or an inline "
                         "spec 'SxP[rR][@INTER[:INTRA]]' in GB/s")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.base import get_config
    from repro.models.api import build_model
    from repro.runtime.server import ServeConfig, ServeEngine

    cfg = get_config(args.arch)
    pctx = None
    if args.smoke:
        cfg = cfg.reduced(n_layers=4, d_model=128, n_heads=4, d_ff=256,
                          vocab=2048)
    else:
        # production mesh only when this host actually has it; otherwise
        # keep the historical pctx-free single-host path
        need = 512 if args.multi_pod else 256
        if len(jax.devices()) == need:
            import dataclasses

            from repro.launch.mesh import make_pctx
            pctx = make_pctx(multi_pod=args.multi_pod, fsdp=False)
            pctx = dataclasses.replace(pctx, plan_policy=args.plan_policy)
        else:
            print(f"({len(jax.devices())} device(s), production mesh "
                  f"needs {need}: serving without a ParallelContext)")
    model = build_model(cfg, pctx, dtype=jnp.float32 if args.smoke
                        else jnp.bfloat16)
    params = model.init(jax.random.key(args.seed))
    engine = ServeEngine(model, params,
                         ServeConfig(max_new_tokens=args.max_new,
                                     temperature=args.temperature),
                         pctx=pctx, fabric=args.fabric)
    prompts = np.random.default_rng(args.seed).integers(
        0, cfg.vocab, size=(args.prompts, args.prompt_len)).astype(np.int32)
    out = engine.generate(prompts, seed=args.seed)
    print(f"generated {out.shape}; "
          f"prefill {engine.stats['prefill_s']*1e3:.0f}ms, "
          f"decode {engine.stats['decode_s']*1e3:.0f}ms")
    for phase, per_op in engine.stats.get("plans", {}).items():
        for op, rep in per_op.items():
            if not rep:
                continue
            print(f"planner[{phase}/{op}]: {rep['plan']} "
                  f"predicted={rep['predicted_us']:.1f}us "
                  f"vs baseline={rep['baseline_us']:.1f}us "
                  f"({rep['speedup_pct']:+.1f}%)")
    print(out[:, :16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
