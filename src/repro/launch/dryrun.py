import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with ZERO device allocation (inputs are
ShapeDtypeStructs):

  * compiled executable for the production mesh (16x16 single-pod and
    2x16x16 multi-pod) — proving the sharding config is coherent;
  * ``memory_analysis()``  — per-device bytes (fits/doesn't fit);
  * ``cost_analysis()``    — per-device FLOPs / bytes for the roofline;
  * collective wire bytes per mesh axis, parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — the paper's bottleneck-link quantity;
  * the three roofline terms (§Roofline) with the TPU v5e constants.

Results are cached as JSON under results/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi_k2_1t \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import dataclasses
import json
import math
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ARCH_IDS, SHAPES, ShapeSpec, cell_is_skipped, get_config, shapes_for)
from repro.launch.hlo_analysis import MeshLayout


@dataclasses.dataclass
class _CollView:
    bytes_by_axis: dict
    bytes_by_kind: dict
    num_ops: int
from repro.launch.mesh import make_pctx
from repro.models.api import build_model
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.trainer import TrainState, make_train_step

# TPU v5e hardware constants (prompt-supplied)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
DCN_BW = 6.25e9              # bytes/s / chip inter-pod (50 Gbps class)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Named sharding/schedule variants (pctx overrides).  "mw" is the
# paper-faithful default; the rest are §Perf hillclimb levers.
# moe_microbatch="plan" derives the pipeline chunk count G from the
# planner's overlap-aware dispatch decision for the CELL's workload
# (batch, fabric, modeled expert compute) instead of a hard-coded
# preset — the knob the pipelined scoring mode genuinely tunes.
VARIANTS = {
    "mw": {},                                   # MultiWrite hierarchical EP
    "auto": {"plan_policy": "auto"},            # planner-chosen schemes
    "baseline": {"moe_scheme": "baseline"},     # unicast EP dispatch
    "nosp": {"seq_parallel": False},            # no sequence parallelism
    "selrem": {"remat": "selective"},           # selective remat
    "nofsdp": {"fsdp": False},                  # pure DP (replicated params)
    # hillclimb combos (§Perf):
    "mwopt": {"moe_deferred_tp_reduce": True,   # deferred expert-TP psum
              "moe_microbatch": "plan"},        # + planned pipeline chunks
    "mwdefer": {"moe_deferred_tp_reduce": True},
    "mwmicro": {"moe_microbatch": "plan"},
    "baseopt": {"moe_scheme": "baseline",
                "moe_deferred_tp_reduce": True, "moe_microbatch": "plan"},
}

# optimizer-moment dtype per variant (memory lever for the 1T cell)
VARIANT_OPT_DTYPE = {"mwopt": jnp.bfloat16, "baseopt": jnp.bfloat16}


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs, weak-type-correct, shardable)
# ---------------------------------------------------------------------------

def batch_shapes(cfg, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        if cfg.input_mode == "embeddings" and cfg.family != "encdec":
            return {"embeds": jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                                   jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "encdec":
        return {"src_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.bfloat16),
                "tgt_tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.input_mode == "embeddings":
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16),
                "positions": jax.ShapeDtypeStruct((b, s, 3), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    out = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
           "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "prefill":
        out.pop("labels")
    return out


def input_specs(arch: str, shape_name: str, pctx, *, opt_dtype=None):
    """(kind, fn, sharded ShapeDtypeStruct args) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg, pctx)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_sds = jax.eval_shape(model.init, key_sds)
    pspecs = shd.param_specs(params_sds, cfg, pctx)
    params_in = shd.with_sharding(params_sds, pspecs, pctx)
    batch_sds = batch_shapes(cfg, shape)
    bspecs = shd.batch_specs(batch_sds, pctx)
    batch_in = shd.with_sharding(batch_sds, bspecs, pctx)

    if shape.kind == "train":
        opt = adamw(lr=1e-4, opt_dtype=opt_dtype)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = shd.param_specs(opt_sds, cfg, pctx)   # elementwise -> same rules
        opt_in = shd.with_sharding(opt_sds, ospecs, pctx)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        state_in = TrainState(params_in, opt_in,
                              shd.with_sharding(
                                  step_sds, jax.sharding.PartitionSpec(),
                                  pctx))
        # pin outputs: new state inherits input shardings (donation works),
        # metrics replicated
        state_out = jax.tree_util.tree_map(lambda s: s.sharding, state_in)
        repl = jax.sharding.NamedSharding(pctx.mesh,
                                          jax.sharding.PartitionSpec())
        metrics_out = {"loss": repl, "grad_norm": repl, "ce": repl,
                       "aux": repl}
        fn = make_train_step(
            model, opt, donate=True,
            jit_kwargs={"out_shardings": (state_out, metrics_out)})
        return "train", fn, (state_in, batch_in)

    # serving cells: params stored bf16 (standard for inference — halves
    # weight HBM and read traffic vs fp32 training master weights)
    params_in = jax.tree_util.tree_map(
        lambda s: (jax.ShapeDtypeStruct(s.shape, jnp.bfloat16,
                                        sharding=s.sharding)
                   if jnp.issubdtype(s.dtype, jnp.floating) else s),
        params_in)
    cache_len = shape.seq_len
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cache_len))
    cspecs = shd.cache_specs(cache_sds, cfg, pctx)
    cache_in = shd.with_sharding(cache_sds, cspecs, pctx)
    cache_out = jax.tree_util.tree_map(lambda s: s.sharding, cache_in)
    b = shape.global_batch
    logits_spec = jax.sharding.PartitionSpec(
        pctx.dp_axes if b % (pctx.num_pods * pctx.data_size) == 0 else None,
        pctx.model_axis if cfg.vocab % pctx.model_size == 0 else None)
    logits_out = jax.sharding.NamedSharding(pctx.mesh, logits_spec)
    if shape.kind == "prefill":
        fn = jax.jit(model.prefill, donate_argnums=(2,),
                     out_shardings=(logits_out, cache_out))
        return "prefill", fn, (params_in, batch_in, cache_in)
    fn = jax.jit(model.decode, donate_argnums=(2,),
                 out_shardings=(logits_out, cache_out))
    return "decode", fn, (params_in, batch_in, cache_in)


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------

def model_flops_per_step(arch: str, shape: ShapeSpec) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) — the §Roofline 'useful FLOPs'."""
    from repro.models.api import param_count_shape_only
    cfg = get_config(arch)
    n = param_count_shape_only(cfg)
    if cfg.is_moe:
        per_rank_share = cfg.top_k / cfg.num_experts
        # active = non-expert params + top_k/E of expert params
        expert = (cfg.n_layers - cfg.first_k_dense) * cfg.num_experts * \
            (3 * cfg.d_model * cfg.expert_d_ff)
        n = n - expert + expert * per_rank_share
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * tokens


def vmem_elem_counts(arch: str, shape: ShapeSpec, pctx) -> set:
    """Element counts of kernel-resident intermediates for shape-based
    VMEM tagging (see hlo_module.analyze_module): flash score blocks
    [B_loc, H_loc, S, block_k] and SSD/WKV chunk matrices [bh_loc, Q, Q].
    Several sharding variants are emitted; exact-count matching keeps
    collision risk negligible for these large products."""
    cfg = get_config(arch)
    if shape.kind == "decode":
        return set()
    dp = pctx.num_pods * pctx.data_size
    b_loc = max(1, shape.global_batch // dp)
    s = shape.seq_len
    out = set()
    if cfg.family in ("dense", "moe", "encdec") or cfg.shared_attn_every:
        block = min(1024, s)
        for h in {cfg.n_heads, max(1, cfg.n_heads // pctx.model_size)}:
            out.add(b_loc * h * s * block)
    if cfg.family == "hybrid":
        heads = (cfg.ssm_expand * cfg.d_model) // cfg.ssm_head_dim
        for hl in {heads, max(1, heads // pctx.model_size)}:
            out.add(b_loc * hl * 64 * 64)                 # [bh, Q, Q], Q=64
            out.add(b_loc * hl * 64 * cfg.ssm_state)      # decay/B blocks
    if cfg.family == "rwkv":
        heads = cfg.d_model // cfg.rwkv_head_dim
        for hl in {heads, max(1, heads // pctx.model_size)}:
            out.add(b_loc * hl * 32 * 32)                 # [bh, Q, Q], Q=32
            out.add(b_loc * hl * 32 * cfg.rwkv_head_dim)  # r~/k~ blocks
    return out


# Fabric axis of the planner report grid: every cell additionally carries
# the dispatch+combine decision on each of these registered fabrics
# (--fabric overrides; see core.topology.FABRICS / parse_fabric).
DEFAULT_REPORT_FABRICS = ("2x8", "4x8", "2x8r2")


def planner_cell_report(arch: str, shape: ShapeSpec, pctx,
                        fabrics=DEFAULT_REPORT_FABRICS,
                        calibration=None, budget_s=None) -> dict:
    """Which plan the planner picks for this cell, and the predicted
    delta vs the baseline plan (the quantity the dry-run table reports
    next to the roofline terms).  The cell's collective sites are
    declared as a program and planned JOINTLY — the MoE dispatch/combine
    pair shares one chunk pipeline, so the reported G is the shared G
    the model executes under a bound ExecutionPlan.  ``fabrics`` adds a
    what-if axis: the same cell's per-op decisions on each named fabric.
    ``calibration`` (a telemetry store or path) adds a second what-if
    axis: the same decisions under the store's FITTED hardware model —
    'what would the planner do on the fabric we actually measured'.
    ``budget_s`` declares a latency budget for the cell's phase
    (--phase-budget-us): the contention-aware sweep then reports whether
    any feasible combination met it."""
    from repro.core import planner as pl
    cal_store = None
    if calibration is not None:
        from repro.telemetry import resolve_store
        cal_store = resolve_store(calibration)
    cfg = get_config(arch)
    out = {"policy": pctx.plan_policy}
    n_local = _cell_tokens_per_rank(shape, pctx)
    cell_compute_s = _cell_compute_s(cfg, shape, pctx)
    eplan = None
    if cfg.is_moe:
        eplan = _cell_execution_plan(arch, shape, pctx, budget_s=budget_s)
        role_d = f"{shape.kind}/moe_dispatch"
        out["execution_plan"] = eplan.fingerprint
        out["moe_dispatch"] = eplan.decision(role_d).report()
        out["moe_combine"] = eplan.decision(
            f"{shape.kind}/moe_combine").report()
        joint = eplan.joint.get(role_d)
        out["moe_joint"] = joint.report() if joint else None
        # the microbatch this cell EXECUTES (pctx knob — planner-derived
        # for the "plan" presets; under auto the joint decision's shared
        # G clamped to a divisor of the local token count, exactly as
        # moe_ffn runs it) next to the planner's own pick, so
        # preset/decision drift is visible in the table instead of
        # silently baked in
        planned_g = joint.microbatch if joint else 1
        g_knob = (planned_g if pctx.plan_policy == "auto"
                  else int(pctx.moe_microbatch))
        out["moe_microbatch"] = {
            "executed": max(1, math.gcd(g_knob, n_local)),
            "planned": planned_g,
            "compute_s": cell_compute_s,
        }
    if shape.kind == "train":
        # gradient sync rides in the same cell program (train phase only)
        if eplan is None:
            eplan = _cell_execution_plan(arch, shape, pctx,
                                         budget_s=budget_s)
            out["execution_plan"] = eplan.fingerprint
        gs = eplan.decisions.get("train/grad_sync")
        if gs is not None:
            out["grad_sync"] = gs.report()
    if eplan is not None:
        # contention breakdown + sweep-cost introspection of the cell's
        # phase (solo vs merged shared-link wire, beam/oracle statistics,
        # budget verdict when --phase-budget-us is in play)
        out["phases"] = {ph: dict(rep)
                         for ph, rep in eplan.phase_report.items()}
        out["planner_stats"] = dict(eplan.planner_stats)
    # Reference decision on the paper's §3.1 fixture (8-NPU split-TP full
    # mesh) at this cell's per-chip activation fragment — a what-if the
    # table carries alongside every cell, NOT a collective the traced
    # model necessarily issues (tp_subgroups=1 emits no split-TP gather).
    from repro.core.topology import get_fabric, split_tp_full_mesh
    topo, _ = split_tp_full_mesh(8, tp=4)
    frag = n_local * cfg.d_model * 2
    d = pl.default_planner().choose("allgather", frag, topo)
    out["allgather_ref_8x4"] = {"frag_bytes": frag, **d.report()}
    # Fabric axis: how the decisions move with the physical bottleneck.
    out["fabrics"] = {}
    for fname in fabrics or ():
        ftopo = get_fabric(fname)
        cell = {"allgather": pl.default_planner().choose(
            "allgather", frag, ftopo).report()}
        if cfg.is_moe:
            cell["dispatch"] = pl.default_planner().choose(
                "dispatch", n_local * cfg.d_model * 2, ftopo,
                num_experts=cfg.num_experts, top_k=cfg.top_k,
                token_bytes=cfg.d_model * 2,
                compute_s=cell_compute_s).report()
            cell["combine"] = pl.default_planner().choose(
                "combine", n_local * cfg.d_model * 2, ftopo,
                num_experts=cfg.num_experts, top_k=cfg.top_k,
                token_bytes=cfg.d_model * 2,
                compute_s=cell_compute_s).report()
        # calibration what-if: the same fabric cell under the measured
        # (fitted) hardware model from the --calibration store
        if cal_store is not None:
            from repro.telemetry import calibrated_hw
            hw_cal = calibrated_hw(cal_store, ftopo)
            cal = {"fitted": bool(hw_cal.link_bw),
                   "allgather": pl.default_planner().choose(
                       "allgather", frag, ftopo, hw_cal).report()}
            if cfg.is_moe:
                cal["dispatch"] = pl.default_planner().choose(
                    "dispatch", n_local * cfg.d_model * 2, ftopo, hw_cal,
                    num_experts=cfg.num_experts, top_k=cfg.top_k,
                    token_bytes=cfg.d_model * 2,
                    compute_s=cell_compute_s).report()
                cal["combine"] = pl.default_planner().choose(
                    "combine", n_local * cfg.d_model * 2, ftopo, hw_cal,
                    num_experts=cfg.num_experts, top_k=cfg.top_k,
                    token_bytes=cfg.d_model * 2,
                    compute_s=cell_compute_s).report()
            cell["calibrated"] = cal
        out["fabrics"][fname] = cell
    if cal_store is not None:
        out["calibration_store"] = {"path": cal_store.path,
                                    "records": len(cal_store),
                                    "fabrics": cal_store.fabrics()}
    return out


def _cell_tokens_per_rank(shape: ShapeSpec, pctx) -> int:
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    return max(1, tokens // (pctx.num_pods * pctx.data_size))


def _cell_program(arch: str, shape: ShapeSpec, pctx, budget_s=None):
    """The ONE declared collective program of this cell (phase ==
    shape.kind), shared by the "plan" preset derivation, the auto-policy
    binding and the cell report — so the G a preset executes is always
    derived from the same joint decision the report displays as
    'planned'.  ``budget_s`` caps the phase's contention-aware latency
    (the --phase-budget-us what-if)."""
    from repro.parallel.context import build_collective_program
    cfg = get_config(arch)
    seq = shape.seq_len if shape.kind != "decode" else 1
    return build_collective_program(
        cfg, pctx, "dryrun", {shape.kind: (shape.global_batch, seq)},
        phase_budgets={shape.kind: budget_s} if budget_s else None)


def _cell_execution_plan(arch: str, shape: ShapeSpec, pctx, budget_s=None):
    """Jointly-planned ExecutionPlan of this cell's program (planned
    regardless of policy: the fixed-policy cells still REPORT what the
    planner would bind)."""
    return pctx.plan_collectives(
        _cell_program(arch, shape, pctx, budget_s=budget_s))


def _cell_compute_s(cfg, shape: ShapeSpec, pctx) -> float:
    """Modeled per-rank expert-FFN time of this cell — the overlap
    context the planner's pipelined scoring mode prices chunked
    dispatch/combine against."""
    if not cfg.is_moe:
        return 0.0
    from repro.core.latency_model import moe_overlap_compute_s
    return moe_overlap_compute_s(
        _cell_tokens_per_rank(shape, pctx), cfg.top_k, cfg.d_model,
        cfg.expert_d_ff, tp=pctx.model_size)


def _planned_microbatch(arch: str, shape: ShapeSpec, pctx) -> int:
    """Derive the moe_microbatch preset from the JOINT pipeline decision
    of this cell's program (the 'mwmicro' drift fix, now joint-aware:
    the shared G is the one the dispatch+combine round trip scores best
    at, not the dispatch half's own optimum)."""
    cfg = get_config(arch)
    if not cfg.is_moe:
        return 1
    eplan = _cell_execution_plan(arch, shape, pctx)
    joint = eplan.joint.get(f"{shape.kind}/moe_dispatch")
    g = joint.microbatch if joint else 1
    return max(1, math.gcd(g, _cell_tokens_per_rank(shape, pctx)))


def _cell_pctx(arch: str, shape: ShapeSpec, multi_pod: bool, variant: str):
    pctx_kw = dict(VARIANTS[variant])
    if shape.kind != "train":
        # serving: replicate dense params over data (classic TP serving);
        # MoE experts stay EP-sharded via moe_specs regardless.
        pctx_kw.setdefault("fsdp", False)
    planned_g = pctx_kw.get("moe_microbatch") == "plan"
    if planned_g:
        pctx_kw.pop("moe_microbatch")   # integer presets pass through
    pctx = make_pctx(multi_pod=multi_pod, **pctx_kw)
    if planned_g:
        pctx = dataclasses.replace(
            pctx, moe_microbatch=_planned_microbatch(arch, shape, pctx))
    if pctx.plan_policy == "auto":
        # bind the cell's jointly-planned ExecutionPlan: the traced model
        # resolves its sites by lookup — the dry run exercises the same
        # bound-plan path production launchers use
        program = _cell_program(arch, shape, pctx)
        if program.sites:
            pctx = pctx.bind(pctx.plan_collectives(program))
    return pctx


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             variant: str = "mw", verbose: bool = True,
             fabrics=DEFAULT_REPORT_FABRICS, calibration=None,
             budget_s=None) -> dict:
    skip = cell_is_skipped(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "variant": variant, "skipped": skip}
    shape = SHAPES[shape_name]
    pctx = _cell_pctx(arch, shape, multi_pod, variant)
    t0 = time.monotonic()
    kind, fn, args = input_specs(arch, shape_name, pctx,
                                 opt_dtype=VARIANT_OPT_DTYPE.get(variant))
    with pctx.mesh:
        lowered = fn.lower(*args)
        t_lower = time.monotonic() - t0
        t0 = time.monotonic()
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    layout = MeshLayout(axes=("pod", "data", "model") if multi_pod
                        else ("data", "model"),
                        sizes=(2, 16, 16) if multi_pod else (16, 16))
    # loop-multiplicity-aware analysis (XLA:CPU cost_analysis counts while
    # bodies once — see launch/hlo_module.py):
    from repro.launch.hlo_module import analyze_module
    mod = analyze_module(hlo, layout,
                         vmem_elem_counts=vmem_elem_counts(
                             arch, shape, pctx))
    coll = _CollView(mod.collective_by_axis, mod.collective_by_kind,
                     mod.collective_ops)
    chips = 512 if multi_pod else 256

    flops_dev = float(mod.flops)
    bytes_dev = float(mod.hbm_bytes)
    xla_flops_dev = float(cost.get("flops", 0.0))     # body-once reference
    # kernel-adjusted memory: intermediates tagged to flash/scan source
    # regions stay in VMEM in the Pallas kernels (boundary q/k/v/o traffic
    # is counted at their producers/consumers); assume the fused kernel
    # eliminates 95% of tagged traffic (flash intermediates are O(S*T) vs
    # O(S*d) boundaries — >99% at 32k, 95% is conservative).
    tagged = sum(mod.hbm_tagged.values())
    bytes_dev_kernel = bytes_dev - 0.95 * tagged
    compute_term = flops_dev / PEAK_FLOPS
    memory_term = bytes_dev / HBM_BW
    ici_bytes = sum(v for k, v in coll.bytes_by_axis.items() if k != "pod")
    pod_bytes = coll.bytes_by_axis.get("pod", 0)
    collective_term = ici_bytes / ICI_BW + pod_bytes / DCN_BW
    collective_term_ici_only = (ici_bytes + pod_bytes) / ICI_BW
    mflops = model_flops_per_step(arch, shape)
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant, "kind": kind, "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "bytes_per_device_kernel_adj": bytes_dev_kernel,
                 "hbm_tagged": mod.hbm_tagged,
                 "xla_flops_body_once": xla_flops_dev,
                 "loop_trip_counts": mod.loops},
        "collectives": {
            "by_axis": coll.bytes_by_axis,
            "by_kind": coll.bytes_by_kind,
            "num_ops": coll.num_ops,
        },
        "planner": planner_cell_report(arch, shape, pctx, fabrics=fabrics,
                                       calibration=calibration,
                                       budget_s=budget_s),
        "roofline": {
            "compute_term_s": compute_term,
            "memory_term_s": memory_term,
            "memory_term_kernel_adj_s": bytes_dev_kernel / HBM_BW,
            "collective_term_s": collective_term,
            "collective_term_ici_only_s": collective_term_ici_only,
            "dominant": max(
                [("compute", compute_term), ("memory", memory_term),
                 ("collective", collective_term)], key=lambda kv: kv[1])[0],
            "model_flops_global": mflops,
            "useful_flops_ratio": (mflops / (flops_dev * chips)
                                   if flops_dev else None),
        },
    }
    if verbose:
        mm = result["memory"]
        print(f"[{arch} x {shape_name} x "
              f"{'multi' if multi_pod else 'single'} x {variant}] "
              f"kind={kind} compile={t_compile:.0f}s")
        print(f"  memory/device: args={_gb(mm['argument_bytes'])} "
              f"temp={_gb(mm['temp_bytes'])} out={_gb(mm['output_bytes'])}")
        print(f"  flops/device={flops_dev:.3e} bytes/device={bytes_dev:.3e}")
        print(f"  collective bytes by axis: "
              f"{ {k: _gb(v) for k, v in coll.bytes_by_axis.items()} }")
        r = result["roofline"]
        print(f"  roofline: compute={r['compute_term_s']*1e3:.2f}ms "
              f"memory={r['memory_term_s']*1e3:.2f}ms "
              f"collective={r['collective_term_s']*1e3:.2f}ms "
              f"-> dominant={r['dominant']}")
        for op_name, pr in result["planner"].items():
            if isinstance(pr, dict) and "plan" in pr:
                print(f"  planner[{op_name}]: {pr['plan']} "
                      f"predicted={pr['predicted_us']:.1f}us "
                      f"vs baseline={pr['baseline_us']:.1f}us "
                      f"({pr['speedup_pct']:+.1f}%)")
        mb = result["planner"].get("moe_microbatch")
        if mb:
            print(f"  planner[microbatch]: executed={mb['executed']} "
                  f"planned={mb['planned']}")
        for ph, rep in result["planner"].get("phases", {}).items():
            line = (f"  planner[phase {ph}]: {rep['score_s'] * 1e6:.1f}us "
                    f"(contention +{rep['contention_s'] * 1e6:.1f}us)")
            if rep.get("budget_s"):
                line += (f", budget {rep['budget_s'] * 1e6:.0f}us "
                         f"{'ok' if rep.get('budget_ok') else 'VIOLATED'}")
            print(line)
        st = result["planner"].get("planner_stats")
        if st:
            print(f"  planner[search]: {'/'.join(st['search'])}, "
                  f"{st['combos_scored']}/{st['product']} combination(s) "
                  f"scored in {st['planning_wall_s'] * 1e3:.1f}ms")
    return result


def _gb(x):
    if x is None:
        return "?"
    return f"{x/2**30:.2f}GiB"


def cell_path(arch, shape_name, multi_pod, variant):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh = "multi" if multi_pod else "single"
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape_name}__{mesh}__{variant}.json")


def run_and_save(arch, shape_name, multi_pod, variant="mw",
                 force=False, fabrics=DEFAULT_REPORT_FABRICS,
                 calibration=None, budget_s=None) -> dict:
    path = cell_path(arch, shape_name, multi_pod, variant)
    if os.path.exists(path) and not force:
        with open(path) as f:
            result = json.load(f)
        # the compiled cell is fabric-independent, but the planner
        # what-if axes are not: refresh them (cheap — no recompile) when
        # the cached cell was computed with a different fabric set, when
        # a calibration store is in play (its fits move with every probe
        # run), or when a phase budget changes the feasibility filter
        cached = set(result.get("planner", {}).get("fabrics", {}))
        if "planner" in result and (cached != set(fabrics or ())
                                    or calibration is not None
                                    or budget_s is not None):
            pctx = _cell_pctx(arch, SHAPES[shape_name], multi_pod, variant)
            result["planner"] = planner_cell_report(
                arch, SHAPES[shape_name], pctx, fabrics=fabrics,
                calibration=calibration, budget_s=budget_s)
            with open(path, "w") as f:
                json.dump(result, f, indent=1)
        return result
    try:
        result = run_cell(arch, shape_name, multi_pod=multi_pod,
                          variant=variant, fabrics=fabrics,
                          calibration=calibration, budget_s=budget_s)
    except Exception as e:  # record failures — they are bugs to fix
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "multi" if multi_pod else "single",
                  "variant": variant, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
        print(f"FAILED [{arch} x {shape_name}]: {e}", file=sys.stderr)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default="mw", choices=list(VARIANTS))
    ap.add_argument("--fabric", default=",".join(DEFAULT_REPORT_FABRICS),
                    help="comma list of fabrics (registered names or "
                         "parseable specs like 4x8, 2x8r2@12.5) for the "
                         "per-cell planner what-if axis; '' disables")
    ap.add_argument("--calibration", default=None,
                    help="telemetry calibration store (JSONL path): every "
                         "cell's planner section additionally reports the "
                         "decisions under the store's FITTED hardware "
                         "model — the measured-fabric what-if axis")
    ap.add_argument("--phase-budget-us", type=float, default=None,
                    help="latency budget (us) for each cell's phase: the "
                         "contention-aware sweep reports whether any "
                         "feasible plan combination met it")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell")
    ap.add_argument("--force", action="store_true")
    from repro.telemetry.exporter import (add_metrics_args,
                                          finish_exporter_from_args,
                                          start_exporter_from_args)
    add_metrics_args(ap)
    args = ap.parse_args(argv)
    exporter = start_exporter_from_args(args)
    fabrics = tuple(f for f in args.fabric.split(",") if f)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in shapes_for(arch):
                for mp in meshes:
                    cells.append((arch, shape, mp, args.variant))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp, args.variant))

    budget_s = (args.phase_budget_us * 1e-6
                if args.phase_budget_us else None)
    failures = 0
    for arch, shape, mp, variant in cells:
        r = run_and_save(arch, shape, mp, variant, force=args.force,
                         fabrics=fabrics, calibration=args.calibration,
                         budget_s=budget_s)
        if "error" in r:
            failures += 1
    print(f"\n{len(cells) - failures}/{len(cells)} cells OK")
    finish_exporter_from_args(args, exporter)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
