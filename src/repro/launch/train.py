"""Training launcher.

Local (real devices, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch mistral_nemo_12b \
      --smoke --steps 50

Production (multi-host TPU; this process shape is what you'd launch per
host — jax.distributed.initialize is invoked when JAX_COORDINATOR is set):
  python -m repro.launch.train --arch kimi_k2_1t --shape train_4k \
      --multi-pod --ckpt-dir gs://...

The mesh is the production (16,16) / (2,16,16) layout from launch/mesh.py;
parallelism knobs (moe scheme, remat, SP, FSDP) come from --variant, same
names as the dry-run.
"""

from __future__ import annotations

import argparse
import logging
import os

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny batch on local devices")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="mw")
    ap.add_argument("--plan-policy", choices=["auto", "fixed"],
                    default=None,
                    help="auto: collective schemes/splits chosen by the "
                         "latency-model planner per payload (§5.2 dynamic "
                         "workflow); fixed: use the --variant knobs "
                         "verbatim.  Default: auto, unless the chosen "
                         "--variant pins an explicit scheme (ablations "
                         "like 'baseline' stay ablations)")
    ap.add_argument("--fabric", default=None,
                    help="fabric the planner scores against instead of the "
                         "mesh-derived shape: a registered name (2x8, 4x8, "
                         "2x8r2, 2x8asym, tpu_2x16) or an inline spec "
                         "'SxP[rR][@INTER[:INTRA]]' in GB/s, e.g. "
                         "'4x8@12.5'.  Changes WHICH dispatch/combine "
                         "plans win; execution stays on the actual mesh")
    ap.add_argument("--calibrate", choices=["off", "startup", "online"],
                    default="off",
                    help="telemetry loop: 'startup' runs a probe sweep + "
                         "fit before step 0 so planner decisions are "
                         "scored under MEASURED link bandwidths; 'online' "
                         "additionally re-probes every --calibrate-every "
                         "steps and re-fits when predicted-vs-measured "
                         "drift exceeds the monitor threshold (decisions "
                         "flip at runtime, no restart)")
    ap.add_argument("--calibrate-every", type=int, default=25,
                    help="online probe cadence in steps")
    ap.add_argument("--calibration-store", default=None,
                    help="calibration JSONL path (default "
                         "results/calibration/calibration.jsonl); "
                         "measurements persist across runs per fabric "
                         "fingerprint")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    from repro.telemetry.exporter import (add_metrics_args,
                                          finish_exporter_from_args,
                                          start_exporter_from_args)
    add_metrics_args(ap)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)
    exporter = start_exporter_from_args(args)

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()          # multi-host entry

    from repro.configs.base import SHAPES, get_config
    from repro.data.pipeline import DataConfig, SyntheticLM, batch_for_model
    from repro.models.api import build_model
    from repro.optim import adamw, cosine_schedule
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        pctx = None
        batch, seq = 4, 64
    else:
        import dataclasses

        from repro.launch.dryrun import VARIANTS
        from repro.launch.mesh import make_pctx
        variant_kw = VARIANTS[args.variant]
        pctx = make_pctx(multi_pod=args.multi_pod, **variant_kw)
        plan_policy = args.plan_policy
        if plan_policy is None:
            # planner by default, but a variant that pins a scheme or a
            # policy is an explicit ablation — don't override it
            pins = {"moe_scheme", "plan_policy"} & set(variant_kw)
            plan_policy = pctx.plan_policy if pins else "auto"
        pctx = dataclasses.replace(pctx, plan_policy=plan_policy)
        if args.fabric:
            from repro.core.topology import get_fabric
            pctx = dataclasses.replace(pctx, fabric=get_fabric(args.fabric))
            logging.info("planner fabric: %s", pctx.fabric.name)
        shape = SHAPES[args.shape]
        batch, seq = shape.global_batch, shape.seq_len

    monitor = None
    probe = None
    if args.calibrate != "off":
        import dataclasses

        from repro.core.planner import _ep_topology
        from repro.core.topology import get_fabric
        from repro.telemetry import (GroundTruth, SimProbe,
                                     startup_calibration)
        if pctx is not None:
            topo = _ep_topology(pctx.num_pods, pctx.data_size, pctx.fabric)
        else:
            topo = get_fabric(args.fabric or "2x8")
        # Execution backend: the simulated probe (injectable ground
        # truth) stands in wherever there is no real fabric to time —
        # deployments on a live mesh swap in telemetry.LiveProbe.
        probe = SimProbe(GroundTruth())
        store, monitor, event = startup_calibration(
            topo, args.calibration_store, probe=probe)
        logging.info("calibration startup: %d store records, drift at fit "
                     "%.1f%%, recalibrated=%s", len(store),
                     100 * (event["drift"] if event else 0.0), bool(event))
        if pctx is not None:
            pctx = dataclasses.replace(pctx, calibration=store)

    # Declare the training phase's collective program up-front and bind
    # the jointly-planned ExecutionPlan: the MoE (dispatch, combine) pair
    # is swept as ONE shared chunk pipeline (a smaller dispatch G can win
    # on the combined score) and the split-TP boundary gather rides in
    # the same program.  Built AFTER calibration so the plan is scored
    # under the fitted model; moe_ffn resolves its sites by lookup
    # against the bound plan at trace time.
    eplan = None
    if pctx is not None:
        from repro.parallel.context import build_collective_program
        # itemsize must match the activation dtype built below (site
        # keys embed the payload bucket)
        program = build_collective_program(
            cfg, pctx, "train", {"train": (batch, seq)},
            itemsize=4 if args.smoke else 2)
        if program.sites and pctx.plan_policy == "auto":
            eplan = pctx.plan_collectives(program)
            pctx = pctx.bind(eplan)
            for line in eplan.summary().splitlines():
                logging.info("planner %s", line)
            joint = eplan.joint.get("train/moe_dispatch")
            if joint is not None and joint.microbatch > 1:
                logging.info(
                    "planner pipelined MoE round trip: G=%d shared chunks "
                    "(serial %.1fus -> %.1fus predicted)",
                    joint.microbatch, joint.predicted_serial_s * 1e6,
                    joint.predicted_s * 1e6)
            gs = eplan.decisions.get("train/grad_sync")
            if gs is not None:
                g = gs.shard_map_kwargs.get("microbatch", 1)
                # executed reduction: under plain jit AD inserts the DP
                # mean implicitly, which GSPMD lowers to the flat ring
                # the "ring" plan models; non-ring verdicts need the
                # shard_map planned_psum lowering (core/collectives.py)
                note = ("matches the implicit GSPMD ring this jit step "
                        "executes" if gs.plan == "ring" else
                        "needs the shard_map planned_psum lowering; this "
                        "jit step executes the implicit ring")
                logging.info(
                    "planner gradient sync: %s G=%d (serial %.2fms -> "
                    "%.2fms pipelined; ring baseline %.2fms) — %s",
                    gs.plan, g, gs.predicted_serial_s * 1e3,
                    gs.predicted_s * 1e3, gs.baseline_s * 1e3, note)
        elif pctx.plan_policy == "auto":
            logging.info("planner auto: no collective sites to declare "
                         "for this config (dense, no split-TP gather)")
        else:
            logging.info("planner fixed: moe_scheme=%s moe_combine=%s "
                         "moe_microbatch=%d",
                         pctx.moe_scheme,
                         pctx.moe_combine or pctx.moe_scheme,
                         pctx.moe_microbatch)

    model = build_model(cfg, pctx,
                        dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                  global_batch=batch, seed=args.seed))
    opt = adamw(lr=cosine_schedule(args.lr, warmup=min(100, args.steps // 10
                                                       or 1),
                                   total=args.steps), weight_decay=0.01)
    tcfg = TrainerConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every,
                         checkpoint_dir=args.ckpt_dir, log_every=10)

    # LIVE overlap-efficiency feedback (ROADMAP debt): pipelined moe_ffn
    # step wall times flow through Planner.note_measurement into the
    # joint decision's log rows, so DriftMonitor's fit_overlap_eff is fed
    # by the real training loop — not just SimProbe/synthetic rows.
    attribution = None
    if monitor is not None and eplan is not None:
        from repro.telemetry import StepAttribution
        joint = next((d for d in eplan.joint.values()
                      if d.microbatch > 1), None)
        if joint is not None:
            from repro.core.planner import default_planner
            attribution = StepAttribution(
                default_planner(), joint,
                n_layers=max(1, cfg.n_layers
                             - getattr(cfg, "first_k_dense", 0)))

    step_hook = None
    if attribution is not None or args.calibrate == "online":
        stale_warned = [False]

        def step_hook(step, row, _every=max(1, args.calibrate_every)):
            if attribution is not None:
                attribution.observe_step(row["wall"])
            if args.calibrate != "online" or step == 0 or step % _every:
                return
            event = monitor.run_cycle(probe)
            if event:
                logging.info(
                    "step %d: drift %.1f%% exceeded %.0f%% — recalibrated "
                    "(%d links refit, overlap_eff=%s, %d program(s) "
                    "replanned); planner cache invalidated",
                    step, 100 * event["drift"],
                    100 * monitor.threshold, event["measured_links"],
                    event.get("overlap_eff"),
                    len(event.get("programs", [])))
                if (pctx is not None and not stale_warned[0]
                        and pctx.bound_plan_stale()):
                    stale_warned[0] = True
                    from repro.telemetry import default_registry
                    default_registry()["repro_plan_stale_total"].inc(
                        program=eplan.program.name,
                        fingerprint=eplan.fingerprint)
                    logging.warning(
                        "step %d: bound ExecutionPlan %s is now STALE — "
                        "the replan under the refit calibration chose "
                        "different decisions; training keeps executing "
                        "the old plan until re-trace (hot re-bind not "
                        "wired yet)", step, eplan.fingerprint)

    trainer = Trainer(model, opt,
                      lambda s: batch_for_model(cfg, data.batch(s)),
                      tcfg, init_rng=jax.random.key(args.seed),
                      step_hook=step_hook)
    hist = trainer.run()
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps; "
              f"straggler events: {len(trainer.ledger.events)}")
    if monitor is not None:
        rep = monitor.report()
        print(f"calibration: {rep['recalibrations']} recalibration(s), "
              f"drift {rep['drift_pct']:.1f}%, "
              f"{rep['store_records']} store records")
    if attribution is not None:
        print(f"overlap feedback: {attribution.fed} step timing(s) fed "
              f"into the joint pipeline decision's measurement rows")
    finish_exporter_from_args(args, exporter)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
