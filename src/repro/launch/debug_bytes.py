"""Debug utility: attribute HBM-byte estimates to HLO instructions.

Usage:
  XLA_FLAGS=--xla_force_host_platform_device_count=512 PYTHONPATH=src \
    python -m repro.launch.debug_bytes --arch X --shape Y [--multi-pod]

Prints the top-N instructions by multiplicity-weighted traffic — the
profiling view the §Perf loop reads (no real-TPU trace exists here).
"""

from __future__ import annotations

import re
from collections import defaultdict

from repro.launch import hlo_module as H


def attribute_bytes(text: str, top: int = 20, layout=None):
    from repro.launch.hlo_analysis import MeshLayout
    from repro.launch.hlo_module import analyze_module
    if layout is None:
        layout = MeshLayout(("data", "model"), (16, 16))
    rows = []
    cost = analyze_module(text, layout, collect_rows=rows)
    rows.sort(reverse=True)
    print(f"total HBM-byte estimate: {cost.hbm_bytes:.3e}")
    for w, m, op, name, ob, cname in rows[:top]:
        print(f"{w/1e9:9.2f} GB  x{m:6.0f}  {op:18s} out={ob/1e6:9.1f}MB  "
              f"{name[:44]:44s} in {cname[:24]}")
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="mw")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()
    from repro.launch.dryrun import VARIANTS, input_specs, SHAPES
    from repro.launch.mesh import make_pctx
    kw = dict(VARIANTS[args.variant])
    if SHAPES[args.shape].kind != "train":
        kw.setdefault("fsdp", False)
    pctx = make_pctx(multi_pod=args.multi_pod, **kw)
    kind, fn, fargs = input_specs(args.arch, args.shape, pctx)
    with pctx.mesh:
        compiled = fn.lower(*fargs).compile()
    attribute_bytes(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
