"""Long-term soak harness: scripted degradations against the live loop.

The paper's headline evidence is "long-term stress tests on commercially
deployed devices" — this driver is our equivalent, built on SimProbe's
injectable :class:`GroundTruth`.  One bound collective program serves
for N simulated hours while the harness mutates the fabric truth on a
scripted schedule (rail slowdowns, asymmetric single-direction
slowdowns, recoveries), runs one full telemetry cycle per epoch, and
scrapes its own Prometheus exporter over real HTTP each epoch — the
same bytes an operator's scrape job would pull.

End-to-end assertions over the whole run:

    detection     every injected event trips a recalibration within
                  ``--detect-within`` epochs
    convergence   after a class-uniform event, the trusted "inter" fit
                  lands within 20% of the injected true rail bandwidth
    flips         the planner's post-cycle decision for the monitored
                  dispatch cell equals a fresh ORACLE planner scored on
                  the hidden truth (grace window while drift is being
                  detected), and at least one genuine scheme flip occurs
    stale         stale-bound-plan warnings fire EXACTLY once per
                  changed-program recalibration (re-bind resets the
                  one-shot)
    slo           the scraped per-cell SLO classification transitions
                  good -> poor (stale model at the degradation epoch)
                  -> good (post-recalibration)

Writes ``results/STRESS_soak.json`` with the full timeline.

    PYTHONPATH=src python -m repro.launch.stress            # full soak
    PYTHONPATH=src python -m repro.launch.stress --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.core.planner import Planner, bucket_payload
from repro.core.topology import get_fabric
from repro.telemetry import (CalibrationStore, DriftMonitor, GroundTruth,
                             MetricsExporter, SimProbe, parse_text,
                             reset_default_registry, scrape)
from repro.telemetry.probe import link_class

TOKEN_BYTES = 7168
FLIP_BATCH = 64            # the Fig 8 cell bench_calibration validates:
#   unicast healthy, multiwrite under a 4x rail slowdown (2x8)
SLO_BATCH = 512            # large-payload cell whose SLO the scrape tracks


# ---------------------------------------------------------------------------
# truth mutations (the degradation schedule's vocabulary)
# ---------------------------------------------------------------------------

def apply_event(truth: GroundTruth, topo, event: dict) -> GroundTruth:
    kind = event["kind"]
    if kind == "degrade":
        return truth.degraded(topo, event.get("factor", 4.0))
    if kind == "recover":
        # drop every per-link override AND any blackout: healthy again
        return dataclasses.replace(truth, link_bw=(), dead_links=())
    if kind == "asym":
        # one rail DIRECTION slows down (src_server -> everyone else);
        # the return direction stays healthy — the per-role fit case
        factor = float(event.get("factor", 4.0))
        src_server = int(event.get("src_server", 0))
        cur = dict(truth.link_bw)
        links = {}
        for key, ln in topo.links.items():
            if (link_class(topo, *key) == "inter"
                    and topo.server_of(key[0]) == src_server):
                links[key] = cur.get(key, ln.bw) / factor
        return truth.with_links(links)
    raise ValueError(f"unknown stress event kind {event['kind']!r}")


def true_inter_bw(truth: GroundTruth, topo) -> float:
    """Mean bandwidth the truth's inter-server links actually deliver."""
    cur = dict(truth.link_bw)
    bws = [cur.get(key, ln.bw) for key, ln in topo.links.items()
           if link_class(topo, *key) == "inter"]
    return sum(bws) / len(bws) if bws else 0.0


def build_schedule(epochs: int, smoke: bool) -> list[dict]:
    """Scripted degradation schedule over ``epochs`` probe cycles."""
    if smoke:
        return [{"epoch": 1, "kind": "degrade", "factor": 4.0},
                {"epoch": max(3, epochs - 2), "kind": "recover"}]
    marks = [(0.12, {"kind": "degrade", "factor": 4.0}),
             (0.33, {"kind": "recover"}),
             (0.55, {"kind": "asym", "factor": 4.0, "src_server": 0}),
             (0.78, {"kind": "recover"})]
    return [{"epoch": max(1, int(frac * epochs)), **ev}
            for frac, ev in marks]


# ---------------------------------------------------------------------------
# the soak loop
# ---------------------------------------------------------------------------

def _metric(parsed: dict, name: str, **labels) -> float:
    """One scraped sample, 0.0 when the series has no samples yet."""
    want = tuple(sorted((k, str(v)) for k, v in labels.items()))
    for (n, lbls), v in parsed.items():
        if n == name and tuple(l for l in lbls
                               if l[0] in labels) == want:
            return v
    return 0.0


def run_soak(*, fabric: str = "2x8", epochs: int = 48,
             epoch_minutes: float = 10.0, noise: float = 0.01,
             seed: int = 0, detect_within: int = 2,
             smoke: bool = False, out_path: str | None = None,
             port: int = 0) -> dict:
    reset_default_registry()
    topo = get_fabric(fabric)
    planner = Planner()
    store = CalibrationStore(":memory:")
    monitor = DriftMonitor(planner, store, topo)
    truth = GroundTruth(noise=noise, seed=seed)
    schedule = build_schedule(epochs, smoke)
    by_epoch = {ev["epoch"]: ev for ev in schedule}

    # the bound program: a prefill/decode serving shape — prefill sits at
    # the Fig 8 flip cell (scheme changes under a rail slowdown), decode
    # stays small-payload unicast
    from repro.core import plan as plan_ir
    program = plan_ir.CollectiveProgram(
        name="stress_serve",
        sites=(*plan_ir.moe_sites("prefill", num_experts=64, top_k=8,
                                  tokens_per_rank=FLIP_BATCH,
                                  token_bytes=TOKEN_BYTES),
               *plan_ir.moe_sites("decode", num_experts=64, top_k=8,
                                  tokens_per_rank=4,
                                  token_bytes=TOKEN_BYTES)))
    eplan = planner.plan_program(program, topo)
    flip_payload = float(FLIP_BATCH) * TOKEN_BYTES
    slo_bucket = bucket_payload(float(SLO_BATCH) * TOKEN_BYTES)

    exporter = MetricsExporter(port).start()
    stale_warned = [False]
    stale_warnings: list[int] = []

    def check_stale(epoch: int) -> bool:
        """The launcher-style one-shot stale check (run twice per epoch
        to PROVE the warning cannot double-fire)."""
        stale = planner.plan_is_stale(eplan)
        if stale and not stale_warned[0]:
            stale_warned[0] = True
            stale_warnings.append(epoch)
            from repro.telemetry import default_registry
            default_registry()["repro_plan_stale_total"].inc(
                program=program.name, fingerprint=eplan.fingerprint)
            print(f"epoch {epoch}: WARNING bound plan "
                  f"{eplan.fingerprint} is stale (replan chose "
                  f"different decisions)")
        return bool(stale)

    timeline: list[dict] = []
    recal_epochs: list[int] = []
    changed_recals: list[int] = []
    prev_scrape: dict = {}
    prev_plan: str | None = None
    t_wall = time.monotonic()
    try:
        for epoch in range(epochs):
            event = by_epoch.get(epoch)
            if event is not None:
                truth = apply_event(truth, topo, event)
                print(f"epoch {epoch}: injected {event['kind']} "
                      f"(true inter bw now "
                      f"{true_inter_bw(truth, topo) / 1e9:.2f} GB/s)")
            # fresh probe rng per epoch: run-to-run jitter, not one
            # frozen noise draw replayed forever
            probe = SimProbe(dataclasses.replace(truth,
                                                 seed=seed + 1000 + epoch))
            recal = monitor.run_cycle(probe)
            if recal is not None:
                recal_epochs.append(epoch)
                if any(p["changed"] for p in recal.get("programs", [])):
                    changed_recals.append(epoch)
            # one-shot stale surface + hot re-bind (checked twice: the
            # second call must never warn again)
            was_stale = check_stale(epoch)
            check_stale(epoch)
            if was_stale:
                eplan = monitor.replanned(program.name) or \
                    planner.plan_program(program, topo)
                stale_warned[0] = False
            # post-cycle planner verdict vs a fresh oracle on the truth
            decision = planner.choose("dispatch", flip_payload, topo)
            oracle = Planner(hw=truth.true_hw()).choose(
                "dispatch", flip_payload, topo)
            # the operator's view: scrape our own exporter over HTTP
            parsed = parse_text(scrape(exporter.url))
            slo_deltas = {
                cls: (_metric(parsed, "repro_slo_class_total",
                              op="dispatch", payload_bucket=slo_bucket,
                              slo=cls)
                      - _metric(prev_scrape, "repro_slo_class_total",
                                op="dispatch", payload_bucket=slo_bucket,
                                slo=cls))
                for cls in ("good", "acceptable", "poor")}
            # epoch class = WORST class observed this epoch (SLOs report
            # the tail, not the mode — one poor probe among good ones
            # makes the cell poor)
            slo_class = next((cls for cls in ("poor", "acceptable", "good")
                              if slo_deltas.get(cls, 0) > 0), None)
            row = {
                "epoch": epoch,
                "sim_time_h": round(epoch * epoch_minutes / 60.0, 3),
                "event": event,
                "true_inter_gbps": true_inter_bw(truth, topo) / 1e9,
                "drift_pct": round(100 * monitor.drift(), 2),
                "recalibrated": recal is not None,
                "fits": recal["fits"] if recal else None,
                "planner_plan": decision.plan,
                "oracle_plan": oracle.plan,
                "flipped": (prev_plan is not None
                            and decision.plan != prev_plan),
                "bound_fingerprint": eplan.fingerprint,
                "stale_warned": was_stale,
                "slo_class": slo_class,
                "scrape": {
                    "drift_ratio": _metric(parsed, "repro_drift_ratio",
                                           op="dispatch"),
                    "recalibrations": _metric(
                        parsed, "repro_recalibrations_total"),
                    "decision_flips": sum(
                        v for (n, lbls), v in parsed.items()
                        if n == "repro_planner_decision_flips_total"),
                    "slo_deltas": slo_deltas,
                },
            }
            timeline.append(row)
            prev_scrape = parsed
            prev_plan = decision.plan
    finally:
        exporter.stop()

    # -- the five end-to-end assertions -------------------------------------
    failures: list[str] = []

    def check(name: str, ok: bool, detail: str) -> dict:
        if not ok:
            failures.append(f"{name}: {detail}")
        return {"name": name, "ok": bool(ok), "detail": detail}

    # 1. detection latency: every event trips a recal within the window
    latencies = {}
    for ev in schedule:
        hit = next((r for r in recal_epochs
                    if ev["epoch"] <= r <= ev["epoch"] + detect_within),
                   None)
        latencies[ev["epoch"]] = (None if hit is None
                                  else hit - ev["epoch"])
    a_detect = check(
        "detection",
        all(v is not None for v in latencies.values()),
        f"recal latency per event epoch: {latencies} "
        f"(window {detect_within})")

    # 2. convergence: after a class-uniform event, the trusted inter fit
    #    lands within 20% of the injected truth
    conv = []
    for ev in schedule:
        if ev["kind"] not in ("degrade", "recover"):
            continue
        rows = [r for r in timeline
                if r["recalibrated"] and r["fits"]
                and ev["epoch"] <= r["epoch"] <= ev["epoch"]
                + detect_within]
        if not rows:
            conv.append((ev["epoch"], None, None, False))
            continue
        fit = rows[-1]["fits"].get("inter", {})
        fitted = fit.get("bw_gbps", 0.0) * 1e9
        true_bw = (rows[-1]["true_inter_gbps"]) * 1e9
        ok = (fit.get("trusted", False) and true_bw > 0
              and abs(fitted - true_bw) / true_bw <= 0.20)
        conv.append((ev["epoch"], round(fitted / 1e9, 2),
                     round(true_bw / 1e9, 2), ok))
    a_conv = check(
        "convergence", all(c[-1] for c in conv),
        f"(event_epoch, fitted_gbps, true_gbps, ok): {conv}")

    # 3. decision flips match the oracle: outside detection grace
    #    windows the fitted planner and the truth oracle must agree,
    #    and at least one genuine scheme flip must have happened
    grace = {e for ev in schedule
             for e in range(ev["epoch"],
                            ev["epoch"] + detect_within + 1)}
    mismatches = [r["epoch"] for r in timeline
                  if r["epoch"] not in grace
                  and r["planner_plan"] != r["oracle_plan"]]
    n_flips = sum(1 for r in timeline if r["flipped"])
    a_flips = check(
        "flips", not mismatches and n_flips >= 1,
        f"planner-vs-oracle mismatches at epochs {mismatches}; "
        f"{n_flips} genuine flip(s) observed")

    # 4. stale warnings: exactly once per changed-program recalibration
    a_stale = check(
        "stale", stale_warnings == changed_recals,
        f"stale warnings at {stale_warnings}, changed-program recals "
        f"at {changed_recals}")

    # 5. SLO transition good -> poor -> good around the first degrade
    deg = next(ev["epoch"] for ev in schedule if ev["kind"] == "degrade")
    classes = [r["slo_class"] for r in timeline]
    pre = [c for c in classes[:deg] if c]
    post = [c for c in classes[deg + 1:] if c]
    a_slo = check(
        "slo",
        bool(pre) and pre[-1] == "good"
        and classes[deg] == "poor"
        and "good" in post,
        f"classes around degrade@{deg}: pre={pre[-2:]} "
        f"at={classes[deg]} post={post[:3]}")

    assertions = [a_detect, a_conv, a_flips, a_stale, a_slo]

    # 6. asymmetric-degradation windows settle after ONE recalibration:
    #    per-role fit attribution books each probe against the truly
    #    bottlenecking direction, so the slow direction's fit converges
    #    instead of alternating with the healthy return rail and
    #    re-tripping the drift threshold every epoch
    for ev in schedule:
        if ev["kind"] != "asym":
            continue
        nxt = min((e["epoch"] for e in schedule
                   if e["epoch"] > ev["epoch"]), default=epochs)
        in_window = [e for e in recal_epochs if ev["epoch"] <= e < nxt]
        assertions.append(check(
            "asym_window", len(in_window) <= 1,
            f"recalibrations during asym window "
            f"[{ev['epoch']}, {nxt}): {in_window} (churn if > 1)"))

    result = {
        "config": {"fabric": fabric, "epochs": epochs,
                   "epoch_minutes": epoch_minutes,
                   "sim_hours": round(epochs * epoch_minutes / 60.0, 2),
                   "noise": noise, "seed": seed, "smoke": smoke,
                   "detect_within": detect_within,
                   "flip_batch": FLIP_BATCH, "slo_batch": SLO_BATCH},
        "ts": time.time(),
        "wall_s": round(time.monotonic() - t_wall, 2),
        "schedule": schedule,
        "assertions": assertions,
        "ok": not failures,
        "timeline": timeline,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "..", "..",
                                "..", "results", "STRESS_soak.json")
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    for a in result["assertions"]:
        print(f"[{'ok' if a['ok'] else 'FAIL'}] {a['name']}: {a['detail']}")
    print(f"soak: {epochs} epoch(s) over "
          f"{result['config']['sim_hours']}h simulated, "
          f"{len(recal_epochs)} recalibration(s), "
          f"{len(stale_warnings)} stale warning(s) -> {out_path}")
    if failures:
        for fmsg in failures:
            print(f"STRESS FAILURE: {fmsg}", file=sys.stderr)
    return result


# ---------------------------------------------------------------------------
# failure-events soak: rail blackout -> detect -> reroute -> hot re-bind
# ---------------------------------------------------------------------------

def run_failure_soak(*, fabric: str = "2x8", epochs: int = 8,
                     noise: float = 0.01, seed: int = 0,
                     detect_within: int = 2,
                     out_path: str | None = None, port: int = 0) -> dict:
    """The fault-tolerance arc end-to-end: a rail goes DARK mid-serve
    (both directions of one inter-server link stop carrying probe
    traffic), the FailureDetector declares it dead within
    ``detect_within`` cycles, the planner retargets the bound program
    around it on the surviving capacity graph, the staged replacement
    plan hot-swaps in at a step boundary with ZERO cold retraces, no
    executed plan ever charges the dark rail outside the detection
    grace window, and recovery flips the decisions back.

    Writes ``results/STRESS_failover.json``.
    """
    from repro.core.planner import ledger_infeasible, plan_site_ledgers
    from repro.core.topology import FailureState
    from repro.parallel.context import PlanBinder
    from repro.telemetry.failover import FailureDetector

    reset_default_registry()
    topo = get_fabric(fabric)
    planner = Planner()
    store = CalibrationStore(":memory:")
    detector = FailureDetector(topo, strikes=min(2, detect_within))
    monitor = DriftMonitor(planner, store, topo, detector=detector)
    truth = GroundTruth(noise=noise, seed=seed)

    # the blacked-out rail: the first inter-server link, both directions
    # (a dark cable is dark both ways)
    rail = detector.rails[0]
    blackout = {rail, (rail[1], rail[0])}
    blackout_epoch = 1
    restore_epoch = max(blackout_epoch + detect_within + 2, epochs - 3)
    schedule = [
        {"epoch": blackout_epoch, "kind": "blackout",
         "links": sorted(blackout)},
        {"epoch": restore_epoch, "kind": "restore"},
    ]
    by_epoch = {ev["epoch"]: ev for ev in schedule}

    from repro.core import plan as plan_ir
    program = plan_ir.CollectiveProgram(
        name="stress_serve",
        sites=(*plan_ir.moe_sites("prefill", num_experts=64, top_k=8,
                                  tokens_per_rank=FLIP_BATCH,
                                  token_bytes=TOKEN_BYTES),
               *plan_ir.moe_sites("decode", num_experts=64, top_k=8,
                                  tokens_per_rank=4,
                                  token_bytes=TOKEN_BYTES)))
    eplan = planner.plan_program(program, topo)

    def decisions_of(plan) -> dict:
        return {role: (plan.decisions[role].plan,
                       tuple(plan.decisions[role].knobs))
                for role in sorted(plan.decisions)}

    pre_blackout = decisions_of(eplan)
    plan_topos = {eplan.fingerprint: topo}

    # the "traced lowering": the failure soak runs no real model, so the
    # artifact is a build receipt — what matters is WHEN builds happen
    # (stage time, off the step path) and that swaps never build
    trace_log: list[str] = []

    def trace_fn(plan):
        trace_log.append(plan.fingerprint)
        return {"fingerprint": plan.fingerprint}

    binder = PlanBinder(trace_fn, plan=eplan)

    # live queued traffic rides through the blackout: a seeded open-loop
    # Poisson stream drains through the continuous-batching scheduler
    # (virtual clock) WHILE the fault arc runs.  Epochs whose active
    # plan still charges the dark rail quadruple the virtual step time
    # (the degraded fabric); the drain must lose nothing.
    from repro.serving import (AdmissionController, BatchScheduler,
                               PlannerProbe, RequestQueue, TrafficConfig,
                               TrafficGenerator)
    traffic_window_s = 0.25          # virtual serving time per soak epoch
    n_traffic = 120
    sprobe = PlannerProbe(topo, token_bytes=TOKEN_BYTES)
    traffic_tpot_slo = sprobe.decode_step_s(FLIP_BATCH) * 1.15
    queue = RequestQueue()
    for req in TrafficGenerator(TrafficConfig(
            arrival_rate_rps=n_traffic / (0.6 * epochs * traffic_window_s),
            num_requests=n_traffic, prompt_lens=(128,), max_news=(16,),
            seed=seed + 77)).requests():
        queue.push(req)
    sched = BatchScheduler(
        queue=queue,
        admission=AdmissionController(sprobe, capacity=FLIP_BATCH,
                                      policy="planner",
                                      tpot_slo_s=traffic_tpot_slo,
                                      ttft_slo_s=0.08),
        probe=sprobe)
    deg_start = deg_end = None

    exporter = MetricsExporter(port).start()
    timeline: list[dict] = []
    swap_epochs: list[int] = []
    detect_log: list[dict] = []
    recal_epochs: list[int] = []
    t_wall = time.monotonic()
    try:
        for epoch in range(epochs):
            # step boundary: a staged re-bind lands HERE, never mid-epoch
            if binder.swap_if_pending():
                swap_epochs.append(epoch)
            event = by_epoch.get(epoch)
            if event is not None:
                if event["kind"] == "blackout":
                    truth = truth.with_dead(blackout)
                    print(f"epoch {epoch}: rail "
                          f"{rail[0]}<->{rail[1]} went DARK")
                else:
                    truth = dataclasses.replace(truth, dead_links=())
                    print(f"epoch {epoch}: rail restored")
            probe = SimProbe(dataclasses.replace(truth,
                                                 seed=seed + 1000 + epoch))
            n_det = len(detector.events)
            recal = monitor.run_cycle(probe)
            if recal is not None:
                recal_epochs.append(epoch)
            for ev in detector.events[n_det:]:
                detect_log.append({"epoch": epoch, **{
                    k: (list(v) if isinstance(v, tuple) else v)
                    for k, v in ev.items()}})
            # stage the latest retargeted plan (no-op when it is already
            # the active one); its lowering builds NOW, off the step path
            staged = monitor.staged_plan(program.name)
            staged_now = False
            if staged is not None:
                plan_topos.setdefault(staged.fingerprint, monitor.topo)
                staged_now = binder.stage(staged)
            # feasibility audit of the ACTIVE plan against hidden TRUTH
            truth_failures = FailureState(
                dead_links=set(truth.dead_links))
            active = binder.plan
            ledgers = plan_site_ledgers(
                active, plan_topos[active.fingerprint])
            violations = sorted(
                role for role, led in ledgers.items()
                if ledger_infeasible(led, truth_failures) is not None)
            # serve this epoch's slice of the request stream under the
            # fabric the active plan actually gets: dark-rail epochs run
            # at 4x virtual step time until the failover swap lands
            if violations and deg_start is None:
                deg_start = sched.now
            if not violations and deg_start is not None and deg_end is None:
                deg_end = sched.now
            sched.step_time_scale = 4.0 if violations else 1.0
            sched.run_for(traffic_window_s)
            parsed = parse_text(scrape(exporter.url))
            timeline.append({
                "epoch": epoch,
                "event": event,
                "truth_dead": sorted(truth.dead_links),
                "detector_dead": sorted(detector.dead_links()),
                "active_fingerprint": active.fingerprint,
                "active_decisions": decisions_of(active),
                "swapped": epoch in swap_epochs,
                "staged": staged_now,
                "violations": violations,
                "recalibrated": recal is not None,
                "traffic": {"now_s": sched.now,
                            "completed": len(sched.completed),
                            "queue_depth": len(queue),
                            "in_flight": sched.in_flight,
                            "degraded": bool(violations)},
                "scrape": {
                    "failed_links": _metric(parsed, "repro_failed_links",
                                            fabric=fabric),
                    "rebinds": sum(
                        v for (n, _), v in parsed.items()
                        if n == "repro_plan_rebind_total"),
                    "cold_retraces": sum(
                        v for (n, _), v in parsed.items()
                        if n == "repro_rebind_cold_retrace_total"),
                    "infeasible_masked": sum(
                        v for (n, _), v in parsed.items()
                        if n == "repro_plan_infeasible_total"),
                },
            })
    finally:
        exporter.stop()

    # post-recovery drain: whatever the blackout backed up must finish
    # on the healthy fabric
    sched.step_time_scale = 1.0
    sched.run_until_drained()
    if deg_start is not None and deg_end is None:
        deg_end = sched.now

    failures_list: list[str] = []

    def check(name: str, ok: bool, detail: str) -> dict:
        if not ok:
            failures_list.append(f"{name}: {detail}")
        return {"name": name, "ok": bool(ok), "detail": detail}

    # 1. detection: both directions of the dark rail declared dead
    #    within the window, and revived within the window after restore
    dead_at = {tuple(e["link"]): e["epoch"] for e in detect_log
               if e["kind"] == "link_dead"}
    revived_at = {tuple(e["link"]): e["epoch"] for e in detect_log
                  if e["kind"] == "link_recovered"}
    a_detect = check(
        "detection",
        all(blackout_epoch <= dead_at.get(k, 10 ** 9)
            <= blackout_epoch + detect_within for k in blackout)
        and all(restore_epoch <= revived_at.get(k, 10 ** 9)
                <= restore_epoch + detect_within for k in blackout),
        f"dead_at={dead_at} revived_at={revived_at} "
        f"(blackout@{blackout_epoch}, restore@{restore_epoch}, "
        f"window {detect_within})")

    # 2. reroute: the failover swap lands within one step of detection
    #    and the swapped-in plan's ledgers avoid the dark rail
    first_dead = min(dead_at.values(), default=None)
    failover_swap = next((e for e in swap_epochs
                          if e > blackout_epoch), None)
    all_violations = [(r["epoch"], r["violations"]) for r in timeline
                      if r["violations"]]
    a_reroute = check(
        "reroute",
        first_dead is not None and failover_swap is not None
        and failover_swap <= first_dead + 1
        and all(not r["violations"] for r in timeline
                if failover_swap <= r["epoch"] < restore_epoch),
        f"first link declared dead @{first_dead}, failover swap "
        f"@{failover_swap}, post-swap violations: {all_violations}")

    # 3. no infeasible execution outside the detection grace window
    #    (the plan bound when the rail dies keeps executing until the
    #    detector has evidence — that window is bounded, not zero)
    grace = set(range(blackout_epoch,
                      (failover_swap if failover_swap is not None
                       else blackout_epoch + detect_within + 2)))
    bad = [(r["epoch"], r["violations"]) for r in timeline
           if r["violations"] and r["epoch"] not in grace]
    a_exec = check(
        "no_dead_exec", not bad and len(grace) <= detect_within + 2,
        f"dead-link executions outside grace {sorted(grace)}: {bad}")

    # 4. hot re-bind: exactly one swap per transition, all lowerings
    #    built at stage time — zero cold retraces at swap time
    a_rebind = check(
        "rebind",
        binder.swaps == 2 and binder.cold_retraces == 0
        and len(trace_log) == binder.cache_misses,
        f"swaps={binder.swaps} (want 2: failover + failback) "
        f"cold_retraces={binder.cold_retraces} "
        f"builds={len(trace_log)} cache_misses={binder.cache_misses}")

    # 5. flip-back: after recovery the active plan's DECISIONS equal the
    #    pre-blackout plan's (fingerprints may differ — calibration
    #    refits during the blackout legitimately move hw identity)
    final = timeline[-1]["active_decisions"]
    a_flip = check(
        "flipback", final == pre_blackout
        and any(e.get("kind") == "failback" for e in monitor.events),
        f"final decisions {final} vs pre-blackout {pre_blackout}; "
        f"monitor events: "
        f"{[e.get('kind') for e in monitor.events]}")

    # 6. traffic: the dark-rail drain loses NOTHING — every arrived
    #    request is admitted and completes; the degraded window's TTFT
    #    spike stays bounded by the window itself (no unbounded
    #    starvation); and after recovery the TTFT tail returns to the
    #    healthy band
    from repro.serving.scheduler import _pctl
    from repro.telemetry.metrics import default_registry
    reg = default_registry()
    admitted_m = reg["repro_requests_total"].value(outcome="admitted")
    completed_m = reg["repro_requests_total"].value(outcome="completed")
    pre = [r.ttft_s for r in sched.completed
           if deg_start is None or r.first_token_s < deg_start]
    # recovery is judged on requests that ARRIVED after the degraded
    # window closed (first-token timing alone still carries the
    # blackout backlog's queueing tail)
    post = [r.ttft_s for r in sched.completed
            if deg_end is not None and r.arrival_s >= deg_end]
    pre_p99 = _pctl(pre, 99)
    post_p99 = _pctl(post, 99)
    spike = max((r.ttft_s for r in sched.completed), default=0.0)
    deg_len = ((deg_end - deg_start)
               if deg_start is not None and deg_end is not None else 0.0)
    a_traffic = check(
        "traffic",
        len(sched.completed) == n_traffic and len(queue) == 0
        and sched.in_flight == 0 and admitted_m == completed_m == n_traffic
        and deg_len > 0 and pre and post
        and spike <= deg_len + max(10 * pre_p99, 0.05)
        # 2.5x, not 1x: post-drain concurrency is higher than the light
        # pre-blackout warmup, so iterations are legitimately longer
        and post_p99 <= 2.5 * pre_p99 and post_p99 <= 0.5 * spike,
        f"completed={len(sched.completed)}/{n_traffic} "
        f"(metrics admitted={admitted_m:.0f} completed={completed_m:.0f}), "
        f"degraded window {deg_len * 1e3:.0f}ms, max TTFT "
        f"{spike * 1e3:.1f}ms, p99 TTFT pre/post "
        f"{pre_p99 * 1e3:.1f}/{post_p99 * 1e3:.1f}ms")

    result = {
        "config": {"fabric": fabric, "epochs": epochs, "noise": noise,
                   "seed": seed, "detect_within": detect_within,
                   "blackout_rail": sorted(blackout),
                   "blackout_epoch": blackout_epoch,
                   "restore_epoch": restore_epoch,
                   "traffic": {"requests": n_traffic,
                               "window_s": traffic_window_s,
                               "tpot_slo_s": traffic_tpot_slo}},
        "ts": time.time(),
        "wall_s": round(time.monotonic() - t_wall, 2),
        "schedule": schedule,
        "detections": detect_log,
        "swap_epochs": swap_epochs,
        "recal_epochs": recal_epochs,
        "assertions": [a_detect, a_reroute, a_exec, a_rebind, a_flip,
                       a_traffic],
        "ok": not failures_list,
        "timeline": timeline,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "..", "..",
                                "..", "results", "STRESS_failover.json")
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    for a in result["assertions"]:
        print(f"[{'ok' if a['ok'] else 'FAIL'}] {a['name']}: {a['detail']}")
    print(f"failure soak: {epochs} epoch(s), blackout@{blackout_epoch} "
          f"restore@{restore_epoch}, {binder.swaps} swap(s), "
          f"{binder.cold_retraces} cold retrace(s) -> {out_path}")
    if failures_list:
        for fmsg in failures_list:
            print(f"STRESS FAILURE: {fmsg}", file=sys.stderr)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fabric", default="2x8")
    ap.add_argument("--hours", type=float, default=8.0,
                    help="simulated soak duration")
    ap.add_argument("--epoch-minutes", type=float, default=10.0,
                    help="simulated probe cadence (one telemetry cycle "
                         "per epoch)")
    ap.add_argument("--noise", type=float, default=0.01,
                    help="lognormal measurement jitter sigma")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--detect-within", type=int, default=2,
                    help="max epochs between an injected event and its "
                         "recalibration")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 6-epoch soak with one degradation + "
                         "recovery")
    ap.add_argument("--failure-events", action="store_true",
                    help="run the fault-tolerance arc instead: rail "
                         "blackout -> detect -> reroute -> hot re-bind "
                         "-> recover (results/STRESS_failover.json)")
    ap.add_argument("--epochs", type=int, default=None,
                    help="failure-events soak length (default 10)")
    ap.add_argument("--out", default=None,
                    help="result JSON path (default "
                         "results/STRESS_soak.json)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="exporter port the soak scrapes (0 = ephemeral)")
    args = ap.parse_args(argv)
    if args.failure_events:
        result = run_failure_soak(
            fabric=args.fabric, epochs=args.epochs or 10,
            noise=args.noise, seed=args.seed,
            detect_within=args.detect_within, out_path=args.out,
            port=args.metrics_port)
        return 0 if result["ok"] else 1
    epochs = (6 if args.smoke
              else max(4, int(args.hours * 60 / args.epoch_minutes)))
    result = run_soak(fabric=args.fabric, epochs=epochs,
                      epoch_minutes=args.epoch_minutes, noise=args.noise,
                      seed=args.seed, detect_within=args.detect_within,
                      smoke=args.smoke, out_path=args.out,
                      port=args.metrics_port)
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
