"""Long-term soak harness: scripted degradations against the live loop.

The paper's headline evidence is "long-term stress tests on commercially
deployed devices" — this driver is our equivalent, built on SimProbe's
injectable :class:`GroundTruth`.  One bound collective program serves
for N simulated hours while the harness mutates the fabric truth on a
scripted schedule (rail slowdowns, asymmetric single-direction
slowdowns, recoveries), runs one full telemetry cycle per epoch, and
scrapes its own Prometheus exporter over real HTTP each epoch — the
same bytes an operator's scrape job would pull.

End-to-end assertions over the whole run:

    detection     every injected event trips a recalibration within
                  ``--detect-within`` epochs
    convergence   after a class-uniform event, the trusted "inter" fit
                  lands within 20% of the injected true rail bandwidth
    flips         the planner's post-cycle decision for the monitored
                  dispatch cell equals a fresh ORACLE planner scored on
                  the hidden truth (grace window while drift is being
                  detected), and at least one genuine scheme flip occurs
    stale         stale-bound-plan warnings fire EXACTLY once per
                  changed-program recalibration (re-bind resets the
                  one-shot)
    slo           the scraped per-cell SLO classification transitions
                  good -> poor (stale model at the degradation epoch)
                  -> good (post-recalibration)

Writes ``results/STRESS_soak.json`` with the full timeline.

    PYTHONPATH=src python -m repro.launch.stress            # full soak
    PYTHONPATH=src python -m repro.launch.stress --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.core.planner import Planner, bucket_payload
from repro.core.topology import get_fabric
from repro.telemetry import (CalibrationStore, DriftMonitor, GroundTruth,
                             MetricsExporter, SimProbe, parse_text,
                             reset_default_registry, scrape)
from repro.telemetry.probe import link_class

TOKEN_BYTES = 7168
FLIP_BATCH = 64            # the Fig 8 cell bench_calibration validates:
#   unicast healthy, multiwrite under a 4x rail slowdown (2x8)
SLO_BATCH = 512            # large-payload cell whose SLO the scrape tracks


# ---------------------------------------------------------------------------
# truth mutations (the degradation schedule's vocabulary)
# ---------------------------------------------------------------------------

def apply_event(truth: GroundTruth, topo, event: dict) -> GroundTruth:
    kind = event["kind"]
    if kind == "degrade":
        return truth.degraded(topo, event.get("factor", 4.0))
    if kind == "recover":
        # drop every per-link override: the fabric is healthy again
        return dataclasses.replace(truth, link_bw=())
    if kind == "asym":
        # one rail DIRECTION slows down (src_server -> everyone else);
        # the return direction stays healthy — the per-role fit case
        factor = float(event.get("factor", 4.0))
        src_server = int(event.get("src_server", 0))
        cur = dict(truth.link_bw)
        links = {}
        for key, ln in topo.links.items():
            if (link_class(topo, *key) == "inter"
                    and topo.server_of(key[0]) == src_server):
                links[key] = cur.get(key, ln.bw) / factor
        return truth.with_links(links)
    raise ValueError(f"unknown stress event kind {event['kind']!r}")


def true_inter_bw(truth: GroundTruth, topo) -> float:
    """Mean bandwidth the truth's inter-server links actually deliver."""
    cur = dict(truth.link_bw)
    bws = [cur.get(key, ln.bw) for key, ln in topo.links.items()
           if link_class(topo, *key) == "inter"]
    return sum(bws) / len(bws) if bws else 0.0


def build_schedule(epochs: int, smoke: bool) -> list[dict]:
    """Scripted degradation schedule over ``epochs`` probe cycles."""
    if smoke:
        return [{"epoch": 1, "kind": "degrade", "factor": 4.0},
                {"epoch": max(3, epochs - 2), "kind": "recover"}]
    marks = [(0.12, {"kind": "degrade", "factor": 4.0}),
             (0.33, {"kind": "recover"}),
             (0.55, {"kind": "asym", "factor": 4.0, "src_server": 0}),
             (0.78, {"kind": "recover"})]
    return [{"epoch": max(1, int(frac * epochs)), **ev}
            for frac, ev in marks]


# ---------------------------------------------------------------------------
# the soak loop
# ---------------------------------------------------------------------------

def _metric(parsed: dict, name: str, **labels) -> float:
    """One scraped sample, 0.0 when the series has no samples yet."""
    want = tuple(sorted((k, str(v)) for k, v in labels.items()))
    for (n, lbls), v in parsed.items():
        if n == name and tuple(l for l in lbls
                               if l[0] in labels) == want:
            return v
    return 0.0


def run_soak(*, fabric: str = "2x8", epochs: int = 48,
             epoch_minutes: float = 10.0, noise: float = 0.01,
             seed: int = 0, detect_within: int = 2,
             smoke: bool = False, out_path: str | None = None,
             port: int = 0) -> dict:
    reset_default_registry()
    topo = get_fabric(fabric)
    planner = Planner()
    store = CalibrationStore(":memory:")
    monitor = DriftMonitor(planner, store, topo)
    truth = GroundTruth(noise=noise, seed=seed)
    schedule = build_schedule(epochs, smoke)
    by_epoch = {ev["epoch"]: ev for ev in schedule}

    # the bound program: a prefill/decode serving shape — prefill sits at
    # the Fig 8 flip cell (scheme changes under a rail slowdown), decode
    # stays small-payload unicast
    from repro.core import plan as plan_ir
    program = plan_ir.CollectiveProgram(
        name="stress_serve",
        sites=(*plan_ir.moe_sites("prefill", num_experts=64, top_k=8,
                                  tokens_per_rank=FLIP_BATCH,
                                  token_bytes=TOKEN_BYTES),
               *plan_ir.moe_sites("decode", num_experts=64, top_k=8,
                                  tokens_per_rank=4,
                                  token_bytes=TOKEN_BYTES)))
    eplan = planner.plan_program(program, topo)
    flip_payload = float(FLIP_BATCH) * TOKEN_BYTES
    slo_bucket = bucket_payload(float(SLO_BATCH) * TOKEN_BYTES)

    exporter = MetricsExporter(port).start()
    stale_warned = [False]
    stale_warnings: list[int] = []

    def check_stale(epoch: int) -> bool:
        """The launcher-style one-shot stale check (run twice per epoch
        to PROVE the warning cannot double-fire)."""
        stale = planner.plan_is_stale(eplan)
        if stale and not stale_warned[0]:
            stale_warned[0] = True
            stale_warnings.append(epoch)
            from repro.telemetry import default_registry
            default_registry()["repro_plan_stale_total"].inc(
                program=program.name, fingerprint=eplan.fingerprint)
            print(f"epoch {epoch}: WARNING bound plan "
                  f"{eplan.fingerprint} is stale (replan chose "
                  f"different decisions)")
        return bool(stale)

    timeline: list[dict] = []
    recal_epochs: list[int] = []
    changed_recals: list[int] = []
    prev_scrape: dict = {}
    prev_plan: str | None = None
    t_wall = time.monotonic()
    try:
        for epoch in range(epochs):
            event = by_epoch.get(epoch)
            if event is not None:
                truth = apply_event(truth, topo, event)
                print(f"epoch {epoch}: injected {event['kind']} "
                      f"(true inter bw now "
                      f"{true_inter_bw(truth, topo) / 1e9:.2f} GB/s)")
            # fresh probe rng per epoch: run-to-run jitter, not one
            # frozen noise draw replayed forever
            probe = SimProbe(dataclasses.replace(truth,
                                                 seed=seed + 1000 + epoch))
            recal = monitor.run_cycle(probe)
            if recal is not None:
                recal_epochs.append(epoch)
                if any(p["changed"] for p in recal.get("programs", [])):
                    changed_recals.append(epoch)
            # one-shot stale surface + hot re-bind (checked twice: the
            # second call must never warn again)
            was_stale = check_stale(epoch)
            check_stale(epoch)
            if was_stale:
                eplan = monitor.replanned(program.name) or \
                    planner.plan_program(program, topo)
                stale_warned[0] = False
            # post-cycle planner verdict vs a fresh oracle on the truth
            decision = planner.choose("dispatch", flip_payload, topo)
            oracle = Planner(hw=truth.true_hw()).choose(
                "dispatch", flip_payload, topo)
            # the operator's view: scrape our own exporter over HTTP
            parsed = parse_text(scrape(exporter.url))
            slo_deltas = {
                cls: (_metric(parsed, "repro_slo_class_total",
                              op="dispatch", payload_bucket=slo_bucket,
                              slo=cls)
                      - _metric(prev_scrape, "repro_slo_class_total",
                                op="dispatch", payload_bucket=slo_bucket,
                                slo=cls))
                for cls in ("good", "acceptable", "poor")}
            # epoch class = WORST class observed this epoch (SLOs report
            # the tail, not the mode — one poor probe among good ones
            # makes the cell poor)
            slo_class = next((cls for cls in ("poor", "acceptable", "good")
                              if slo_deltas.get(cls, 0) > 0), None)
            row = {
                "epoch": epoch,
                "sim_time_h": round(epoch * epoch_minutes / 60.0, 3),
                "event": event,
                "true_inter_gbps": true_inter_bw(truth, topo) / 1e9,
                "drift_pct": round(100 * monitor.drift(), 2),
                "recalibrated": recal is not None,
                "fits": recal["fits"] if recal else None,
                "planner_plan": decision.plan,
                "oracle_plan": oracle.plan,
                "flipped": (prev_plan is not None
                            and decision.plan != prev_plan),
                "bound_fingerprint": eplan.fingerprint,
                "stale_warned": was_stale,
                "slo_class": slo_class,
                "scrape": {
                    "drift_ratio": _metric(parsed, "repro_drift_ratio",
                                           op="dispatch"),
                    "recalibrations": _metric(
                        parsed, "repro_recalibrations_total"),
                    "decision_flips": sum(
                        v for (n, lbls), v in parsed.items()
                        if n == "repro_planner_decision_flips_total"),
                    "slo_deltas": slo_deltas,
                },
            }
            timeline.append(row)
            prev_scrape = parsed
            prev_plan = decision.plan
    finally:
        exporter.stop()

    # -- the five end-to-end assertions -------------------------------------
    failures: list[str] = []

    def check(name: str, ok: bool, detail: str) -> dict:
        if not ok:
            failures.append(f"{name}: {detail}")
        return {"name": name, "ok": bool(ok), "detail": detail}

    # 1. detection latency: every event trips a recal within the window
    latencies = {}
    for ev in schedule:
        hit = next((r for r in recal_epochs
                    if ev["epoch"] <= r <= ev["epoch"] + detect_within),
                   None)
        latencies[ev["epoch"]] = (None if hit is None
                                  else hit - ev["epoch"])
    a_detect = check(
        "detection",
        all(v is not None for v in latencies.values()),
        f"recal latency per event epoch: {latencies} "
        f"(window {detect_within})")

    # 2. convergence: after a class-uniform event, the trusted inter fit
    #    lands within 20% of the injected truth
    conv = []
    for ev in schedule:
        if ev["kind"] not in ("degrade", "recover"):
            continue
        rows = [r for r in timeline
                if r["recalibrated"] and r["fits"]
                and ev["epoch"] <= r["epoch"] <= ev["epoch"]
                + detect_within]
        if not rows:
            conv.append((ev["epoch"], None, None, False))
            continue
        fit = rows[-1]["fits"].get("inter", {})
        fitted = fit.get("bw_gbps", 0.0) * 1e9
        true_bw = (rows[-1]["true_inter_gbps"]) * 1e9
        ok = (fit.get("trusted", False) and true_bw > 0
              and abs(fitted - true_bw) / true_bw <= 0.20)
        conv.append((ev["epoch"], round(fitted / 1e9, 2),
                     round(true_bw / 1e9, 2), ok))
    a_conv = check(
        "convergence", all(c[-1] for c in conv),
        f"(event_epoch, fitted_gbps, true_gbps, ok): {conv}")

    # 3. decision flips match the oracle: outside detection grace
    #    windows the fitted planner and the truth oracle must agree,
    #    and at least one genuine scheme flip must have happened
    grace = {e for ev in schedule
             for e in range(ev["epoch"],
                            ev["epoch"] + detect_within + 1)}
    mismatches = [r["epoch"] for r in timeline
                  if r["epoch"] not in grace
                  and r["planner_plan"] != r["oracle_plan"]]
    n_flips = sum(1 for r in timeline if r["flipped"])
    a_flips = check(
        "flips", not mismatches and n_flips >= 1,
        f"planner-vs-oracle mismatches at epochs {mismatches}; "
        f"{n_flips} genuine flip(s) observed")

    # 4. stale warnings: exactly once per changed-program recalibration
    a_stale = check(
        "stale", stale_warnings == changed_recals,
        f"stale warnings at {stale_warnings}, changed-program recals "
        f"at {changed_recals}")

    # 5. SLO transition good -> poor -> good around the first degrade
    deg = next(ev["epoch"] for ev in schedule if ev["kind"] == "degrade")
    classes = [r["slo_class"] for r in timeline]
    pre = [c for c in classes[:deg] if c]
    post = [c for c in classes[deg + 1:] if c]
    a_slo = check(
        "slo",
        bool(pre) and pre[-1] == "good"
        and classes[deg] == "poor"
        and "good" in post,
        f"classes around degrade@{deg}: pre={pre[-2:]} "
        f"at={classes[deg]} post={post[:3]}")

    result = {
        "config": {"fabric": fabric, "epochs": epochs,
                   "epoch_minutes": epoch_minutes,
                   "sim_hours": round(epochs * epoch_minutes / 60.0, 2),
                   "noise": noise, "seed": seed, "smoke": smoke,
                   "detect_within": detect_within,
                   "flip_batch": FLIP_BATCH, "slo_batch": SLO_BATCH},
        "ts": time.time(),
        "wall_s": round(time.monotonic() - t_wall, 2),
        "schedule": schedule,
        "assertions": [a_detect, a_conv, a_flips, a_stale, a_slo],
        "ok": not failures,
        "timeline": timeline,
    }
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "..", "..",
                                "..", "results", "STRESS_soak.json")
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    for a in result["assertions"]:
        print(f"[{'ok' if a['ok'] else 'FAIL'}] {a['name']}: {a['detail']}")
    print(f"soak: {epochs} epoch(s) over "
          f"{result['config']['sim_hours']}h simulated, "
          f"{len(recal_epochs)} recalibration(s), "
          f"{len(stale_warnings)} stale warning(s) -> {out_path}")
    if failures:
        for fmsg in failures:
            print(f"STRESS FAILURE: {fmsg}", file=sys.stderr)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fabric", default="2x8")
    ap.add_argument("--hours", type=float, default=8.0,
                    help="simulated soak duration")
    ap.add_argument("--epoch-minutes", type=float, default=10.0,
                    help="simulated probe cadence (one telemetry cycle "
                         "per epoch)")
    ap.add_argument("--noise", type=float, default=0.01,
                    help="lognormal measurement jitter sigma")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--detect-within", type=int, default=2,
                    help="max epochs between an injected event and its "
                         "recalibration")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: 6-epoch soak with one degradation + "
                         "recovery")
    ap.add_argument("--out", default=None,
                    help="result JSON path (default "
                         "results/STRESS_soak.json)")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="exporter port the soak scrapes (0 = ephemeral)")
    args = ap.parse_args(argv)
    epochs = (6 if args.smoke
              else max(4, int(args.hours * 60 / args.epoch_minutes)))
    result = run_soak(fabric=args.fabric, epochs=epochs,
                      epoch_minutes=args.epoch_minutes, noise=args.noise,
                      seed=args.seed, detect_within=args.detect_within,
                      smoke=args.smoke, out_path=args.out,
                      port=args.metrics_port)
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
