"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state):

  single-pod:  (16, 16)      axes ("data", "model")        = 256 chips
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

Axis roles: see :mod:`repro.parallel.context`.  The dry-run launcher
forces 512 host devices via XLA_FLAGS before any jax import; everything
else (tests, benches) sees the real device count.
"""

from __future__ import annotations

from repro.parallel.compat import make_mesh
from repro.parallel.context import ParallelContext


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_pctx(*, multi_pod: bool = False, **kw) -> ParallelContext:
    mesh = make_production_mesh(multi_pod=multi_pod)
    return ParallelContext(mesh=mesh,
                           pod_axis="pod" if multi_pod else None, **kw)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for multi-device CPU tests (device count must match)."""
    return make_mesh(shape, axes)
