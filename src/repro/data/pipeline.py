"""Deterministic synthetic data pipeline with per-host sharding.

Production shape: an infinite iterator of global batches, deterministic in
(seed, step) so a restarted job regenerates the exact token stream — the
property the fault-tolerant trainer's data-skip replay relies on (restore
at step k => skip k batches bit-exactly, on any host count).

The token stream is a mixture of Zipf-distributed unigrams and repeated
n-gram motifs, giving a learnable (compressible) distribution so examples
show loss curves that actually go down, unlike uniform noise.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    motif_count: int = 64
    motif_prob: float = 0.5


class SyntheticLM:
    """Deterministic (seed, step) -> batch generator."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank (part of the "dataset")
        self.motifs = rng.integers(
            0, cfg.vocab, size=(cfg.motif_count, cfg.motif_len)
        ).astype(np.int32)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.probs = p / p.sum()

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1):
        """Batch shard for ``host_id`` at ``step``.  Concatenating all host
        shards reproduces the global batch regardless of host count."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        per_host = cfg.global_batch // num_hosts
        rows = []
        for r in range(host_id * per_host, (host_id + 1) * per_host):
            rng = np.random.default_rng(
                (cfg.seed, step, r))           # row-deterministic
            toks = rng.choice(cfg.vocab, size=cfg.seq_len + 1,
                              p=self.probs).astype(np.int32)
            # paste motifs
            n_paste = rng.binomial(cfg.seq_len // cfg.motif_len,
                                   cfg.motif_prob)
            for _ in range(n_paste):
                m = rng.integers(0, cfg.motif_count)
                at = rng.integers(0, cfg.seq_len + 1 - cfg.motif_len)
                toks[at:at + cfg.motif_len] = self.motifs[m]
            rows.append(toks)
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1].copy(),
                "labels": arr[:, 1:].copy()}

    def iter_batches(self, start_step: int = 0, host_id: int = 0,
                     num_hosts: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, host_id, num_hosts)
            step += 1


def batch_for_model(cfg: ModelConfig, data: dict, rng_seed: int = 0):
    """Adapt a token batch to the model's input format (stub frontends
    supply embeddings deterministically derived from the tokens)."""
    import jax.numpy as jnp
    toks, labels = data["tokens"], data["labels"]
    b, s = toks.shape
    if cfg.family == "encdec":
        emb = _stub_embed(toks, cfg.d_model)
        return {"src_embeds": jnp.asarray(emb),
                "tgt_tokens": jnp.asarray(toks),
                "labels": jnp.asarray(labels)}
    if cfg.input_mode == "embeddings":
        emb = _stub_embed(toks, cfg.d_model)
        pos = np.broadcast_to(
            np.arange(s, dtype=np.int32)[None, :, None], (b, s, 3)).copy()
        return {"embeds": jnp.asarray(emb), "positions": jnp.asarray(pos),
                "labels": jnp.asarray(labels)}
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}


def _stub_embed(tokens: np.ndarray, d: int) -> np.ndarray:
    """Deterministic cheap 'frontend': hash tokens into embeddings.
    (The real model would run a ViT / speech encoder here — stubbed per
    the assignment.)"""
    b, s = tokens.shape
    base = (tokens[..., None].astype(np.int64) * 2654435761 % 2**31)
    idx = base + np.arange(d, dtype=np.int64)
    vals = ((idx * 1103515245 + 12345) % 65536).astype(np.float32)
    return ((vals / 32768.0) - 1.0) * 0.05
