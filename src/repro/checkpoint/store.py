"""Sharded checkpointing with atomic commit, resharding restore, and GC.

Layout of one checkpoint:

    <dir>/step_000123/
        manifest.json      # step, mesh shape/axes, tree structure, dtypes,
                           # per-leaf logical shapes, data hashes
        shard_00000.npz    # one file per host: that host's addressable
                           # slices of every leaf (or the full leaves on a
                           # single-host run)

Guarantees engineered for the 1000-node regime:

* **Atomic commit** — writes land in ``step_X.tmp-<nonce>`` and a single
  ``rename`` publishes the checkpoint; readers never observe a partial
  checkpoint, and a crashed writer leaves only a .tmp dir that GC removes.
* **Elastic resharding** — leaves are stored with their LOGICAL (global)
  shapes; restore takes (params_shapes, shardings) for ANY mesh and
  reassembles/redistributes, so a 512-chip checkpoint restores onto 256 or
  1024 chips (elastic scaling after node loss).
* **Integrity** — per-leaf crc32 in the manifest; restore verifies.
* **keep_last_k GC** + best-effort async writes (threaded) for
  checkpoint/compute overlap.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import json
import os
import shutil
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last_k: int = 3
    async_write: bool = False

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if self.async_write else None)
        self._pending: Optional[concurrent.futures.Future] = None

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        """Save a pytree (params/opt state/data step...).  Returns path."""
        host_leaves = {}
        manifest = {"step": int(step), "leaves": {}, "extra": extra or {},
                    "time": time.time(), "format": 1}
        for key, leaf in _flatten_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            manifest["leaves"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
            }
            host_leaves[key] = arr

        def _write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + f".tmp-{os.getpid()}-{int(time.time()*1e6)}"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_00000.npz"), **host_leaves)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic publish
            self._gc()
            return final

        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(_write)
            return os.path.join(self.directory, f"step_{step:08d}")
        return _write()

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- read ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        if not os.path.isdir(self.directory):
            return []
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp" not in name:
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, template: Any,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``template`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings`` (same structure, optional) puts
        each leaf on devices — pass specs for the CURRENT mesh to reshard
        elastically.  Returns (tree, extra)."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_00000.npz"))
        flat_t = _flatten_with_paths(template)
        flat_s = (_flatten_with_paths(shardings) if shardings is not None
                  else [(k, None) for k, _ in flat_t])
        leaves = []
        for (key, tmpl), (_, shard) in zip(flat_t, flat_s):
            info = manifest["leaves"].get(key)
            if info is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            if zlib.crc32(arr.tobytes()) & 0xFFFFFFFF != info["crc"]:
                raise IOError(f"checkpoint corruption in leaf {key}")
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(
                    f"leaf {key}: stored {arr.shape} vs template "
                    f"{tmpl.shape} (resharding changes layout, not shape)")
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        treedef = jax.tree_util.tree_structure(template)
        return treedef.unflatten(leaves), manifest.get("extra", {})

    # -- GC --------------------------------------------------------------------
    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last_k] if self.keep_last_k else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
        # remove orphaned tmp dirs (crashed writers)
        for name in os.listdir(self.directory):
            if ".tmp-" in name:
                full = os.path.join(self.directory, name)
                if time.time() - os.path.getmtime(full) > 3600:
                    shutil.rmtree(full, ignore_errors=True)
