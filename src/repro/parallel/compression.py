"""int8 error-feedback compressed gradient all-reduce (beyond-paper).

The paper removes redundant bytes from *many-to-many* collectives; the
same bottleneck-link first principle (§3.3) applies to the DP gradient
all-reduce when it crosses the pod (DCN) axis.  This module implements the
classic bandwidth lever for that path:

  ring-equivalent all-reduce at 1/4 wire bytes via int8 quantization with
  per-chunk scales + error feedback (the quantization residual is carried
  to the next step, preserving convergence — 1-bit-Adam lineage).

Schedule (inside shard_map over the DP axis, R ranks):
  1. chunk the flat gradient into R pieces;
  2. quantize (int8, per-chunk fp32 scale) and ``all_to_all`` so rank r
     collects every rank's chunk r          — wire: N bytes int8;
  3. local dequant + sum -> reduced chunk r;
  4. re-quantize and ``all_gather``         — wire: N bytes int8;
  5. dequant -> full reduced gradient; residual = input - dequant(sent).

fp32 ring all-reduce moves ~2N*4 bytes; this moves ~2N bytes -> 4x less
on the bottleneck link, directly shrinking the collective roofline term.

Also here: :func:`hierarchical_psum` — reduce-scatter intra-pod, exchange
one pre-reduced shard per pod over DCN, all-gather intra-pod.  This is the
MultiWrite dual (relay-side reduction) applied to gradients: ONE copy of
each reduced byte crosses the slow axis.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .compat import axis_size


def _quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quant.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g: jax.Array, axis: str,
                    err: Optional[jax.Array] = None):
    """Mean-reduce ``g`` over ``axis`` with int8 wire format + error
    feedback.  g: flat [N] fp32 (caller flattens).  Returns (mean, new_err).

    Must run inside shard_map with ``axis`` present.
    """
    r = axis_size(axis)
    n = g.shape[0]
    pad = (-n) % r
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    gp = jnp.pad(gf, (0, pad))
    chunks = gp.reshape(r, -1)                                # [R, N/R]

    # step 2: per-chunk scales ride along as fp32 (R values — negligible)
    scales = jnp.max(jnp.abs(chunks), axis=1) / 127.0
    scales = jnp.maximum(scales, 1e-12)
    q = jnp.clip(jnp.round(chunks / scales[:, None]), -127, 127
                 ).astype(jnp.int8)
    sent_dequant = q.astype(jnp.float32) * scales[:, None]    # what we sent
    new_err = (gp - sent_dequant.reshape(-1))[:n]             # residual

    mine_q = lax.all_to_all(q, axis, split_axis=0, concat_axis=0,
                            tiled=True).reshape(r, -1)        # [R, N/R]
    mine_s = lax.all_to_all(jnp.tile(scales, r), axis, split_axis=0,
                            concat_axis=0, tiled=True).reshape(r, r)
    me = lax.axis_index(axis)
    my_scales = mine_s[:, me]                                  # scale of my chunk per src... see note
    # NOTE: after tiled a2a of the [R] scale vector replicated R times,
    # row p holds rank p's scales; column me is rank p's scale for chunk me.
    reduced = jnp.sum(mine_q.astype(jnp.float32)
                      * my_scales[:, None], axis=0) / r       # mean

    # step 4: requantize the reduced chunk and all-gather
    q2, s2 = _quantize_int8(reduced)
    full_q = lax.all_gather(q2, axis)                          # [R, N/R] int8
    full_s = lax.all_gather(s2, axis)                          # [R]
    out = (full_q.astype(jnp.float32) * full_s[:, None]).reshape(-1)[:n]
    return out, new_err


def hierarchical_psum(g: jax.Array, pod_axis: str, data_axis: str):
    """Pod-aware gradient mean: reduce-scatter over the fast intra-pod axis,
    ONE pre-reduced shard per pod crosses DCN, all-gather intra-pod.

    DCN bytes per chip: N/D (vs N for a flat all-reduce ring crossing pods
    D times per chip-position) — the §3.3 bottleneck-link principle applied
    to the reduction direction.

    Requires a mesh that already factors the replicas into two named
    axes.  When the replicas live on ONE flat mesh axis (the trainer's
    ``data`` axis), use :func:`hierarchical_psum_flat`, which derives
    the same two-level schedule from the fabric's server grouping via
    ``axis_index_groups``.
    """
    d = axis_size(data_axis)
    n = g.shape[0]
    pad = (-n) % d
    gp = jnp.pad(g.astype(jnp.float32), (0, pad))
    # reduce-scatter intra-pod: rank i keeps reduced chunk i
    mine = lax.psum_scatter(gp.reshape(d, -1), data_axis, scatter_dimension=0,
                            tiled=False)                       # [N/D]
    # cross-pod exchange of the pre-reduced shard (the slow-axis hop)
    mine = lax.psum(mine, pod_axis)
    # all-gather intra-pod
    full = lax.all_gather(mine, data_axis).reshape(-1)[:n]
    return full / (d * axis_size(pod_axis))


def hierarchical_psum_flat(g: jax.Array, axis: str, num_servers: int):
    """:func:`hierarchical_psum` on a single flat mesh axis, with the
    two-level factorization derived from the FABRIC rather than
    hard-coded into the mesh shape: ranks on the axis are grouped
    ``num_servers`` x ``npus_per_server`` in fabric order (server-major,
    matching ``ClusterSpec.build``'s node numbering), so the schedule is
    correct on ``4x8`` / ``tpu_2x16``-class shapes, not just 2-server
    meshes.

    Reduce-scatter within each server group (fast links), exchange the
    pre-reduced 1/P shard across same-index rail peers, all-gather back
    within the server group.  Returns the MEAN over the axis.
    """
    r = axis_size(axis)
    s = max(1, int(num_servers))
    if r % s:
        raise ValueError(
            f"axis size {r} does not factor into {s} servers")
    p = r // s
    n = g.shape[0]
    gf = g.astype(jnp.float32)
    if p == 1 or s == 1:
        # degenerate grouping: one level is trivial — a flat psum IS the
        # hierarchical schedule then
        return lax.psum(gf, axis) / r
    intra = [list(range(sv * p, (sv + 1) * p)) for sv in range(s)]
    inter = [[sv * p + i for sv in range(s)] for i in range(p)]
    pad = (-n) % p
    gp = jnp.pad(gf, (0, pad))
    mine = lax.psum_scatter(gp.reshape(p, -1), axis, scatter_dimension=0,
                            tiled=False, axis_index_groups=intra)
    mine = lax.psum(mine, axis, axis_index_groups=inter)
    full = lax.all_gather(mine, axis,
                          axis_index_groups=intra).reshape(-1)[:n]
    return full / r


def tree_compressed_psum(grads, axis: str, err_tree=None):
    """Apply compressed_psum across a pytree (flatten → one fused call)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    err = err_tree if err_tree is not None else jnp.zeros_like(flat)
    red, new_err = compressed_psum(flat, axis, err)
    out = []
    off = 0
    for x, sz in zip(leaves, sizes):
        out.append(red[off:off + sz].reshape(x.shape))
        off += sz
    return treedef.unflatten(out), new_err
