"""JAX version compatibility shims.

The repo targets the current JAX API (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``); the pinned container ships an older release where
``shard_map`` still lives in ``jax.experimental`` (with ``check_rep``
instead of ``check_vma``) and ``make_mesh`` takes no ``axis_types``.
Everything that builds meshes or shard_map programs goes through this
module so the rest of the codebase is version-agnostic.
"""

from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new JAX; ``jax.experimental.shard_map`` on old.

    ``check_vma`` maps onto the old API's ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis from inside shard_map.

    ``jax.lax.axis_size`` on new JAX; the ``psum(1, axis)`` constant-fold
    idiom (returns a python int) on old JAX.
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
        kinds = AxisType.Explicit if explicit else AxisType.Auto
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(kinds,) * len(axis_names))
    except (ImportError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
