"""PartitionSpec assignment for params, batches, and caches.

Sharding layout (Megatron TP over ``model``, FSDP/ZeRO over ``data``,
DP over ``pod``×``data``):

  attention  wq/wk/wv [D, H*dh]  -> P(fsdp, model)      (column parallel)
             wo       [H*dh, D]  -> P(model, fsdp)      (row parallel)
  mlp        w1/w3    [D, F]     -> P(fsdp, model)
             w2       [F, D]     -> P(model, fsdp)
  embedding  emb      [V, D]     -> P(model, fsdp)      (vocab parallel)
  unembed    w        [D, V]     -> P(fsdp, model)
  MoE        w*       [E, D, F]  -> P(ep, ..., model)   (EP over pod+data
                                    when experts >= ranks, else data)
  mamba2     in_proj  [D, Pout]  -> P(fsdp, model); out_proj row-parallel
  rwkv6      time/channel mats   -> col/row parallel as above
  norms / scalars / small tables -> replicated

Stacked (scanned) layers get a leading ``None``; rules are rank-relative.
Optimizer moments inherit param specs elementwise (ZeRO comes free).
Caches: batch dim over DP when divisible; KV length over ``model`` for
decode (flash-decoding style sharded-KV attention) else heads.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.parallel.context import ParallelContext


def _rule_for(path_keys: list[str], cfg: ModelConfig,
              pctx: ParallelContext) -> Optional[tuple]:
    """Base (unstacked) spec template for a leaf, by name/context."""
    name = path_keys[-1]
    in_moe = "moe" in path_keys
    fsdp = pctx.data_axis if pctx.fsdp else None
    model = pctx.model_axis
    if in_moe and name in ("w1", "w3", "w2", "router"):
        use_pod, _ = pctx.ep_ranks(cfg.num_experts)
        ep = ((pctx.pod_axis, pctx.data_axis) if (use_pod and pctx.pod_axis)
              else (pctx.data_axis,))
        if name == "router":
            return (None, None)
        if name == "w2":
            return (ep, model, None)
        return (ep, None, model)                     # w1 / w3
    col = {"wq", "wk", "wv", "w1", "w3", "ck", "cr", "wr", "wg",
           "in_proj", "wA"}
    row = {"wo", "w2", "cv", "out_proj"}
    if name in col:
        return (fsdp, model)
    if name in row:
        return (model, fsdp)
    if name == "emb":
        return (model, fsdp)
    if name == "w" and "unembed" in path_keys:
        return (fsdp, model)
    if name == "wB":
        return (None, model)
    if name == "conv":
        return (None, model)
    if name in ("mu", "cmu", "u"):
        return (None, None)
    if name in ("A_log", "D", "dt_bias", "w0", "w"):
        return (None,)                                # norms & head scalars
    return None                                       # replicate


def param_specs(params: Any, cfg: ModelConfig,
                pctx: ParallelContext) -> Any:
    """PartitionSpec pytree matching ``params`` (shapes may be
    ShapeDtypeStructs — only ndim is used)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        keys = [_k(p) for p in path]
        base = _rule_for(keys, cfg, pctx)
        nd = len(leaf.shape)
        if base is None:
            specs.append(P())
            continue
        spec = list(base)
        while len(spec) < nd:                 # stacked scan dims lead
            spec.insert(0, None)
        spec = spec[:nd] if len(spec) > nd else spec
        # divisibility guard: drop axes that don't divide the dim
        spec = _guard(spec, leaf.shape, pctx)
        specs.append(P(*spec))
    return treedef.unflatten(specs)


def _k(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _axis_size(pctx: ParallelContext, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= _axis_size(pctx, a)
        return out
    return pctx.mesh.shape[axis]


def _guard(spec: list, shape: tuple, pctx: ParallelContext) -> list:
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        elif dim % _axis_size(pctx, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return out


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def batch_specs(batch: Any, pctx: ParallelContext) -> Any:
    """Shard the batch dim over DP axes (when divisible)."""
    def per_leaf(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        dp = pctx.dp_axes if (leaf.ndim and
                              b % _axis_size(pctx, pctx.dp_axes) == 0) \
            else None
        return P(dp, *([None] * (leaf.ndim - 1))) if leaf.ndim else P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return treedef.unflatten([per_leaf(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# caches / decode state
# ---------------------------------------------------------------------------

def cache_specs(cache: Any, cfg: ModelConfig, pctx: ParallelContext) -> Any:
    """Per-leaf rules keyed by the cache field names used across families.
    KV caches are per-layer tuples: the name is the last STRING key in the
    path (tuple indices are skipped)."""
    model = pctx.model_axis
    msize = _axis_size(pctx, model)
    dpsize = _axis_size(pctx, pctx.dp_axes)

    def per_leaf(path, leaf):
        names = [_k(p) for p in path if hasattr(p, "key")]
        name = names[-1] if names else _k(path[-1])
        nd = leaf.ndim
        if nd == 0:
            return P()
        shape = leaf.shape
        if name in ("k", "v") and nd == 4:   # [B, S, g, dh] (tuple entry)
            b_ok = shape[0] % dpsize == 0
            s_ok = pctx.seq_shard_decode and shape[1] % msize == 0
            g_ok = shape[2] % msize == 0
            return P(pctx.dp_axes if b_ok else None,
                     model if s_ok else None,
                     model if (g_ok and not s_ok) else None, None)
        if name in ("k", "v"):            # [L, B, S, g, dh]
            b_ok = shape[1] % dpsize == 0
            s_ok = pctx.seq_shard_decode and shape[2] % msize == 0
            g_ok = shape[3] % msize == 0
            return P(None, pctx.dp_axes if b_ok else None,
                     model if s_ok else None,
                     model if (g_ok and not s_ok) else None, None)
        if name == "enc_out":             # [B, S, D]
            b_ok = shape[0] % dpsize == 0
            return P(pctx.dp_axes if b_ok else None, None,
                     model if shape[2] % msize == 0 else None)
        if name == "conv":                # [L, B, K-1, d_inner]
            b_ok = shape[1] % dpsize == 0
            return P(None, pctx.dp_axes if b_ok else None, None,
                     model if shape[3] % msize == 0 else None)
        if name == "ssd":                 # [L, B, H, ds, dh]
            b_ok = shape[1] % dpsize == 0
            return P(None, pctx.dp_axes if b_ok else None,
                     model if shape[2] % msize == 0 else None, None, None)
        if name == "wkv":                 # [L, B, H, dk, dv]
            b_ok = shape[1] % dpsize == 0
            return P(None, pctx.dp_axes if b_ok else None,
                     model if shape[2] % msize == 0 else None, None, None)
        if name in ("tshift", "cshift"):  # [L, B, D]
            b_ok = shape[1] % dpsize == 0
            return P(None, pctx.dp_axes if b_ok else None,
                     model if shape[2] % msize == 0 else None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return treedef.unflatten([per_leaf(p, l) for p, l in flat])


def named(tree_specs: Any, pctx: ParallelContext) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(pctx.mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def with_sharding(shapes: Any, specs: Any, pctx: ParallelContext) -> Any:
    """ShapeDtypeStructs with NamedShardings attached (dry-run inputs)."""
    return jax.tree_util.tree_map(
        lambda sh, sp: jax.ShapeDtypeStruct(
            sh.shape, sh.dtype, sharding=NamedSharding(pctx.mesh, sp)),
        shapes, specs)
