"""Parallelism context: which mesh axes exist and how layers shard.

A :class:`ParallelContext` travels with a model instance.  ``pctx=None``
means single-device (smoke tests); all sharding helpers become no-ops and
the MoE path degenerates to local dispatch.

Axis roles on the production mesh (launch/mesh.py):

  pod    slow inter-pod axis (DCN) — DP, and the outer level of the
         MultiWrite hierarchical EP dispatch.
  data   fast intra-pod axis — DP/FSDP, and EP for MoE layers.
  model  fast intra-pod axis — TP (Megatron col/row), sequence/KV-length
         sharding for decode, optionally subdivided into split-TP domains
         for the §3.1 multiwrite AllGather scenario.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh
    pod_axis: Optional[str] = None    # None on a single-pod mesh
    data_axis: str = "data"
    model_axis: str = "model"
    fsdp: bool = True                 # shard weights over data (ZeRO-3-ish)
    plan_policy: str = "fixed"        # "auto": collective schemes/knobs come
    #   from core.planner.Planner at trace time (the §5.2 dynamic workflow —
    #   scheme choice emerges from payload size + topology + calibration);
    #   "fixed": the explicit knobs below are used verbatim.
    moe_scheme: str = "hierarchical"  # hierarchical (MultiWrite) | baseline
    #                                   (plan_policy="fixed" only)
    moe_combine: Optional[str] = None  # return-path scheme under "fixed":
    #   "hierarchical" (relay-reduced) | "baseline" (unicast return) |
    #   None = follow moe_scheme.  Under "auto" the combine planner op
    #   decides, independently of dispatch.
    fabric: Optional[object] = None   # explicit core.topology.Topology the
    #   planner scores against (--fabric CLI); None = derived from the mesh
    #   shape (pod == server).  Only changes WHICH plan wins — execution
    #   stays on the actual mesh.
    calibration: Optional[object] = None  # telemetry CalibrationStore (or
    #   path) of measured collective timings: planner decisions are scored
    #   under the store's FITTED hardware model for the active fabric
    #   instead of datasheet constants (--calibrate CLI surface).
    moe_skew: float = 0.0             # hot-expert routing skew the planner
    #   prices dispatch/combine under (0 = balanced routing, paper §6.1)
    tp_subgroups: int = 1             # §3.1 split-TP domains on model axis
    remat: str = "full"               # none | selective | full
    seq_shard_decode: bool = True     # shard decode KV length over model
    seq_parallel: bool = True         # Megatron-SP: residual stream's seq
    #                                   dim sharded over model between blocks
    # --- MoE perf levers (§Perf hillclimb; defaults = paper-faithful) -----
    moe_deferred_tp_reduce: bool = False  # move the expert row-parallel
    #   psum ([E_l, Ce, D] per layer) through the LINEAR combine tree to a
    #   single [N, D] psum at the end — ~Ce*E_l/N x fewer model-axis bytes
    moe_microbatch: int = 1           # split dispatch into G chunks,
    #   double-buffered: dispatch of chunk k+1 overlaps expert FFN of
    #   chunk k and combine of chunk k-1 — a latency lever AND a memory
    #   lever (peak dispatch buffers ~2/G of the unchunked size: the
    #   pipeline keeps TWO chunks in flight, vs 1/G for the old serial
    #   chunk loop).  Under plan_policy="auto" the planner's microbatch
    #   knob overrides this — the pipelined scoring mode picks the G
    #   where the overlap win beats the per-chunk alpha.

    # -- derived -------------------------------------------------------------
    @property
    def dp_axes(self):
        return ((self.pod_axis, self.data_axis) if self.pod_axis
                else (self.data_axis,))

    @property
    def num_pods(self) -> int:
        return self.mesh.shape[self.pod_axis] if self.pod_axis else 1

    @property
    def data_size(self) -> int:
        return self.mesh.shape[self.data_axis]

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def ep_ranks(self, num_experts: int) -> tuple[bool, int]:
        """(use_pod_axis, total EP ranks) for an MoE layer: EP spans the pod
        axis only when there are enough experts (the paper's large-EP
        regime); otherwise EP = data axis and pod stays pure DP."""
        if self.pod_axis and num_experts >= self.num_pods * self.data_size:
            return True, self.num_pods * self.data_size
        return False, self.data_size

    # -- planner consumption -------------------------------------------------
    def _plan_topo_hw(self, num_experts: int):
        """(topology, hardware model) the EP planner ops score against:
        the explicit ``fabric`` (or the mesh-derived shape), and — when a
        ``calibration`` store is wired — the store's fitted model for
        that fabric instead of the datasheet DEFAULT."""
        from repro.core.planner import _ep_topology
        use_pod, _ = self.ep_ranks(num_experts)
        topo = _ep_topology(self.num_pods if use_pod else 1,
                            self.data_size, self.fabric)
        hw = None
        if self.calibration is not None:
            from repro.telemetry import calibrated_hw, resolve_store
            hw = calibrated_hw(resolve_store(self.calibration), topo)
        return topo, hw

    def moe_dispatch_plan(self, num_experts: int, top_k: int,
                          tokens_per_rank: int, token_bytes: int,
                          compute_s: float = 0.0):
        """Planner decision for an MoE dispatch on this mesh (or on the
        explicit ``fabric``), or ``None`` when ``plan_policy`` is "fixed"
        (the explicit ``moe_scheme`` knob applies).  Called at trace
        time; decisions are LRU-cached on (topology, payload bucket).
        ``compute_s > 0`` (the modeled expert-FFN time) enables the
        pipelined scoring mode — the decision's ``microbatch`` knob can
        then come back > 1."""
        if self.plan_policy != "auto":
            return None
        from repro.core.planner import moe_dispatch_decision
        use_pod, _ = self.ep_ranks(num_experts)
        topo, hw = self._plan_topo_hw(num_experts)
        return moe_dispatch_decision(
            num_pods=self.num_pods if use_pod else 1,
            ep_per_pod=self.data_size,
            num_experts=num_experts, top_k=top_k,
            tokens_per_rank=tokens_per_rank, token_bytes=token_bytes,
            topo=topo, hw=hw, skew=self.moe_skew, compute_s=compute_s)

    def moe_combine_plan(self, num_experts: int, top_k: int,
                         tokens_per_rank: int, token_bytes: int,
                         compute_s: float = 0.0):
        """Planner decision for the MoE *combine* (return path), planned
        independently of dispatch — the return redundancy is spread over
        the holders' rails and may face asymmetric bandwidth.  ``None``
        under "fixed"."""
        if self.plan_policy != "auto":
            return None
        from repro.core.planner import moe_combine_decision
        use_pod, _ = self.ep_ranks(num_experts)
        topo, hw = self._plan_topo_hw(num_experts)
        return moe_combine_decision(
            num_pods=self.num_pods if use_pod else 1,
            ep_per_pod=self.data_size,
            num_experts=num_experts, top_k=top_k,
            tokens_per_rank=tokens_per_rank, token_bytes=token_bytes,
            topo=topo, hw=hw, skew=self.moe_skew, compute_s=compute_s)

    def resolve_moe_dispatch(self, num_experts: int, top_k: int,
                             tokens_per_rank: int, token_bytes: int,
                             compute_s: float = 0.0) -> dict:
        """The dispatch configuration moe_ffn executes:
        ``{"moe_scheme": ..., "microbatch": G}`` — planner-chosen under
        ``plan_policy="auto"`` (scheme AND pipeline chunk count from one
        sweep), the declared ``moe_scheme``/``moe_microbatch`` knobs
        otherwise."""
        decision = self.moe_dispatch_plan(num_experts, top_k,
                                          tokens_per_rank, token_bytes,
                                          compute_s=compute_s)
        if decision is None:
            return {"moe_scheme": self.moe_scheme,
                    "microbatch": max(1, int(self.moe_microbatch))}
        return dict(decision.shard_map_kwargs)

    def resolve_moe_scheme(self, num_experts: int, top_k: int,
                           tokens_per_rank: int, token_bytes: int,
                           compute_s: float = 0.0) -> str:
        """The dispatch scheme moe_ffn executes: planner-chosen under
        ``plan_policy="auto"``, the declared knob otherwise."""
        return self.resolve_moe_dispatch(
            num_experts, top_k, tokens_per_rank, token_bytes,
            compute_s=compute_s)["moe_scheme"]

    def resolve_combine_scheme(self, num_experts: int, top_k: int,
                               tokens_per_rank: int, token_bytes: int,
                               compute_s: float = 0.0,
                               microbatch: Optional[int] = None) -> str:
        """The combine (return-path) scheme moe_ffn executes:
        planner-chosen under ``plan_policy="auto"`` (the "combine" op,
        resolved independently of dispatch), else the declared
        ``moe_combine`` knob, defaulting to following ``moe_scheme``.

        ``microbatch`` constrains the comparison to the pipeline depth
        the layer actually RUNS (moe_ffn chunks the whole pipeline at
        the dispatch decision's G): the scheme is chosen among the
        combine candidates at that G, not at a G the execution never
        honors."""
        decision = self.moe_combine_plan(num_experts, top_k,
                                         tokens_per_rank, token_bytes,
                                         compute_s=compute_s)
        if decision is None:
            if self.moe_combine is not None:
                return self.moe_combine
            return self.moe_scheme
        if microbatch is None:
            return decision.shard_map_kwargs["moe_combine"]
        from repro.core import plan as plan_ir
        g = max(1, int(microbatch))
        at_g = [(t, name) for name, kn, t in decision.candidates
                if dict(kn).get("microbatch", 1) == g]
        if not at_g:                   # G outside the grid: unconstrained
            return decision.shard_map_kwargs["moe_combine"]
        best_name = min(at_g)[1]
        return plan_ir.get_plan("combine", best_name).shard_map_kwargs(
            microbatch=g)["moe_combine"]


def shard(x, pctx: Optional[ParallelContext], *spec):
    """with_sharding_constraint that no-ops without a context."""
    if pctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_residual(x, pctx: Optional[ParallelContext]):
    """Between-block constraint on the residual stream [B, S, D]:
    SP shards S over model (memory / L x smaller scan-bwd carry stack)."""
    if pctx is None:
        return x
    if pctx.seq_parallel and x.shape[1] % pctx.model_size == 0:
        return shard(x, pctx, pctx.dp_axes, pctx.model_axis, None)
    return shard(x, pctx, pctx.dp_axes, None, None)
