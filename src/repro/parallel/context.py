"""Parallelism context: which mesh axes exist and how layers shard.

A :class:`ParallelContext` travels with a model instance.  ``pctx=None``
means single-device (smoke tests); all sharding helpers become no-ops and
the MoE path degenerates to local dispatch.

Axis roles on the production mesh (launch/mesh.py):

  pod    slow inter-pod axis (DCN) — DP, and the outer level of the
         MultiWrite hierarchical EP dispatch.
  data   fast intra-pod axis — DP/FSDP, and EP for MoE layers.
  model  fast intra-pod axis — TP (Megatron col/row), sequence/KV-length
         sharding for decode, optionally subdivided into split-TP domains
         for the §3.1 multiwrite AllGather scenario.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
from jax.sharding import Mesh, PartitionSpec as P


def _deprecated(name: str, repl: str) -> None:
    warnings.warn(
        f"ParallelContext.{name} is deprecated (one release): collective "
        f"sites are planned as a whole program now — use {repl}",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh
    pod_axis: Optional[str] = None    # None on a single-pod mesh
    data_axis: str = "data"
    model_axis: str = "model"
    fsdp: bool = True                 # shard weights over data (ZeRO-3-ish)
    plan_policy: str = "fixed"        # "auto": collective schemes/knobs come
    #   from core.planner.Planner at trace time (the §5.2 dynamic workflow —
    #   scheme choice emerges from payload size + topology + calibration);
    #   "fixed": the explicit knobs below are used verbatim.
    moe_scheme: str = "hierarchical"  # hierarchical (MultiWrite) | baseline
    #                                   (plan_policy="fixed" only)
    moe_combine: Optional[str] = None  # return-path scheme under "fixed":
    #   "hierarchical" (relay-reduced) | "baseline" (unicast return) |
    #   None = follow moe_scheme.  Under "auto" the combine planner op
    #   decides, independently of dispatch.
    fabric: Optional[object] = None   # explicit core.topology.Topology the
    #   planner scores against (--fabric CLI); None = derived from the mesh
    #   shape (pod == server).  Only changes WHICH plan wins — execution
    #   stays on the actual mesh.
    calibration: Optional[object] = None  # telemetry CalibrationStore (or
    #   path) of measured collective timings: planner decisions are scored
    #   under the store's FITTED hardware model for the active fabric
    #   instead of datasheet constants (--calibrate CLI surface).
    moe_skew: float = 0.0             # hot-expert routing skew the planner
    #   prices dispatch/combine under (0 = balanced routing, paper §6.1)
    tp_subgroups: int = 1             # §3.1 split-TP domains on model axis
    remat: str = "full"               # none | selective | full
    seq_shard_decode: bool = True     # shard decode KV length over model
    seq_parallel: bool = True         # Megatron-SP: residual stream's seq
    #                                   dim sharded over model between blocks
    # --- MoE perf levers (§Perf hillclimb; defaults = paper-faithful) -----
    moe_deferred_tp_reduce: bool = False  # move the expert row-parallel
    #   psum ([E_l, Ce, D] per layer) through the LINEAR combine tree to a
    #   single [N, D] psum at the end — ~Ce*E_l/N x fewer model-axis bytes
    moe_microbatch: int = 1           # split dispatch into G chunks,
    #   double-buffered: dispatch of chunk k+1 overlaps expert FFN of
    #   chunk k and combine of chunk k-1 — a latency lever AND a memory
    #   lever (peak dispatch buffers ~2/G of the unchunked size: the
    #   pipeline keeps TWO chunks in flight, vs 1/G for the old serial
    #   chunk loop).  Under plan_policy="auto" the planner's microbatch
    #   knob overrides this — the pipelined scoring mode picks the G
    #   where the overlap win beats the per-chunk alpha.
    execution_plan: Optional[object] = None  # bound
    #   core.plan.ExecutionPlan: the jointly-planned, fingerprinted
    #   verdict for this workload's declared collective program.  Trace-
    #   time consumers (moe_ffn, split-TP gathers) resolve their site by
    #   key lookup against it; sites the program didn't declare fall back
    #   to plan_policy.  Install via ``pctx.bind(plan)``.

    # -- derived -------------------------------------------------------------
    @property
    def dp_axes(self):
        return ((self.pod_axis, self.data_axis) if self.pod_axis
                else (self.data_axis,))

    @property
    def num_pods(self) -> int:
        return self.mesh.shape[self.pod_axis] if self.pod_axis else 1

    @property
    def data_size(self) -> int:
        return self.mesh.shape[self.data_axis]

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    def ep_ranks(self, num_experts: int) -> tuple[bool, int]:
        """(use_pod_axis, total EP ranks) for an MoE layer: EP spans the pod
        axis only when there are enough experts (the paper's large-EP
        regime); otherwise EP = data axis and pod stays pure DP."""
        if self.pod_axis and num_experts >= self.num_pods * self.data_size:
            return True, self.num_pods * self.data_size
        return False, self.data_size

    # -- planner consumption -------------------------------------------------
    def _plan_topo_hw(self, num_experts: int):
        """(topology, hardware model) the EP planner ops score against:
        the explicit ``fabric`` (or the mesh-derived shape), and — when a
        ``calibration`` store is wired — the store's fitted model for
        that fabric instead of the datasheet DEFAULT."""
        from repro.core.planner import _ep_topology
        use_pod, _ = self.ep_ranks(num_experts)
        topo = _ep_topology(self.num_pods if use_pod else 1,
                            self.data_size, self.fabric)
        hw = None
        if self.calibration is not None:
            from repro.telemetry import calibrated_hw, resolve_store
            hw = calibrated_hw(resolve_store(self.calibration), topo)
        return topo, hw

    # -- declarative collective programs (the bindable planning surface) -----
    def bind(self, plan) -> "ParallelContext":
        """Install a jointly-planned :class:`~repro.core.plan.ExecutionPlan`
        (returns the bound context — the dataclass is frozen).  The plan
        must have been planned for THIS context's fabric: binding a plan
        fingerprinted on a different topology is a deployment bug caught
        here rather than silently mis-executed."""
        if (plan is not None and self.fabric is not None
                and plan.topo_fingerprint != ("pinned",)):
            from repro.core.topology import same_fabric_fingerprint
            fp = self.fabric.fingerprint()
            # failure/recalibration variants of the serving fabric are
            # legitimate bind targets (failover re-binds a plan computed
            # on the surviving-capacity graph); FOREIGN fabrics are not
            if not same_fabric_fingerprint(plan.topo_fingerprint, fp):
                raise ValueError(
                    f"ExecutionPlan {plan.fingerprint} was planned on "
                    f"{plan.topo_fingerprint[0]!r}, but this context's "
                    f"fabric is {fp[0]!r} — replan the program for the "
                    f"active fabric before binding")
        if plan is not None:
            # lazy: repro.telemetry transitively imports the planner this
            # module feeds, so the metrics plane resolves at call time
            from repro.telemetry import metrics as _m
            _m.default_registry()["repro_plan_bind_total"].inc(
                program=plan.program.name, fingerprint=plan.fingerprint)
        return dataclasses.replace(self, execution_plan=plan)

    def moe_sites(self, phase: str, *, num_experts: int, top_k: int,
                  tokens_per_rank: int, token_bytes: int,
                  compute_s: float = 0.0) -> tuple:
        """This context's coupled MoE (dispatch, combine) site pair for
        one phase — skew comes from the declared ``moe_skew`` knob, so a
        program built here prices exactly what the trace-time lookup
        will ask for."""
        from repro.core import plan as plan_ir
        return plan_ir.moe_sites(
            phase, num_experts=num_experts, top_k=top_k,
            tokens_per_rank=tokens_per_rank, token_bytes=token_bytes,
            skew=self.moe_skew, compute_s=compute_s)

    def split_tp_gather_site(self, phase: str, *, global_batch: int,
                             seq_len: int, d_model: int, itemsize: int = 2):
        """The §3.1 split-TP AllGather site this context's transformer
        blocks will issue for one phase (the SP -> TP boundary gather of
        ``_split_tp_seq_gather``), or ``None`` when the geometry emits no
        split-TP gather — mirrors the trace-time guards exactly."""
        m, nd = self.model_size, self.tp_subgroups
        dp = self.num_pods * self.data_size
        if (nd != 2 or not self.seq_parallel or m % nd or seq_len % m
                or global_batch % dp):
            return None
        from repro.core import plan as plan_ir
        from repro.core.topology import split_tp_full_mesh
        frag = (global_batch // dp) * (seq_len // m) * d_model * itemsize
        topo, _ = split_tp_full_mesh(m, tp=m // nd)
        return plan_ir.allgather_site(phase, frag_bytes=frag,
                                      num_domains=nd, topo=topo)

    def grad_sync_site(self, phase: str, *, num_params: int,
                       tokens_per_rank: int):
        """The per-step gradient AllReduce site of one training phase,
        or ``None`` when there are no data-parallel replicas to sync.

        Payload: fp32 gradients of the TP-sharded parameters.  Overlap
        context: the modeled backward-pass time — gradient buckets
        become ready back-to-front during backprop, so a chunked sync
        (microbatch > 1) hides earlier chunks' wire time behind later
        layers' backward compute.  Fabric: the full DP span — gradient
        sync always crosses the pod axis (unlike EP, which stays
        intra-pod for small expert counts)."""
        dp = self.num_pods * self.data_size
        if dp <= 1:
            return None
        from repro.core import plan as plan_ir
        from repro.core.latency_model import backward_compute_s
        from repro.core.planner import _ep_topology
        payload = float(num_params) * 4.0 / max(1, self.model_size)
        compute = backward_compute_s(num_params, tokens_per_rank,
                                     tp=self.model_size)
        topo = _ep_topology(self.num_pods, self.data_size, self.fabric)
        return plan_ir.grad_sync_site(phase, payload_bytes=payload,
                                      compute_s=compute, topo=topo)

    def plan_collectives(self, program):
        """Jointly plan a declared program on this context's fabric and
        calibration: the launch-surface entry point
        (``pctx = pctx.bind(pctx.plan_collectives(program))``)."""
        from repro.core.planner import default_planner
        num_experts = max((dict(s.scenario_kw).get("num_experts", 0)
                           for s in program.sites), default=0)
        topo, hw = self._plan_topo_hw(num_experts)
        return default_planner().plan_program(program, topo, hw)

    def bound_plan_stale(self, planner=None) -> Optional[bool]:
        """Whether the bound ExecutionPlan has been superseded by a
        replan of its program under newer calibration (True), is still
        current (False), or cannot be judged (None: nothing bound, a
        pinned plan, or a program the planner never saw).  The minimal
        observable slice of hot re-binding: until plans swap in-place,
        drift at least becomes VISIBLE at every launch surface."""
        if self.execution_plan is None:
            return None
        if planner is None:
            from repro.core.planner import default_planner
            planner = default_planner()
        return planner.plan_is_stale(self.execution_plan)

    # -- trace-time site resolution ------------------------------------------
    def moe_pipeline_kwargs(self, num_experts: int, top_k: int,
                            tokens_per_rank: int, token_bytes: int,
                            compute_s: float = 0.0,
                            microbatch: Optional[int] = None) -> dict:
        """The full MoE round-trip configuration one layer executes:
        ``{"moe_scheme", "moe_combine", "microbatch"}`` — dispatch
        scheme, return-path scheme and the SHARED pipeline chunk count,
        decided together.

        Resolution order: (1) a bound :class:`ExecutionPlan` whose
        declared dispatch site matches this workload (pure lookup, the
        production path); (2) under ``plan_policy="auto"``, an ad-hoc
        single-phase program through ``Planner.plan_program`` (same
        joint sweep, LRU-cached — undeclared workloads still plan
        jointly); (3) the declared fixed knobs.  The executable-pairing
        constraint (a unicast dispatch leaves no relay state, so its
        return path is unicast) holds on every path.

        ``microbatch`` constrains the result to the chunk count the
        layer actually RUNS: when moe_ffn's divisibility clamp moves G
        off the planned value, it re-resolves here and gets the best
        joint candidate AT the executed G (a scheme pair that only won
        at the planned depth is never executed at one the sweep scored
        worse)."""
        payload = float(tokens_per_rank) * token_bytes
        scen = dict(num_experts=num_experts, top_k=top_k,
                    token_bytes=token_bytes)
        decision = None
        if self.execution_plan is not None:
            role = self.execution_plan.find_role(
                "dispatch", payload, skew=self.moe_skew,
                compute_s=compute_s, **scen)
            if role is not None:
                anchor = self.execution_plan.group_of.get(role)
                decision = (self.execution_plan.joint.get(anchor)
                            if anchor is not None else None)
                if decision is None:
                    kw = self.execution_plan.site_kwargs(role)
                    return self._norm_moe_kwargs(
                        self._kwargs_at_g(None, kw, microbatch))
        if decision is None:
            if self.plan_policy != "auto":
                return self._norm_moe_kwargs(self._kwargs_at_g(
                    None, {"moe_scheme": self.moe_scheme,
                           "moe_combine": self.moe_combine,
                           "microbatch": max(1, int(self.moe_microbatch))},
                    microbatch))
            from repro.core import plan as plan_ir
            sites = self.moe_sites(
                "auto", num_experts=num_experts, top_k=top_k,
                tokens_per_rank=tokens_per_rank, token_bytes=token_bytes,
                compute_s=compute_s)
            eplan = self.plan_collectives(
                plan_ir.CollectiveProgram("moe/auto", sites))
            decision = eplan.joint.get(sites[0].role)
            if decision is None:
                return self._norm_moe_kwargs(self._kwargs_at_g(
                    None, eplan.site_kwargs(sites[0].role), microbatch))
        return self._norm_moe_kwargs(self._kwargs_at_g(
            decision, dict(decision.shard_map_kwargs), microbatch))

    @staticmethod
    def _kwargs_at_g(decision, kwargs: dict,
                     microbatch: Optional[int]) -> dict:
        """Constrain a resolved configuration to an executed chunk count:
        the best JOINT candidate at that G when the decision carries a
        candidate sweep, else the same kwargs with G overridden."""
        if microbatch is None or \
                int(microbatch) == int(kwargs.get("microbatch", 1)):
            return kwargs
        g = max(1, int(microbatch))
        for name, kn, _ in sorted(
                getattr(decision, "candidates", None) or (),
                key=lambda c: c[2]):
            if dict(kn).get("microbatch", 1) != g or "+" not in name:
                continue
            from repro.core import plan as plan_ir
            d_name, _, c_name = name.partition("+")
            kw = plan_ir.get_plan("dispatch", d_name).shard_map_kwargs(
                microbatch=g)
            kw.update(plan_ir.get_plan("combine", c_name).shard_map_kwargs(
                microbatch=g))
            return kw
        return {**kwargs, "microbatch": g}

    @staticmethod
    def _norm_moe_kwargs(kw: dict) -> dict:
        """Normalize a resolved MoE configuration: the combine defaults
        to following the dispatch scheme, and the baseline (unicast)
        dispatch forces the unicast return path (no relay state exists
        for a relay-reduced combine)."""
        scheme = kw.get("moe_scheme", "hierarchical")
        combine = kw.get("moe_combine") or scheme
        if scheme == "baseline":
            combine = "baseline"
        return {"moe_scheme": scheme, "moe_combine": combine,
                "microbatch": max(1, int(kw.get("microbatch", 1)))}

    def allgather_plan(self, frag_bytes: float, num_domains: int = 2):
        """Decision for the §3.1 split-TP AllGather at one traced
        fragment size: bound-plan lookup first, then the planner under
        "auto", ``None`` under "fixed" (the call site keeps the
        paper-faithful analytic knobs)."""
        if self.execution_plan is not None:
            role = self.execution_plan.find_role(
                "allgather", frag_bytes, num_domains=num_domains)
            if role is not None:
                return self.execution_plan.decision(role)
        if self.plan_policy != "auto":
            return None
        from repro.core.planner import default_planner
        from repro.core.topology import split_tp_full_mesh
        n = self.model_size
        topo, _ = split_tp_full_mesh(n, tp=max(1, n // num_domains))
        return default_planner().choose(
            "allgather", float(frag_bytes), topo, executable_only=True,
            num_domains=num_domains)

    # -- deprecated per-op resolution shims (one release) ---------------------
    # The resolve_*/moe_*_plan knob zoo planned every site independently;
    # coupled sites are planned jointly through CollectiveProgram /
    # ExecutionPlan now.  These delegate to the program path so legacy
    # callers see the jointly-planned answers.
    def _moe_site_decision(self, op: str, num_experts: int, top_k: int,
                           tokens_per_rank: int, token_bytes: int,
                           compute_s: float = 0.0):
        payload = float(tokens_per_rank) * token_bytes
        scen = dict(num_experts=num_experts, top_k=top_k,
                    token_bytes=token_bytes)
        if self.execution_plan is not None:
            role = self.execution_plan.find_role(
                op, payload, skew=self.moe_skew, compute_s=compute_s,
                **scen)
            if role is not None:
                return self.execution_plan.decision(role)
        if self.plan_policy != "auto":
            return None
        from repro.core import plan as plan_ir
        sites = self.moe_sites("auto", num_experts=num_experts,
                               top_k=top_k, tokens_per_rank=tokens_per_rank,
                               token_bytes=token_bytes, compute_s=compute_s)
        eplan = self.plan_collectives(
            plan_ir.CollectiveProgram("moe/auto", sites))
        role = sites[0].role if op == "dispatch" else sites[1].role
        return eplan.decision(role)

    def moe_dispatch_plan(self, num_experts: int, top_k: int,
                          tokens_per_rank: int, token_bytes: int,
                          compute_s: float = 0.0):
        """DEPRECATED shim: the per-site dispatch view of the jointly
        planned MoE pipeline (``None`` under "fixed" with no bound
        plan).  Use ``plan_collectives`` + ``ExecutionPlan.decision``."""
        _deprecated("moe_dispatch_plan",
                    "plan_collectives(program).decision(role)")
        return self._moe_site_decision("dispatch", num_experts, top_k,
                                       tokens_per_rank, token_bytes,
                                       compute_s)

    def moe_combine_plan(self, num_experts: int, top_k: int,
                         tokens_per_rank: int, token_bytes: int,
                         compute_s: float = 0.0):
        """DEPRECATED shim: the per-site combine view of the jointly
        planned MoE pipeline (no longer planned independently of
        dispatch — the executable-pairing constraint and the shared
        microbatch G apply)."""
        _deprecated("moe_combine_plan",
                    "plan_collectives(program).decision(role)")
        return self._moe_site_decision("combine", num_experts, top_k,
                                       tokens_per_rank, token_bytes,
                                       compute_s)

    def resolve_moe_dispatch(self, num_experts: int, top_k: int,
                             tokens_per_rank: int, token_bytes: int,
                             compute_s: float = 0.0) -> dict:
        """DEPRECATED shim: ``{"moe_scheme", "microbatch"}`` of the
        jointly planned pipeline.  Use :meth:`moe_pipeline_kwargs`."""
        _deprecated("resolve_moe_dispatch", "moe_pipeline_kwargs")
        kw = self.moe_pipeline_kwargs(num_experts, top_k, tokens_per_rank,
                                      token_bytes, compute_s=compute_s)
        return {"moe_scheme": kw["moe_scheme"],
                "microbatch": kw["microbatch"]}

    def resolve_moe_scheme(self, num_experts: int, top_k: int,
                           tokens_per_rank: int, token_bytes: int,
                           compute_s: float = 0.0) -> str:
        """DEPRECATED shim: the dispatch scheme of the jointly planned
        pipeline.  Use :meth:`moe_pipeline_kwargs`."""
        _deprecated("resolve_moe_scheme", "moe_pipeline_kwargs")
        return self.moe_pipeline_kwargs(
            num_experts, top_k, tokens_per_rank, token_bytes,
            compute_s=compute_s)["moe_scheme"]

    def resolve_combine_scheme(self, num_experts: int, top_k: int,
                               tokens_per_rank: int, token_bytes: int,
                               compute_s: float = 0.0,
                               microbatch: Optional[int] = None) -> str:
        """DEPRECATED shim: the return-path scheme of the jointly
        planned pipeline.  ``microbatch`` is accepted for compatibility
        and ignored — the joint sweep already chooses the combine scheme
        at the ONE shared G the pipeline executes."""
        _deprecated("resolve_combine_scheme", "moe_pipeline_kwargs")
        del microbatch
        return self.moe_pipeline_kwargs(
            num_experts, top_k, tokens_per_rank, token_bytes,
            compute_s=compute_s)["moe_combine"]


def build_collective_program(cfg, pctx: ParallelContext, name: str,
                             phases: dict, *, itemsize: int = 2,
                             phase_budgets: Optional[dict] = None):
    """The declared collective program of one launch surface.

    ``phases`` maps a phase name ("train" | "prefill" | "decode") to its
    ``(global_batch, seq_len)`` workload (``seq_len == 1`` for decode).
    Per phase this declares the coupled MoE (dispatch, combine) pair
    (MoE archs) and the split-TP boundary gather (when the context's
    geometry emits one) — exactly the sites the traced model will look
    up, derived from the same shard math the trace uses.  ``itemsize``
    must match the activation dtype the model will TRACE with (bf16
    default; pass 4 for fp32 smoke runs) — site keys embed the payload
    bucket, so a dtype mismatch makes every lookup miss and fall back
    to ad-hoc planning at the wrong payload.

    ``phase_budgets`` (phase name -> seconds) declares per-phase latency
    caps — a decode SLO here constrains the OTHER phases' plans during
    the planner's contention-aware sweep (``--decode-slo-us`` on the
    serve CLI)."""
    from repro.core import plan as plan_ir
    from repro.core.latency_model import moe_overlap_compute_s
    sites = []
    for phase, (global_batch, seq_len) in phases.items():
        if getattr(cfg, "is_moe", False):
            dp = pctx.num_pods * pctx.data_size
            n_rank = max(1, (global_batch * seq_len) // dp)
            d_ff = getattr(cfg, "expert_d_ff", cfg.d_model)
            compute_s = moe_overlap_compute_s(
                n_rank, cfg.top_k, cfg.d_model, d_ff, tp=pctx.model_size)
            sites.extend(pctx.moe_sites(
                phase, num_experts=cfg.num_experts, top_k=cfg.top_k,
                tokens_per_rank=n_rank,
                token_bytes=cfg.d_model * itemsize,
                compute_s=compute_s))
        if seq_len > 1:
            ag = pctx.split_tp_gather_site(
                phase, global_batch=global_batch, seq_len=seq_len,
                d_model=cfg.d_model, itemsize=itemsize)
            if ag is not None:
                sites.append(ag)
        if phase == "train":
            # every optimizer step ends in a gradient AllReduce over the
            # DP replicas — declare it so the planner sweeps its scheme
            # and chunking jointly with the phase's other collectives
            from repro.models.api import param_count_shape_only
            dp = pctx.num_pods * pctx.data_size
            n_rank = max(1, (global_batch * seq_len) // dp)
            gs = pctx.grad_sync_site(
                phase, num_params=param_count_shape_only(cfg),
                tokens_per_rank=n_rank)
            if gs is not None:
                sites.append(gs)
    return plan_ir.CollectiveProgram(name, tuple(sites),
                                     phase_budgets=dict(phase_budgets or {}))


class PlanBinder:
    """Double-buffered :class:`~repro.core.plan.ExecutionPlan` binding
    with a traced-lowering cache keyed on plan fingerprint — the hot
    re-bind mechanic that turns plan churn into a runtime non-event
    (ROADMAP: millions-of-users path).

    ``trace_fn(plan)`` builds the traced/lowered artifact that executes
    under ``plan`` (e.g. jitted prefill/decode closures over the bound
    context).  The binder keeps two buffers:

    - the **active** (plan, artifact) pair the step loop executes;
    - a **pending** plan staged by :meth:`stage` — its artifact is built
      (or found in the cache) at stage time, OFF the step path.

    :meth:`swap_if_pending` is called at step boundaries and is a pure
    pointer swap when the staged lowering is cached (the invariant the
    stress soak asserts: zero cold retraces).  A swap whose artifact is
    missing — evicted, or staged around the cache — builds it AT the
    swap point and counts it as a cold retrace, so regressions are
    observable rather than silent.  Re-binding to a previously-seen
    fingerprint (recovery flipping back to the pre-failure plan) is a
    cache hit: no retrace at all.
    """

    def __init__(self, trace_fn, plan=None, *, cache_size: int = 8) -> None:
        import collections
        self._trace_fn = trace_fn
        self._cache: "collections.OrderedDict" = collections.OrderedDict()
        self.cache_size = max(1, int(cache_size))
        self.swaps = 0
        self.cold_retraces = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self._pending = None          # staged plan awaiting a boundary
        self._active = (None, None)   # (plan, artifact)
        if plan is not None or trace_fn is not None:
            # the initial bind traces at construction (startup, not a
            # swap): the step loop starts with a warm active buffer
            self._active = (plan, self._build(plan))

    @staticmethod
    def _key(plan):
        return plan.fingerprint if plan is not None else None

    @staticmethod
    def _program(plan) -> str:
        return plan.program.name if plan is not None else "none"

    def _metrics(self):
        from repro.telemetry import metrics as _m
        return _m.default_registry()

    def _build(self, plan):
        """Artifact for ``plan`` through the fingerprint-keyed cache."""
        key = self._key(plan)
        if key in self._cache:
            self._cache.move_to_end(key)
            self.cache_hits += 1
            self._metrics()["repro_lowering_cache_hits_total"].inc(
                program=self._program(plan))
            return self._cache[key]
        self.cache_misses += 1
        self._metrics()["repro_lowering_cache_misses_total"].inc(
            program=self._program(plan))
        art = self._trace_fn(plan)
        self._cache[key] = art
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return art

    @property
    def plan(self):
        return self._active[0]

    @property
    def artifact(self):
        return self._active[1]

    @property
    def pending(self) -> bool:
        return self._pending is not None

    def stage(self, plan) -> bool:
        """Stage ``plan`` for the next step boundary, building its
        lowering NOW (double-buffered: the active plan keeps serving
        while the replacement traces).  Returns False when ``plan`` is
        already active with nothing pending — there is nothing to swap."""
        if self._pending is None and self._key(plan) == \
                self._key(self._active[0]):
            return False
        self._build(plan)
        self._pending = plan
        return True

    def prefetch(self, plan) -> bool:
        """Warm the traced-lowering cache for ``plan`` WITHOUT staging a
        swap — the serving tier's batch-bucket prefetch.  The
        neighboring bucket's lowering is built here, off the step path,
        so a later :meth:`stage` + :meth:`swap_if_pending` when the
        decode batch grows across the bucket boundary is a pure pointer
        flip (mirroring the failover swap).  Returns True when this
        call built the artifact; False when it was already cached (or
        already active)."""
        key = self._key(plan)
        if key == self._key(self._active[0]) or key in self._cache:
            return False
        self._build(plan)
        self._metrics()["repro_plan_prefetch_total"].inc(
            program=self._program(plan))
        return True

    def swap_if_pending(self) -> bool:
        """Make the staged plan active (call between steps).  A pure
        pointer swap when the staged lowering is cached; a cache miss
        here IS the cold retrace the double-buffering exists to avoid,
        and is counted as such."""
        if self._pending is None:
            return False
        plan = self._pending
        self._pending = None
        key = self._key(plan)
        if key in self._cache:
            self._cache.move_to_end(key)
            art = self._cache[key]
        else:
            self.cold_retraces += 1
            self._metrics()["repro_rebind_cold_retrace_total"].inc(
                program=self._program(plan))
            art = self._build(plan)
        self._active = (plan, art)
        self.swaps += 1
        self._metrics()["repro_plan_rebind_total"].inc(
            program=self._program(plan),
            fingerprint=(plan.fingerprint if plan is not None else "none"))
        return True


def shard(x, pctx: Optional[ParallelContext], *spec):
    """with_sharding_constraint that no-ops without a context."""
    if pctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_residual(x, pctx: Optional[ParallelContext]):
    """Between-block constraint on the residual stream [B, S, D]:
    SP shards S over model (memory / L x smaller scan-bwd carry stack)."""
    if pctx is None:
        return x
    if pctx.seq_parallel and x.shape[1] % pctx.model_size == 0:
        return shard(x, pctx, pctx.dp_axes, pctx.model_axis, None)
    return shard(x, pctx, pctx.dp_axes, None, None)
