"""Latency-model-driven plan selection (paper §5.2 dynamic workflow).

The paper makes scheme choice *dynamic*: "the split ratio is dynamically
calculated based on the measured bandwidth of both link types", and Fig 7
shows MultiWrite only wins past a ~2 MB crossover.  :class:`Planner`
reproduces that behaviour for any registered
:class:`~repro.core.plan.CollectivePlan`:

    decision = Planner().choose("allgather", payload_bytes, topo)
    decision.plan               # "baseline" below ~2 MB, "multiwrite_*" above
    decision.shard_map_kwargs   # mode=/split= for the JAX layer

``choose`` sweeps every registered plan x its knob grid (grids are seeded
on :func:`repro.core.schedules.optimal_split`), simulates each candidate
on the packet oracle, scores the ledger with the calibrated
:class:`~repro.core.latency_model.HardwareModel`, and memoizes the
decision in an LRU cache keyed on
``(op, topology fingerprint, bucketed payload size, hw)`` — so the JAX
layer can consult the planner at every trace without re-simulating.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from collections import OrderedDict
from typing import Optional

from . import plan as plan_ir
from . import schedules as _schedules  # noqa: F401  (registers the plans)
from .latency_model import (DEFAULT, HardwareModel, overlap_endpoints,
                            phase_breakdown, pipeline_overlap_endpoints,
                            score_ledger, score_phase, score_pipeline)
# bucketing lives next to the CollectiveSite keys it must agree with;
# re-exported here because this module defined it historically
from .plan import bucket_compute_s, bucket_payload  # noqa: F401
from .topology import TPU_ICI_LINK_BW, Topology, full_mesh, tpu_pods

_METRICS = None


def _metrics_registry():
    """The process metrics plane, resolved lazily: ``repro.telemetry``
    imports this module (the monitor drives the planner), so the import
    must happen at call time, not module load."""
    global _METRICS
    if _METRICS is None:
        from repro.telemetry import metrics as _m
        _METRICS = _m.default_registry()
    return _METRICS


# ---------------------------------------------------------------------------
# feasibility under failures
# ---------------------------------------------------------------------------

class NoFeasiblePlanError(RuntimeError):
    """Every candidate of an op was masked as infeasible under the
    topology's :class:`~repro.core.topology.FailureState` — the fabric is
    effectively partitioned for this collective.  Raised instead of
    scoring garbage on links that cannot carry traffic; callers (serving
    tier, stress harness) treat it as "shed or hold traffic", never as a
    plan."""

    def __init__(self, op: str, fabric: str, masked: list[str]):
        self.op = op
        self.fabric = fabric
        self.masked = list(masked)
        detail = "; ".join(self.masked[:4])
        if len(self.masked) > 4:
            detail += f"; ... ({len(self.masked)} candidates)"
        super().__init__(
            f"no feasible {op!r} plan on {fabric}: every candidate was "
            f"masked by the fabric's failure state [{detail}]")


def ledger_infeasible(ledger, failures) -> Optional[str]:
    """Why a simulated ledger cannot execute under ``failures`` (None =
    feasible).  Two checks, straight from the failure model:

    - any charged link is dead (or touches a lost NPU) — no scheme can
      serialize bytes over a dark rail;
    - any *software forwarding engine* the plan relies on
      (``ledger.engine_serial`` — populated only by multiwrite/relayed
      schedules) sits on a dead relay.  Plain unicast store-and-forward
      charges ``relay_bytes`` but no engine, so it survives a relay-engine
      loss — the multiwrite → hierarchical → unicast degradation ladder.
    """
    for key in ledger.link_bytes:
        if failures.link_is_dead(key):
            return f"dead link {key[0]}->{key[1]}"
    for node in ledger.engine_serial:
        if failures.relay_is_dead(node):
            return f"dead relay engine on node {node}"
    return None


def plan_site_ledgers(eplan, topo: Topology) -> dict:
    """Re-simulate each site decision of ``eplan`` on ``topo`` and
    return ``role -> Ledger`` — the byte ledgers the bound plan actually
    executes.  This is the post-hoc feasibility audit surface: the
    stress harness asserts that no ledger of a serving plan charges a
    link the hidden ground truth has killed (the "never execute an
    infeasible plan" invariant, checked against TRUTH rather than
    against the detector's belief)."""
    out = {}
    for role in sorted(eplan.decisions):
        site = next((s for s in eplan.program.sites if s.role == role),
                    None)
        if site is None:
            continue
        d = eplan.decisions[role]
        scheme = plan_ir.get_plan(site.op, d.plan)
        scenario = Planner._scenario(site.op, site.topo or topo,
                                     site.scenario_args())
        out[role] = scheme.simulate(scenario, d.payload_bytes,
                                    **dict(d.knobs))
    return out


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------

def topology_fingerprint(topo: Topology) -> tuple:
    """Hashable identity of a topology (delegates to
    :meth:`Topology.fingerprint`: name, shape, fabric meta and the exact
    per-link bandwidth assignment — asymmetric fabrics with identical
    bandwidth multisets stay distinct)."""
    return topo.fingerprint()


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """The planner's verdict for one (op, topology, payload bucket)."""

    op: str
    plan: str                       # winning plan name
    knobs: tuple                    # sorted (knob, value) pairs
    predicted_s: float              # winner's modeled latency
    baseline_s: float               # the op's baseline plan latency
    payload_bytes: int              # bucketed payload the scores used
    shard_map_kwargs: dict          # what the JAX layer executes
    candidates: tuple               # ((plan, knobs, predicted_s), ...) sorted
    predicted_serial_s: float = 0.0  # winner scored at overlap_eff=0 (==
    #   predicted_s for non-pipelined winners)
    predicted_ideal_s: float = 0.0   # winner scored at overlap_eff=1; the
    #   (serial, ideal) endpoints bracket any measured time, which is how
    #   telemetry fits the achieved overlap efficiency (fit_overlap_eff)

    @property
    def delta_vs_baseline(self) -> float:
        """Predicted latency saved vs the baseline plan (seconds; >0 means
        the chosen plan is faster)."""
        return self.baseline_s - self.predicted_s

    @property
    def speedup_pct(self) -> float:
        if self.baseline_s <= 0:
            return 0.0
        return 100.0 * (1.0 - self.predicted_s / self.baseline_s)

    def knob(self, name: str, default=None):
        return dict(self.knobs).get(name, default)

    @property
    def microbatch(self) -> int:
        """Pipeline chunk count G of the winning plan (1 = unchunked)."""
        return int(self.knob("microbatch", 1))

    def summary(self) -> str:
        kn = ", ".join(f"{k}={v}" for k, v in self.knobs)
        return (f"{self.op}: plan={self.plan}({kn}) "
                f"predicted={self.predicted_s * 1e6:.1f}us "
                f"baseline={self.baseline_s * 1e6:.1f}us "
                f"({self.speedup_pct:+.1f}%)")

    def report(self) -> dict:
        """JSON-serializable view for dry-run cells / serve stats."""
        return {"plan": self.plan, "knobs": dict(self.knobs),
                "predicted_us": self.predicted_s * 1e6,
                "baseline_us": self.baseline_s * 1e6,
                "delta_vs_baseline_us": self.delta_vs_baseline * 1e6,
                "speedup_pct": self.speedup_pct}


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

class Planner:
    """Sweeps registered plans + knob grids; scores with the latency model.

    One process-wide instance (:func:`default_planner`) backs the JAX
    layer; tests construct their own to control the cache.
    """

    # decision_log ring-buffer cap: long-lived servers append a row per
    # fresh decision AND per cache-served measurement forever — without a
    # cap a week-long serve leaks unboundedly.  10k rows keeps far more
    # history than fit_overlap_eff's median needs while bounding memory;
    # evictions are counted (decision_log_dropped /
    # repro_planner_decision_log_dropped_total).
    DECISION_LOG_MAX = 10_000

    PROGRAM_CACHE_SIZE = 64

    # largest per-phase candidate product the exhaustive oracle sweeps;
    # above it "auto" program planning switches to beam search (the
    # product grows multiplicatively with every op that joins a phase —
    # a 3-group tpu_2x16 train phase is already ~2000 combinations)
    EXHAUSTIVE_LIMIT = 512

    def __init__(self, hw: HardwareModel = DEFAULT,
                 cache_size: int = 256, *, beam_width: int = 6,
                 shortlist_k: int = 6, search: str = "auto",
                 decision_log_max: Optional[int] = None) -> None:
        if search not in ("auto", "beam", "exhaustive"):
            raise ValueError(f"unknown search mode {search!r}; expected "
                             f"'auto' | 'beam' | 'exhaustive'")
        self.hw = hw
        self.beam_width = int(beam_width)
        self.shortlist_k = int(shortlist_k)
        self.search = search
        self.cache_size = int(cache_size)
        self._cache: OrderedDict[tuple, PlanDecision] = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0
        self.recalibrations = 0
        # (plan, predicted, measured) rows: one per fresh sweep (measured
        # None until telemetry fills it via note_measurement) — the audit
        # trail the drift monitor and serve reports read.  Ring-buffered
        # at decision_log_max; evictions counted in decision_log_dropped.
        self.decision_log: list[dict] = []
        self.decision_log_max = int(self.DECISION_LOG_MAX
                                    if decision_log_max is None
                                    else decision_log_max)
        self.decision_log_dropped = 0
        # last winning scheme per (op, fabric, bucket) cell — flips
        # (scheme changes after a recalibration) are an SLO-bearing
        # production event, counted in repro_planner_decision_flips_total
        self._last_scheme: dict[tuple, str] = {}
        # whole-program planning: memoized ExecutionPlans plus a registry
        # of every (program, topo) planned through this planner, so a
        # re-calibration can replan PROGRAMS (the unit consumers bind)
        # rather than just dropping per-op cache entries.
        self._program_cache: OrderedDict[tuple, object] = OrderedDict()
        self._programs: OrderedDict[tuple, tuple] = OrderedDict()

    # -- cache ---------------------------------------------------------------
    def cache_info(self) -> dict:
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "size": len(self._cache), "maxsize": self.cache_size}

    def cache_clear(self) -> None:
        self._cache.clear()
        self._program_cache.clear()
        self.cache_hits = self.cache_misses = 0

    # -- online re-calibration ----------------------------------------------
    def refresh_hardware(self, hw: HardwareModel) -> None:
        """Swap the hardware model (telemetry re-calibration) and drop
        every cached decision.  The cache key already carries
        ``hw.fingerprint()``, so stale entries could never be *served*
        under the new model — clearing just stops them squatting in the
        LRU."""
        self.hw = hw
        self._cache.clear()
        self._program_cache.clear()
        self.recalibrations += 1

    def _trim_decision_log(self) -> None:
        """Ring-buffer eviction for every decision_log append path (fresh
        decisions, program rows AND note_measurement's fallback append —
        the path that used to leak on long-lived servers)."""
        overflow = len(self.decision_log) - self.decision_log_max
        if overflow > 0:
            del self.decision_log[:overflow]
            self.decision_log_dropped += overflow
            _metrics_registry()[
                "repro_planner_decision_log_dropped_total"].inc(overflow)

    def _log_decision(self, decision: PlanDecision, topo_name: str) -> None:
        self.decision_log.append(
            {"op": decision.op, "plan": decision.plan,
             "knobs": dict(decision.knobs), "topo": topo_name,
             "payload_bytes": decision.payload_bytes,
             "predicted_s": decision.predicted_s,
             # overlap-interpolation endpoints of the winner: the rows
             # telemetry fits hw.overlap_eff against once measured_s
             # arrives (fit_overlap_eff skips rows where they coincide)
             "predicted_serial_s": decision.predicted_serial_s,
             "predicted_ideal_s": decision.predicted_ideal_s,
             "measured_s": None})
        self._trim_decision_log()
        reg = _metrics_registry()
        labels = dict(op=decision.op, fabric=topo_name,
                      payload_bucket=str(decision.payload_bytes))
        reg["repro_planner_decisions_total"].inc(scheme=decision.plan,
                                                 **labels)
        cell = (decision.op, topo_name, decision.payload_bytes)
        prev = self._last_scheme.get(cell)
        if prev is not None and prev != decision.plan:
            reg["repro_planner_decision_flips_total"].inc(**labels)
        self._last_scheme[cell] = decision.plan

    def note_measurement(self, decision: PlanDecision,
                         measured_s: float) -> dict:
        """Attach a measured execution time to the most recent logged row
        for this decision (telemetry closes the loop here); appends a
        fresh row if the decision was served from cache.  The knob AND
        predicted-score match matter: a G == 1 execution time written
        into a G > 1 row — or into the same plan's row for a DIFFERENT
        fabric/compute context (equal op/plan/payload, different
        endpoints) — would corrupt the overlap-efficiency fit.
        ``predicted_s`` is copied verbatim from the decision into its
        log row, so float equality identifies exactly its rows."""
        knobs = dict(decision.knobs)
        for row in reversed(self.decision_log):
            if (row["op"] == decision.op and row["plan"] == decision.plan
                    and row["payload_bytes"] == decision.payload_bytes
                    and row["predicted_s"] == decision.predicted_s
                    and dict(row.get("knobs", {})) == knobs
                    and row["measured_s"] is None):
                row["measured_s"] = float(measured_s)
                return row
        row = {"op": decision.op, "plan": decision.plan,
               "knobs": dict(decision.knobs), "topo": None,
               "payload_bytes": decision.payload_bytes,
               "predicted_s": decision.predicted_s,
               "predicted_serial_s": decision.predicted_serial_s,
               "predicted_ideal_s": decision.predicted_ideal_s,
               "measured_s": float(measured_s)}
        self.decision_log.append(row)
        self._trim_decision_log()
        return row

    # -- scenario construction ----------------------------------------------
    @staticmethod
    def _scenario(op: str, topo: Topology, scenario_kw: dict):
        if op == "allgather":
            num_domains = scenario_kw.get("num_domains", 2)
            return plan_ir.AllGatherScenario.split_tp(topo, num_domains)
        if op in ("dispatch", "combine"):
            cls = (plan_ir.DispatchScenario if op == "dispatch"
                   else plan_ir.CombineScenario)
            return cls(
                topo=topo,
                num_experts=scenario_kw.get("num_experts", 64),
                top_k=scenario_kw.get("top_k", 8),
                token_bytes=scenario_kw.get("token_bytes", 7168),
                skew=scenario_kw.get("skew", 0.0),
                compute_s=bucket_compute_s(
                    scenario_kw.get("compute_s", 0.0)))
        if op == "linkprobe":
            return plan_ir.LinkProbeScenario(
                topo, scenario_kw.get("src_server", 0),
                scenario_kw.get("dst_server",
                                1 if topo.meta.num_servers > 1 else 0))
        if op in ("allreduce", "reduce_scatter"):
            return plan_ir.ReduceScenario(
                topo=topo,
                compute_s=bucket_compute_s(
                    scenario_kw.get("compute_s", 0.0)))
        raise ValueError(f"unknown collective op {op!r}")

    # -- the decision --------------------------------------------------------
    def choose(self, op: str, payload_bytes: float, topo: Topology,
               hw: Optional[HardwareModel] = None, *,
               executable_only: bool = False, **scenario_kw) -> PlanDecision:
        """Pick the fastest registered plan for ``op`` at ``payload_bytes``.

        ``payload_bytes`` is the per-participant payload: the AllGather
        fragment size, or ``tokens_per_rank * token_bytes`` for dispatch.
        """
        hw = hw or self.hw
        bucket = bucket_payload(payload_bytes)
        scenario = self._scenario(op, topo, scenario_kw)
        # the hw FINGERPRINT (not the object) is part of the key: an
        # in-place ``planner.hw`` swap after recalibration can never
        # serve a decision scored under the old calibration, and two
        # value-equal models share entries.
        key = (op, topology_fingerprint(topo), bucket, hw.fingerprint(),
               executable_only, scenario.cache_key())
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            _metrics_registry()["repro_planner_cache_hits_total"].inc()
            self._cache.move_to_end(key)
            return hit
        self.cache_misses += 1
        _metrics_registry()["repro_planner_cache_misses_total"].inc()
        decision = self._sweep(op, scenario, bucket, hw, executable_only)
        self._cache[key] = decision
        self._log_decision(decision, topo.name)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return decision

    def _site_rows(self, op: str, scenario, bucket: int, hw: HardwareModel,
                   executable_only: bool) -> list[tuple]:
        """Every (plan, knobs) candidate of one uncoupled site, simulated
        and scored on its own ledger; sorted by (own score, registration
        order).  Rows are ``(t, order, plan, knobs, ledger)``."""
        plans = plan_ir.plans_for(op, executable_only=executable_only)
        if not plans:
            raise ValueError(f"no plans registered for op {op!r}")
        topo = scenario.topo
        failures = topo.failures if topo.failures else None
        scored: list[tuple] = []        # (t, order, plan, knobs, ledger)
        masked: list[str] = []
        for order, p in enumerate(plans):
            for knobs in p.knob_grid():
                try:
                    ledger = p.simulate(scenario, bucket, **knobs)
                    reason = (ledger_infeasible(ledger, failures)
                              if failures is not None else None)
                    if reason is None:
                        t = score_ledger(ledger, hw)
                except (ValueError, KeyError, RuntimeError) as e:
                    # on a degraded fabric a candidate may not even
                    # simulate (no route / missing link); that IS the
                    # feasibility verdict, not an error
                    if failures is None:
                        raise
                    reason = str(e)
                if reason is not None:
                    masked.append(f"{p.name}: {reason}")
                    continue
                scored.append((t, order, p, knobs, ledger))
        if masked:
            _metrics_registry()["repro_plan_infeasible_total"].inc(
                len(masked), op=op, fabric=topo.name)
        if not scored:
            raise NoFeasiblePlanError(op, topo.name, masked)
        scored.sort(key=lambda s: (s[0], s[1]))
        return scored

    def _site_decision(self, op: str, scored: list, chosen: tuple,
                       bucket: int, hw: HardwareModel) -> PlanDecision:
        """PlanDecision for ``chosen`` (any row of ``scored`` — the
        contention-aware program search may pick a non-first row)."""
        best_t, _, best, best_knobs, best_ledger = chosen
        base_name = plan_ir.BASELINE_PLAN[op]
        # the baseline reference is the SERIAL (G == 1) baseline cell —
        # what a fixed-policy baseline deployment actually executes —
        # so speedup_pct keeps its meaning now that the grid also holds
        # pipelined baseline candidates
        base_t = min((t for t, _, p, kn, _ in scored
                      if p.name == base_name
                      and kn.get("microbatch", 1) == 1),
                     default=best_t)
        serial_t, ideal_t = overlap_endpoints(best_ledger, hw)
        return PlanDecision(
            op=op, plan=best.name,
            knobs=tuple(sorted(best_knobs.items())),
            predicted_s=best_t, baseline_s=base_t, payload_bytes=bucket,
            shard_map_kwargs=best.shard_map_kwargs(**best_knobs),
            candidates=tuple((p.name, tuple(sorted(kn.items())), t)
                             for t, _, p, kn, _ in scored),
            predicted_serial_s=serial_t, predicted_ideal_s=ideal_t)

    def _sweep(self, op: str, scenario, bucket: int, hw: HardwareModel,
               executable_only: bool) -> PlanDecision:
        scored = self._site_rows(op, scenario, bucket, hw, executable_only)
        return self._site_decision(op, scored, scored[0], bucket, hw)

    # -- whole-program planning ----------------------------------------------
    def plan_program(self, program: "plan_ir.CollectiveProgram",
                     topo: Topology,
                     hw: Optional[HardwareModel] = None,
                     *, executable_only: bool = True
                     ) -> "plan_ir.ExecutionPlan":
        """Jointly plan every declared site of ``program`` and return the
        immutable, fingerprinted :class:`~repro.core.plan.ExecutionPlan`.

        Uncoupled sites sweep exactly as :meth:`choose` does.  Coupled
        groups — the MoE (dispatch, combine) pair that executes inside
        ONE chunk pipeline — sweep the full (dispatch scheme) x (combine
        scheme) x (shared microbatch G) product under the
        shared-pipeline scorer (:func:`score_pipeline`), so a smaller
        dispatch G can win on the COMBINED score where the old
        dispatch-first resolution would have over-chunked (the joint
        pipeline pays dispatch + combine startup per chunk and its
        bottleneck stage is the max over three stages, not two).

        Groups CONCURRENT within one phase contend for shared links:
        each phase's candidate combinations are scored with
        :func:`~repro.core.latency_model.score_phase` (per-link demand
        summed across the phase's sites, the summed bottleneck charged
        jointly), searched exhaustively when the candidate product is
        small (the oracle) and by beam search over per-group shortlists
        past :data:`EXHAUSTIVE_LIMIT`.  Phases carrying a latency budget
        (``program.phase_budgets``) are planned first and then constrain
        the remaining phases — a combination whose background traffic
        pushes a budgeted phase past its cap is rejected.

        Sites may carry their own fabric (``site.topo``); everything
        else is scored on ``topo``.  Plans are memoized on
        (program, topo, hw, search knobs) and the (program, topo) pair
        is registered so :meth:`replan_programs` can re-derive every
        known program after a re-calibration.
        """
        hw = hw or self.hw
        pkey = (program.cache_key(), topology_fingerprint(topo),
                executable_only)
        key = (*pkey, hw.fingerprint(), self.search, self.beam_width,
               self.shortlist_k)
        hit = self._program_cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            _metrics_registry()["repro_planner_cache_hits_total"].inc()
            self._program_cache.move_to_end(key)
            return hit
        self.cache_misses += 1
        _metrics_registry()["repro_planner_cache_misses_total"].inc()
        t_start = time.perf_counter()
        decisions: dict = {}
        joint: dict = {}
        group_of: dict = {}
        budgets = dict(program.phase_budgets)
        # budgeted phases plan FIRST: their chosen ledgers then act as
        # the fixed background every later phase is constrained against
        phase_order = sorted(program.phases().items(),
                             key=lambda kv: kv[0] not in budgets)
        chosen_entries: dict[str, list] = {}   # phase -> [(score, ledgers)]
        phase_search: dict[str, dict] = {}
        for phase_name, groups in phase_order:
            bundles = [self._group_candidates(g, topo, hw, executable_only)
                       for g in groups]
            constraints = [(chosen_entries[ph], budgets[ph])
                           for ph in budgets
                           if ph != phase_name and ph in chosen_entries]
            combo, stats = self._search_phase(
                bundles, hw, budget=budgets.get(phase_name),
                constraints=constraints)
            phase_search[phase_name] = stats
            entries = []
            for bundle, j in zip(bundles, combo):
                cand = bundle["cands"][j]
                entries.append((cand["score_s"], cand["ledgers"]))
                row = cand["row"]
                if bundle["kind"] == "single":
                    site = bundle["site"]
                    dec = self._site_decision(
                        site.op, bundle["rows"], row, bundle["bucket"], hw)
                    decisions[site.role] = dec
                    self._log_decision(dec, bundle["topo"].name)
                else:
                    dsite, csite = bundle["sites"]
                    d_bucket, c_bucket = bundle["buckets"]
                    d_dec, c_dec, j_dec = self._moe_pair_decisions(
                        bundle["rows"], row, d_bucket, c_bucket, hw)
                    decisions[dsite.role] = d_dec
                    decisions[csite.role] = c_dec
                    joint[dsite.role] = j_dec
                    group_of[dsite.role] = dsite.role
                    group_of[csite.role] = dsite.role
                    self._log_decision(j_dec, bundle["topo"].name)
            chosen_entries[phase_name] = entries
        phase_report: dict[str, dict] = {}
        for phase_name, _ in phase_order:
            entries = chosen_entries[phase_name]
            rep = phase_breakdown(entries, hw)
            rep["groups"] = len(entries)
            rep["budget_s"] = budgets.get(phase_name)
            if phase_name in budgets:
                # the SLO verdict is checked under CONTENDED conditions:
                # every other phase's chosen traffic as background (the
                # continuous-batching regime the budget models)
                background = [led for ph, ents in chosen_entries.items()
                              if ph != phase_name
                              for _, ledgers in ents for led in ledgers]
                rep["contended_score_s"] = score_phase(
                    entries, hw, background=background)
                rep["budget_ok"] = (rep["contended_score_s"]
                                    <= budgets[phase_name])
            rep["search"] = phase_search[phase_name]
            phase_report[phase_name] = rep
        planner_stats = {
            "search": sorted({s["search"]
                              for s in phase_search.values()}),
            "phases": len(phase_search),
            "candidates": sum(s["candidates"]
                              for s in phase_search.values()),
            "product": sum(s["product"] for s in phase_search.values()),
            "combos_scored": sum(s["combos_scored"]
                                 for s in phase_search.values()),
            "combos_pruned": sum(s["combos_pruned"]
                                 for s in phase_search.values()),
            "beam_width": self.beam_width,
            "budget_violated": any(s.get("budget_violated")
                                   for s in phase_search.values()),
            "planning_wall_s": time.perf_counter() - t_start}
        reg = _metrics_registry()
        reg["repro_planner_planning_wall_seconds"].observe(
            planner_stats["planning_wall_s"], program=program.name)
        reg["repro_planner_search_combos_scored"].set(
            planner_stats["combos_scored"], program=program.name)
        reg["repro_planner_search_combos_pruned"].set(
            planner_stats["combos_pruned"], program=program.name)
        reg["repro_planner_search_product"].set(
            planner_stats["product"], program=program.name)
        eplan = plan_ir.ExecutionPlan(
            program=program,
            topo_fingerprint=topology_fingerprint(topo),
            hw_fingerprint=hw.fingerprint(),
            decisions=decisions, joint=joint, group_of=group_of,
            phase_report=phase_report, planner_stats=planner_stats)
        self._log_program(program, topo, eplan)
        self._program_cache[key] = eplan
        while len(self._program_cache) > self.PROGRAM_CACHE_SIZE:
            self._program_cache.popitem(last=False)
        self._programs[pkey] = (program, topo, eplan.fingerprint)
        while len(self._programs) > self.PROGRAM_CACHE_SIZE:
            self._programs.popitem(last=False)
        return eplan

    def _log_program(self, program, topo: Topology, eplan) -> None:
        """Program-level decision_log row: planner COST introspection
        (candidates, combinations, wall-time) rides the same audit trail
        the per-op rows use.  ``predicted_serial_s`` stays 0 so
        fit_overlap_eff never mistakes it for a measurable op row."""
        stats = dict(eplan.planner_stats)
        total = sum(rep.get("score_s", 0.0)
                    for rep in eplan.phase_report.values())
        self.decision_log.append(
            {"op": "program", "plan": program.name, "knobs": {},
             "topo": topo.name, "payload_bytes": 0,
             "predicted_s": total, "predicted_serial_s": 0.0,
             "predicted_ideal_s": 0.0, "measured_s": None,
             "planner": stats})
        self._trim_decision_log()

    def _group_candidates(self, group, topo: Topology, hw: HardwareModel,
                          executable_only: bool) -> dict:
        """Candidate bundle of one jointly-planned group: every scored
        row plus a uniform ``cands`` view ``{score_s, ledgers, row}``
        (sorted by own contention-free score) the phase search consumes."""
        if len(group) == 1:
            site = group[0]
            site_topo = site.topo or topo
            scenario = self._scenario(site.op, site_topo,
                                      site.scenario_args())
            bucket = bucket_payload(site.payload_bytes)
            rows = self._site_rows(site.op, scenario, bucket, hw,
                                   executable_only)
            cands = [{"score_s": r[0], "ledgers": (r[4],), "row": r}
                     for r in rows]
            return {"kind": "single", "site": site, "topo": site_topo,
                    "bucket": bucket, "rows": rows, "cands": cands}
        if (len(group) == 2 and group[0].op == "dispatch"
                and group[1].op == "combine"):
            dsite, csite = group
            pair_topo = dsite.topo or topo
            rows, d_bucket, c_bucket = self._moe_pair_rows(
                dsite, csite, pair_topo, hw,
                executable_only=executable_only)
            cands = [{"score_s": r[0], "ledgers": (r[4], r[7]), "row": r}
                     for r in rows]
            return {"kind": "pair", "sites": (dsite, csite),
                    "topo": pair_topo, "buckets": (d_bucket, c_bucket),
                    "rows": rows, "cands": cands}
        raise ValueError(
            f"unsupported coupled group "
            f"{[(s.role, s.op) for s in group]}: joint sweeps are "
            f"defined for a (dispatch, combine) pair")

    def _search_phase(self, bundles: list, hw: HardwareModel, *,
                      budget: Optional[float] = None,
                      constraints=()) -> tuple[tuple, dict]:
        """Pick one candidate per group minimizing the phase's
        contention-aware score (:func:`score_phase`).

        ``budget``       cap on this phase's own score (its SLO);
        ``constraints``  [(entries, budget_s), ...] of already-planned
                         budgeted phases: a combination is infeasible
                         when its ledgers as BACKGROUND push such a
                         phase past its cap.

        Search mode resolves from ``self.search``: the exhaustive
        oracle when the candidate product is within
        :data:`EXHAUSTIVE_LIMIT` (or forced), else beam search — per
        group the top ``shortlist_k`` candidates by own score, partial
        combinations re-scored jointly and pruned to ``beam_width``.
        The greedy all-own-best combination is always evaluated too, so
        beam search can never do worse than independent per-site
        planning.  Infeasible-everywhere falls back to the best
        unconstrained combination with ``budget_violated`` set.

        Ties break toward the lowest sum of own scores, then the
        lexicographically first combination — with zero contention (all
        groups on disjoint fabrics) that reproduces per-group
        independent planning exactly.
        """
        cand_lists = [b["cands"] for b in bundles]
        product = 1
        for cl in cand_lists:
            product *= len(cl)
        n_candidates = sum(len(cl) for cl in cand_lists)
        mode = self.search
        if mode == "auto":
            mode = ("exhaustive" if product <= self.EXHAUSTIVE_LIMIT
                    else "beam")
        stats = {"search": mode, "groups": len(cand_lists),
                 "candidates": n_candidates, "product": product,
                 "beam_width": (self.beam_width if mode == "beam"
                                else None),
                 "shortlist_k": (self.shortlist_k if mode == "beam"
                                 else None),
                 "budget_violated": False}
        constrained = budget is not None or bool(constraints)
        if len(cand_lists) == 1 and not constrained:
            # a lone group cannot contend with itself beyond what its
            # own scorer already charges: its own best is the optimum
            stats.update(combos_scored=0, combos_pruned=0)
            return (0,), stats

        def entries_of(combo):
            return [(cand_lists[i][j]["score_s"],
                     cand_lists[i][j]["ledgers"])
                    for i, j in enumerate(combo)]

        def feasible(combo, phase_s):
            if budget is not None and phase_s > budget:
                return False
            if constraints:
                bg = [led for _, ledgers in entries_of(combo)
                      for led in ledgers]
                for ents, cap in constraints:
                    if score_phase(ents, hw, background=bg) > cap:
                        return False
            return True

        def own_sum(combo):
            return sum(cand_lists[i][j]["score_s"]
                       for i, j in enumerate(combo))

        scored_count = 0
        finalists: list[tuple] = []     # (phase_s, own_sum, combo)
        if mode == "exhaustive":
            for combo in itertools.product(
                    *(range(len(cl)) for cl in cand_lists)):
                phase_s = score_phase(entries_of(combo), hw)
                scored_count += 1
                finalists.append((phase_s, own_sum(combo), combo))
        else:
            k = max(1, self.shortlist_k)
            width = max(1, self.beam_width)
            beams: list[tuple] = [((), 0.0, 0.0)]
            for cl in cand_lists:
                grown = []
                for combo, _, _ in beams:
                    for j in range(min(k, len(cl))):
                        c2 = combo + (j,)
                        phase_s = score_phase(entries_of(c2), hw)
                        scored_count += 1
                        grown.append((c2, phase_s, own_sum(c2)))
                grown.sort(key=lambda b: (b[1], b[2], b[0]))
                beams = grown[:width]
            finalists = [(s, o, c) for c, s, o in beams]
            greedy = tuple(0 for _ in cand_lists)
            if greedy not in {c for _, _, c in finalists}:
                phase_s = score_phase(entries_of(greedy), hw)
                scored_count += 1
                finalists.append((phase_s, own_sum(greedy), greedy))
        finalists.sort()
        best = finalists[0]
        if constrained:
            for cand in finalists:
                if feasible(cand[2], cand[0]):
                    best = cand
                    break
            else:
                stats["budget_violated"] = True
        stats["combos_scored"] = scored_count
        stats["combos_pruned"] = max(0, product - scored_count)
        return best[2], stats

    def plan_is_stale(self, eplan) -> Optional[bool]:
        """Whether a bound ExecutionPlan has been superseded by a replan
        of the same (program, fabric) under newer calibration — True
        (stale), False (current), or None (this planner has no record,
        e.g. a pinned plan or a foreign planner's product).  A program
        that was RETARGETED to a different topology (failover /
        failback via :meth:`retarget_programs`) makes any plan bound on
        the old fabric stale by construction."""
        program_seen = False
        for pkey, (_, _, fp) in self._programs.items():
            if pkey[0] != eplan.program.cache_key():
                continue
            if pkey[1] == eplan.topo_fingerprint:
                return fp != eplan.fingerprint
            program_seen = True
        if program_seen:
            return True
        return None

    def retarget_programs(self, old_topo: Topology,
                          new_topo: Topology) -> list[dict]:
        """Move every registered program from ``old_topo`` to
        ``new_topo`` and re-plan it there — the planner half of a
        failover (or failback): routing recomputes from the surviving
        capacity graph, and plans bound on the old fabric become stale
        (:meth:`plan_is_stale`) so the runtime re-binds.

        Returns one event per moved program, shaped like
        :meth:`replan_programs` events.  A program whose collectives are
        unplannable on the degraded fabric surfaces the typed
        :class:`NoFeasiblePlanError` in the event (``plan=None``) rather
        than silently keeping the old, infeasible plan registered.
        """
        old_fp = topology_fingerprint(old_topo)
        events = []
        reg = _metrics_registry()
        for pkey, (program, _, old_plan_fp) in list(self._programs.items()):
            if pkey[1] != old_fp:
                continue
            del self._programs[pkey]
            try:
                eplan = self.plan_program(program, new_topo,
                                          executable_only=pkey[-1])
            except NoFeasiblePlanError as e:
                events.append({"program": program.name, "fingerprint": None,
                               "changed": True, "plan": None, "error": e})
                continue
            changed = eplan.fingerprint != old_plan_fp
            reg["repro_plan_replan_total"].inc(
                program=program.name,
                changed="true" if changed else "false")
            events.append({"program": program.name,
                           "fingerprint": eplan.fingerprint,
                           "changed": changed,
                           "plan": eplan})
        return events

    def replan_programs(self) -> list[dict]:
        """Re-plan every registered (program, topo) under the CURRENT
        hardware model — the whole-program face of a re-calibration
        (DriftMonitor calls this after :meth:`refresh_hardware`).
        Returns one event per program: its fresh plan and whether any
        decision changed (fingerprint moved)."""
        events = []
        reg = _metrics_registry()
        for pkey, (program, topo, old_fp) in list(self._programs.items()):
            eplan = self.plan_program(program, topo,
                                      executable_only=pkey[-1])
            changed = eplan.fingerprint != old_fp
            reg["repro_plan_replan_total"].inc(
                program=program.name,
                changed="true" if changed else "false")
            events.append({"program": program.name,
                           "fingerprint": eplan.fingerprint,
                           "changed": changed,
                           "plan": eplan})
        return events

    def _moe_pair_rows(self, dsite, csite, topo: Topology,
                       hw: HardwareModel, *, executable_only: bool
                       ) -> tuple[list, int, int]:
        """Every executable (dispatch config) x (combine config) cell of
        the coupled MoE pair, scored with the shared-pipeline scorer;
        sorted by (joint score, registration order).  Rows are
        ``(t, (d_ord, c_ord), pd, kn_d, ld, pc, kn_c, lc)``."""
        d_scenario = self._scenario("dispatch", topo, dsite.scenario_args())
        c_scenario = self._scenario("combine", topo, csite.scenario_args())
        d_bucket = bucket_payload(dsite.payload_bytes)
        c_bucket = bucket_payload(csite.payload_bytes)
        d_plans = plan_ir.plans_for("dispatch",
                                    executable_only=executable_only)
        c_plans = plan_ir.plans_for("combine",
                                    executable_only=executable_only)
        if not d_plans or not c_plans:
            raise ValueError("no registered dispatch/combine plans")
        failures = topo.failures if topo.failures else None
        masked: list[str] = []

        def half_ledger(cache_key, plan, scenario, bucket, knobs):
            """Simulate one half of the pair; an infeasibility reason
            string (instead of a Ledger) poisons every pairing it joins."""
            if cache_key not in ledgers:
                try:
                    led = plan.simulate(scenario, bucket, **knobs)
                    reason = (ledger_infeasible(led, failures)
                              if failures is not None else None)
                except (ValueError, KeyError, RuntimeError) as e:
                    if failures is None:
                        raise
                    led, reason = None, str(e)
                if reason is not None:
                    masked.append(f"{plan.name}: {reason}")
                    led = None
                ledgers[cache_key] = led
            return ledgers[cache_key]

        scored = []      # (t, order, pd, kn_d, ld, pc, kn_c, lc)
        ledgers: dict = {}
        for d_ord, pd in enumerate(d_plans):
            d_scheme = pd.shard_map_kwargs()["moe_scheme"]
            for kn_d in pd.knob_grid():
                d_key = ("d", pd.name, tuple(sorted(kn_d.items())))
                ld = half_ledger(d_key, pd, d_scenario, d_bucket, kn_d)
                if ld is None:
                    continue
                for c_ord, pc in enumerate(c_plans):
                    c_scheme = pc.shard_map_kwargs()["moe_combine"]
                    # executable pairing: the baseline (unicast) dispatch
                    # has no relay stage, so only the unicast return path
                    # exists for it — mirror of moe_ffn's lowering table
                    if d_scheme == "baseline" and c_scheme != "baseline":
                        continue
                    for kn_c in pc.knob_grid():
                        if kn_c.get("microbatch", 1) != \
                                kn_d.get("microbatch", 1):
                            continue
                        c_key = ("c", pc.name,
                                 tuple(sorted(kn_c.items())))
                        lc = half_ledger(c_key, pc, c_scenario, c_bucket,
                                         kn_c)
                        if lc is None:
                            continue
                        t = score_pipeline((ld, lc), hw)
                        scored.append((t, (d_ord, c_ord), pd, kn_d, ld,
                                       pc, kn_c, lc))
        if masked:
            _metrics_registry()["repro_plan_infeasible_total"].inc(
                len(masked), op="dispatch+combine", fabric=topo.name)
        if not scored:
            raise NoFeasiblePlanError("dispatch+combine", topo.name, masked)
        scored.sort(key=lambda s: (s[0], s[1]))
        return scored, d_bucket, c_bucket

    def _joint_moe_sweep(self, dsite, csite, topo: Topology,
                         hw: HardwareModel, *, executable_only: bool):
        """The coupled (dispatch, combine) product sweep.

        Every (dispatch plan, dispatch knobs) x (combine plan, combine
        knobs) cell whose microbatch knobs AGREE (the executed pipeline
        chunks both halves at one shared G) and whose pair is executable
        (a unicast dispatch leaves no relay state for a relay-reduced
        combine to consume) is scored with :func:`score_pipeline`.
        Returns (dispatch decision, combine decision, joint decision):
        the per-site views carry marginal candidates (best joint score
        per own configuration) and their own-ledger predicted times so
        existing per-op reports keep their meaning; the joint view
        carries the combined score, merged execution kwargs and the
        joint serial/ideal endpoints telemetry fits overlap efficiency
        against."""
        scored, d_bucket, c_bucket = self._moe_pair_rows(
            dsite, csite, topo, hw, executable_only=executable_only)
        return self._moe_pair_decisions(scored, scored[0], d_bucket,
                                        c_bucket, hw)

    def _moe_pair_decisions(self, scored: list, chosen: tuple,
                            d_bucket: int, c_bucket: int,
                            hw: HardwareModel):
        """(dispatch, combine, joint) decisions for ``chosen`` (any row
        of ``scored`` — the program search may pick a non-first row when
        phase contention shifts the optimum)."""
        best_t, _, pd, kn_d, ld, pc, kn_c, lc = chosen
        g = kn_d.get("microbatch", 1)
        # joint baseline: what a fixed unicast/unicast serial deployment
        # pays for the whole round trip
        base_t = min((t for t, _, bpd, bkd, _, bpc, bkc, _ in scored
                      if bpd.name == plan_ir.BASELINE_PLAN["dispatch"]
                      and bpc.name == plan_ir.BASELINE_PLAN["combine"]
                      and bkd.get("microbatch", 1) == 1),
                     default=best_t)
        serial_t, ideal_t = pipeline_overlap_endpoints((ld, lc), hw)
        joint = PlanDecision(
            op="dispatch+combine",
            plan=f"{pd.name}+{pc.name}",
            knobs=(("microbatch", g),),
            predicted_s=best_t, baseline_s=base_t,
            payload_bytes=d_bucket,
            shard_map_kwargs={**pd.shard_map_kwargs(**kn_d),
                              **pc.shard_map_kwargs(**kn_c)},
            candidates=tuple(
                (f"{spd.name}+{spc.name}",
                 tuple(sorted({**skd, **skc}.items())), t)
                for t, _, spd, skd, _, spc, skc, _ in scored),
            predicted_serial_s=serial_t, predicted_ideal_s=ideal_t)
        d_dec = self._marginal_decision(
            "dispatch", pd, kn_d, ld, d_bucket, hw, scored,
            side=lambda s: (s[2], s[3]))
        c_dec = self._marginal_decision(
            "combine", pc, kn_c, lc, c_bucket, hw, scored,
            side=lambda s: (s[5], s[6]))
        return d_dec, c_dec, joint

    def _marginal_decision(self, op: str, best_plan, best_knobs, best_ledger,
                           bucket: int, hw: HardwareModel, scored,
                           side) -> PlanDecision:
        """Per-site view of a joint sweep: the site's own-ledger times at
        the jointly chosen configuration, with candidates carrying the
        best JOINT score reachable per (plan, knobs) of this side —
        reports built on candidates stay meaningful under coupling."""
        marginal: dict = {}
        for row in scored:
            p, kn = side(row)
            k = (p.name, tuple(sorted(kn.items())))
            if k not in marginal or row[0] < marginal[k]:
                marginal[k] = row[0]
        own_t = score_ledger(best_ledger, hw)
        base_name = plan_ir.BASELINE_PLAN[op]
        base_rows = [row for row in scored
                     if side(row)[0].name == base_name
                     and side(row)[1].get("microbatch", 1) == 1]
        base_t = (score_ledger(self._side_ledger(base_rows[0], side), hw)
                  if base_rows else own_t)
        serial_t, ideal_t = overlap_endpoints(best_ledger, hw)
        return PlanDecision(
            op=op, plan=best_plan.name,
            knobs=tuple(sorted(best_knobs.items())),
            predicted_s=own_t, baseline_s=base_t, payload_bytes=bucket,
            shard_map_kwargs=best_plan.shard_map_kwargs(**best_knobs),
            candidates=tuple((name, kn, t)
                             for (name, kn), t in sorted(
                                 marginal.items(),
                                 key=lambda kv: (kv[1], kv[0]))),
            predicted_serial_s=serial_t, predicted_ideal_s=ideal_t)

    @staticmethod
    def _side_ledger(row, side):
        """The ledger belonging to ``side`` of a joint-sweep row."""
        p, _ = side(row)
        # rows are (t, order, pd, kn_d, ld, pc, kn_c, lc)
        return row[4] if p is row[2] else row[7]


_DEFAULT: Optional[Planner] = None


def default_planner() -> Planner:
    """Process-wide planner the JAX layer consults at trace time."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Planner()
    return _DEFAULT


# ---------------------------------------------------------------------------
# high-level helpers consumed by the JAX / launch / benchmark layers
# ---------------------------------------------------------------------------

def _ep_topology(num_pods: int, ep_per_pod: int,
                 topo: Optional[Topology] = None) -> Topology:
    """Topology an EP mesh slice is planned on: an explicit fabric when
    given (``--fabric`` / ``ParallelContext.fabric``), else the
    mesh-derived §3.2 shape — pod == server (slow DCN axis),
    chips-per-pod == NPUs-per-server (fast ICI axis).  A single-pod mesh
    has no slow axis: it is planned on the all-ICI full mesh it actually
    is (where unicast and MultiWrite ledgers coincide and the tie-break
    keeps the relay-free unicast plan)."""
    if topo is not None:
        return topo
    if num_pods > 1:
        return tpu_pods(chips_per_pod=max(2, ep_per_pod), num_pods=num_pods)
    return full_mesh(max(2, ep_per_pod), link_bw=TPU_ICI_LINK_BW,
                     name="ici_full_mesh")


def moe_dispatch_decision(*, num_pods: int, ep_per_pod: int,
                          num_experts: int, top_k: int,
                          tokens_per_rank: int, token_bytes: int,
                          hw: Optional[HardwareModel] = None,
                          planner: Optional[Planner] = None,
                          topo: Optional[Topology] = None,
                          skew: float = 0.0,
                          compute_s: float = 0.0) -> PlanDecision:
    """Plan the MoE dispatch for one EP mesh slice INDEPENDENTLY of its
    return path — the dispatch-first reference (what-if reports and
    ``bench_program``'s comparison baseline); executing consumers plan
    the (dispatch, combine) pair jointly via :meth:`Planner.plan_program`
    (see :func:`_ep_topology` for the fabric the payload is scored on).
    The payload is the per-rank token traffic of one dispatch.
    ``skew > 0`` prices hot-expert (non-uniform) routing.
    ``compute_s > 0`` (the expert-FFN time of the full batch, see
    :func:`repro.core.latency_model.expert_compute_time_s`) enables the
    pipelined scoring mode — the ``microbatch`` knob can then win and
    the decision carries a G > 1 the MoE layer double-buffers."""
    planner = planner or default_planner()
    topo = _ep_topology(num_pods, ep_per_pod, topo)
    return planner.choose(
        "dispatch", float(tokens_per_rank) * token_bytes, topo, hw,
        num_experts=num_experts, top_k=top_k, token_bytes=token_bytes,
        skew=skew, compute_s=compute_s)


def moe_combine_decision(*, num_pods: int, ep_per_pod: int,
                         num_experts: int, top_k: int,
                         tokens_per_rank: int, token_bytes: int,
                         hw: Optional[HardwareModel] = None,
                         planner: Optional[Planner] = None,
                         topo: Optional[Topology] = None,
                         skew: float = 0.0,
                         compute_s: float = 0.0) -> PlanDecision:
    """Plan the MoE *combine* (return path) for one EP mesh slice —
    independent of the dispatch decision (the what-if reference; see
    :func:`moe_dispatch_decision`): the return path's redundancy is
    spread over the holders' rails (and may face asymmetric return
    bandwidth), so its crossover sits elsewhere.  ``compute_s`` is the
    overlap context (see :func:`moe_dispatch_decision`): the combine of
    chunk k-1 hides behind the expert FFN of chunk k."""
    planner = planner or default_planner()
    topo = _ep_topology(num_pods, ep_per_pod, topo)
    return planner.choose(
        "combine", float(tokens_per_rank) * token_bytes, topo, hw,
        num_experts=num_experts, top_k=top_k, token_bytes=token_bytes,
        skew=skew, compute_s=compute_s)


def emergent_crossover_bytes(topo: Topology,
                              hw: Optional[HardwareModel] = None,
                              lo: float = 64 * 2 ** 10,
                              hi: float = 64 * 2 ** 20,
                              planner: Optional[Planner] = None) -> float:
    """Smallest payload bucket where the planner stops choosing baseline
    (the emergent Fig 7 crossover).  Returns ``inf`` if baseline always
    wins in [lo, hi]."""
    planner = planner or default_planner()
    size = float(lo)
    while size <= hi:
        d = planner.choose("allgather", size, topo, hw)
        if d.plan != "baseline":
            return float(d.payload_bytes)
        size *= 2
    return math.inf


def emergent_flip_batch(op: str, topo: Topology,
                        token_bytes: int = 7168,
                        batches: tuple = (16, 32, 64, 128, 256, 512,
                                          1024, 2048, 4096),
                        hw: Optional[HardwareModel] = None,
                        planner: Optional[Planner] = None,
                        **scenario_kw) -> float:
    """Smallest per-rank token batch where the planner stops choosing the
    baseline plan for ``op`` ("dispatch"/"combine") — the Fig 8 flip
    point as an emergent quantity.  ``inf`` if the baseline always wins
    over ``batches`` (e.g. on a full mesh with no slow axis)."""
    planner = planner or default_planner()
    base = plan_ir.BASELINE_PLAN[op]
    for batch in batches:
        d = planner.choose(op, float(batch) * token_bytes, topo, hw,
                           token_bytes=token_bytes, **scenario_kw)
        if d.plan != base:
            return float(batch)
    return math.inf


def serve_flip_batches(topo: Topology, token_bytes: int = 7168,
                       hw: Optional[HardwareModel] = None,
                       planner: Optional[Planner] = None,
                       **scenario_kw) -> dict:
    """Decode-phase scheme-crossover batches per MoE op — what the
    serving tier's AdmissionController consults before growing the
    decode batch across a bucket boundary (``inf``: that op's baseline
    never flips, growth is scheme-neutral)."""
    return {op: emergent_flip_batch(op, topo, token_bytes=token_bytes,
                                    hw=hw, planner=planner, **scenario_kw)
            for op in ("dispatch", "combine")}
