"""Latency-model-driven plan selection (paper §5.2 dynamic workflow).

The paper makes scheme choice *dynamic*: "the split ratio is dynamically
calculated based on the measured bandwidth of both link types", and Fig 7
shows MultiWrite only wins past a ~2 MB crossover.  :class:`Planner`
reproduces that behaviour for any registered
:class:`~repro.core.plan.CollectivePlan`:

    decision = Planner().choose("allgather", payload_bytes, topo)
    decision.plan               # "baseline" below ~2 MB, "multiwrite_*" above
    decision.shard_map_kwargs   # mode=/split= for the JAX layer

``choose`` sweeps every registered plan x its knob grid (grids are seeded
on :func:`repro.core.schedules.optimal_split`), simulates each candidate
on the packet oracle, scores the ledger with the calibrated
:class:`~repro.core.latency_model.HardwareModel`, and memoizes the
decision in an LRU cache keyed on
``(op, topology fingerprint, bucketed payload size, hw)`` — so the JAX
layer can consult the planner at every trace without re-simulating.
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from typing import Optional

from . import plan as plan_ir
from . import schedules as _schedules  # noqa: F401  (registers the plans)
from .latency_model import DEFAULT, HardwareModel, score_ledger
from .topology import TPU_ICI_LINK_BW, Topology, full_mesh, tpu_pods


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------

def topology_fingerprint(topo: Topology) -> tuple:
    """Hashable identity of a topology (delegates to
    :meth:`Topology.fingerprint`: name, shape, fabric meta and the exact
    per-link bandwidth assignment — asymmetric fabrics with identical
    bandwidth multisets stay distinct)."""
    return topo.fingerprint()


def bucket_payload(payload_bytes: float) -> int:
    """Power-of-two payload bucket: plan choice is scored at the bucket
    size, so nearby payloads share one cache entry."""
    if payload_bytes <= 1:
        return 1
    return 1 << int(math.ceil(math.log2(float(payload_bytes))))


def bucket_compute_s(compute_s: float) -> float:
    """Power-of-two bucket (in nanoseconds) for the overlap-context
    compute time, mirroring :func:`bucket_payload`: nearby compute
    estimates share one scenario cache entry instead of fragmenting the
    LRU per traced dtype/shape.  Rounded to the NEAREST power of two in
    log space (not up): the bucketed value is baked into the decision's
    serial/ideal endpoints that fit_overlap_eff measures against, and a
    systematically inflated compute stage would bias the fitted
    efficiency upward."""
    if compute_s <= 0:
        return 0.0
    return float(2.0 ** round(math.log2(compute_s * 1e9))) / 1e9


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanDecision:
    """The planner's verdict for one (op, topology, payload bucket)."""

    op: str
    plan: str                       # winning plan name
    knobs: tuple                    # sorted (knob, value) pairs
    predicted_s: float              # winner's modeled latency
    baseline_s: float               # the op's baseline plan latency
    payload_bytes: int              # bucketed payload the scores used
    shard_map_kwargs: dict          # what the JAX layer executes
    candidates: tuple               # ((plan, knobs, predicted_s), ...) sorted
    predicted_serial_s: float = 0.0  # winner scored at overlap_eff=0 (==
    #   predicted_s for non-pipelined winners)
    predicted_ideal_s: float = 0.0   # winner scored at overlap_eff=1; the
    #   (serial, ideal) endpoints bracket any measured time, which is how
    #   telemetry fits the achieved overlap efficiency (fit_overlap_eff)

    @property
    def delta_vs_baseline(self) -> float:
        """Predicted latency saved vs the baseline plan (seconds; >0 means
        the chosen plan is faster)."""
        return self.baseline_s - self.predicted_s

    @property
    def speedup_pct(self) -> float:
        if self.baseline_s <= 0:
            return 0.0
        return 100.0 * (1.0 - self.predicted_s / self.baseline_s)

    def knob(self, name: str, default=None):
        return dict(self.knobs).get(name, default)

    @property
    def microbatch(self) -> int:
        """Pipeline chunk count G of the winning plan (1 = unchunked)."""
        return int(self.knob("microbatch", 1))

    def summary(self) -> str:
        kn = ", ".join(f"{k}={v}" for k, v in self.knobs)
        return (f"{self.op}: plan={self.plan}({kn}) "
                f"predicted={self.predicted_s * 1e6:.1f}us "
                f"baseline={self.baseline_s * 1e6:.1f}us "
                f"({self.speedup_pct:+.1f}%)")

    def report(self) -> dict:
        """JSON-serializable view for dry-run cells / serve stats."""
        return {"plan": self.plan, "knobs": dict(self.knobs),
                "predicted_us": self.predicted_s * 1e6,
                "baseline_us": self.baseline_s * 1e6,
                "delta_vs_baseline_us": self.delta_vs_baseline * 1e6,
                "speedup_pct": self.speedup_pct}


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------

class Planner:
    """Sweeps registered plans + knob grids; scores with the latency model.

    One process-wide instance (:func:`default_planner`) backs the JAX
    layer; tests construct their own to control the cache.
    """

    DECISION_LOG_MAX = 1024

    def __init__(self, hw: HardwareModel = DEFAULT,
                 cache_size: int = 256) -> None:
        self.hw = hw
        self.cache_size = int(cache_size)
        self._cache: OrderedDict[tuple, PlanDecision] = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0
        self.recalibrations = 0
        # (plan, predicted, measured) rows: one per fresh sweep (measured
        # None until telemetry fills it via note_measurement) — the audit
        # trail the drift monitor and serve reports read.
        self.decision_log: list[dict] = []

    # -- cache ---------------------------------------------------------------
    def cache_info(self) -> dict:
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "size": len(self._cache), "maxsize": self.cache_size}

    def cache_clear(self) -> None:
        self._cache.clear()
        self.cache_hits = self.cache_misses = 0

    # -- online re-calibration ----------------------------------------------
    def refresh_hardware(self, hw: HardwareModel) -> None:
        """Swap the hardware model (telemetry re-calibration) and drop
        every cached decision.  The cache key already carries
        ``hw.fingerprint()``, so stale entries could never be *served*
        under the new model — clearing just stops them squatting in the
        LRU."""
        self.hw = hw
        self._cache.clear()
        self.recalibrations += 1

    def _log_decision(self, decision: PlanDecision, topo_name: str) -> None:
        self.decision_log.append(
            {"op": decision.op, "plan": decision.plan,
             "knobs": dict(decision.knobs), "topo": topo_name,
             "payload_bytes": decision.payload_bytes,
             "predicted_s": decision.predicted_s,
             # overlap-interpolation endpoints of the winner: the rows
             # telemetry fits hw.overlap_eff against once measured_s
             # arrives (fit_overlap_eff skips rows where they coincide)
             "predicted_serial_s": decision.predicted_serial_s,
             "predicted_ideal_s": decision.predicted_ideal_s,
             "measured_s": None})
        if len(self.decision_log) > self.DECISION_LOG_MAX:
            del self.decision_log[:-self.DECISION_LOG_MAX]

    def note_measurement(self, decision: PlanDecision,
                         measured_s: float) -> dict:
        """Attach a measured execution time to the most recent logged row
        for this decision (telemetry closes the loop here); appends a
        fresh row if the decision was served from cache.  The knob AND
        predicted-score match matter: a G == 1 execution time written
        into a G > 1 row — or into the same plan's row for a DIFFERENT
        fabric/compute context (equal op/plan/payload, different
        endpoints) — would corrupt the overlap-efficiency fit.
        ``predicted_s`` is copied verbatim from the decision into its
        log row, so float equality identifies exactly its rows."""
        knobs = dict(decision.knobs)
        for row in reversed(self.decision_log):
            if (row["op"] == decision.op and row["plan"] == decision.plan
                    and row["payload_bytes"] == decision.payload_bytes
                    and row["predicted_s"] == decision.predicted_s
                    and dict(row.get("knobs", {})) == knobs
                    and row["measured_s"] is None):
                row["measured_s"] = float(measured_s)
                return row
        row = {"op": decision.op, "plan": decision.plan,
               "knobs": dict(decision.knobs), "topo": None,
               "payload_bytes": decision.payload_bytes,
               "predicted_s": decision.predicted_s,
               "predicted_serial_s": decision.predicted_serial_s,
               "predicted_ideal_s": decision.predicted_ideal_s,
               "measured_s": float(measured_s)}
        self.decision_log.append(row)
        return row

    # -- scenario construction ----------------------------------------------
    @staticmethod
    def _scenario(op: str, topo: Topology, scenario_kw: dict):
        if op == "allgather":
            num_domains = scenario_kw.get("num_domains", 2)
            return plan_ir.AllGatherScenario.split_tp(topo, num_domains)
        if op in ("dispatch", "combine"):
            cls = (plan_ir.DispatchScenario if op == "dispatch"
                   else plan_ir.CombineScenario)
            return cls(
                topo=topo,
                num_experts=scenario_kw.get("num_experts", 64),
                top_k=scenario_kw.get("top_k", 8),
                token_bytes=scenario_kw.get("token_bytes", 7168),
                skew=scenario_kw.get("skew", 0.0),
                compute_s=bucket_compute_s(
                    scenario_kw.get("compute_s", 0.0)))
        raise ValueError(f"unknown collective op {op!r}")

    # -- the decision --------------------------------------------------------
    def choose(self, op: str, payload_bytes: float, topo: Topology,
               hw: Optional[HardwareModel] = None, *,
               executable_only: bool = False, **scenario_kw) -> PlanDecision:
        """Pick the fastest registered plan for ``op`` at ``payload_bytes``.

        ``payload_bytes`` is the per-participant payload: the AllGather
        fragment size, or ``tokens_per_rank * token_bytes`` for dispatch.
        """
        hw = hw or self.hw
        bucket = bucket_payload(payload_bytes)
        scenario = self._scenario(op, topo, scenario_kw)
        # the hw FINGERPRINT (not the object) is part of the key: an
        # in-place ``planner.hw`` swap after recalibration can never
        # serve a decision scored under the old calibration, and two
        # value-equal models share entries.
        key = (op, topology_fingerprint(topo), bucket, hw.fingerprint(),
               executable_only, scenario.cache_key())
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            self._cache.move_to_end(key)
            return hit
        self.cache_misses += 1
        decision = self._sweep(op, scenario, bucket, hw, executable_only)
        self._cache[key] = decision
        self._log_decision(decision, topo.name)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return decision

    def _sweep(self, op: str, scenario, bucket: int, hw: HardwareModel,
               executable_only: bool) -> PlanDecision:
        plans = plan_ir.plans_for(op, executable_only=executable_only)
        if not plans:
            raise ValueError(f"no plans registered for op {op!r}")
        scored: list[tuple] = []        # (t, order, plan, knobs, ledger)
        for order, p in enumerate(plans):
            for knobs in p.knob_grid():
                ledger = p.simulate(scenario, bucket, **knobs)
                t = score_ledger(ledger, hw)
                scored.append((t, order, p, knobs, ledger))
        scored.sort(key=lambda s: (s[0], s[1]))
        best_t, _, best, best_knobs, best_ledger = scored[0]
        base_name = plan_ir.BASELINE_PLAN[op]
        # the baseline reference is the SERIAL (G == 1) baseline cell —
        # what a fixed-policy baseline deployment actually executes —
        # so speedup_pct keeps its meaning now that the grid also holds
        # pipelined baseline candidates
        base_t = min((t for t, _, p, kn, _ in scored
                      if p.name == base_name
                      and kn.get("microbatch", 1) == 1),
                     default=best_t)
        from .latency_model import overlap_endpoints
        serial_t, ideal_t = overlap_endpoints(best_ledger, hw)
        return PlanDecision(
            op=op, plan=best.name,
            knobs=tuple(sorted(best_knobs.items())),
            predicted_s=best_t, baseline_s=base_t, payload_bytes=bucket,
            shard_map_kwargs=best.shard_map_kwargs(**best_knobs),
            candidates=tuple((p.name, tuple(sorted(kn.items())), t)
                             for t, _, p, kn, _ in scored),
            predicted_serial_s=serial_t, predicted_ideal_s=ideal_t)


_DEFAULT: Optional[Planner] = None


def default_planner() -> Planner:
    """Process-wide planner the JAX layer consults at trace time."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Planner()
    return _DEFAULT


# ---------------------------------------------------------------------------
# high-level helpers consumed by the JAX / launch / benchmark layers
# ---------------------------------------------------------------------------

def _ep_topology(num_pods: int, ep_per_pod: int,
                 topo: Optional[Topology] = None) -> Topology:
    """Topology an EP mesh slice is planned on: an explicit fabric when
    given (``--fabric`` / ``ParallelContext.fabric``), else the
    mesh-derived §3.2 shape — pod == server (slow DCN axis),
    chips-per-pod == NPUs-per-server (fast ICI axis).  A single-pod mesh
    has no slow axis: it is planned on the all-ICI full mesh it actually
    is (where unicast and MultiWrite ledgers coincide and the tie-break
    keeps the relay-free unicast plan)."""
    if topo is not None:
        return topo
    if num_pods > 1:
        return tpu_pods(chips_per_pod=max(2, ep_per_pod), num_pods=num_pods)
    return full_mesh(max(2, ep_per_pod), link_bw=TPU_ICI_LINK_BW,
                     name="ici_full_mesh")


def moe_dispatch_decision(*, num_pods: int, ep_per_pod: int,
                          num_experts: int, top_k: int,
                          tokens_per_rank: int, token_bytes: int,
                          hw: Optional[HardwareModel] = None,
                          planner: Optional[Planner] = None,
                          topo: Optional[Topology] = None,
                          skew: float = 0.0,
                          compute_s: float = 0.0) -> PlanDecision:
    """Plan the MoE dispatch for one EP mesh slice (see
    :func:`_ep_topology` for the fabric the payload is scored on).
    The payload is the per-rank token traffic of one dispatch.
    ``skew > 0`` prices hot-expert (non-uniform) routing.
    ``compute_s > 0`` (the expert-FFN time of the full batch, see
    :func:`repro.core.latency_model.expert_compute_time_s`) enables the
    pipelined scoring mode — the ``microbatch`` knob can then win and
    the decision carries a G > 1 the MoE layer double-buffers."""
    planner = planner or default_planner()
    topo = _ep_topology(num_pods, ep_per_pod, topo)
    return planner.choose(
        "dispatch", float(tokens_per_rank) * token_bytes, topo, hw,
        num_experts=num_experts, top_k=top_k, token_bytes=token_bytes,
        skew=skew, compute_s=compute_s)


def moe_combine_decision(*, num_pods: int, ep_per_pod: int,
                         num_experts: int, top_k: int,
                         tokens_per_rank: int, token_bytes: int,
                         hw: Optional[HardwareModel] = None,
                         planner: Optional[Planner] = None,
                         topo: Optional[Topology] = None,
                         skew: float = 0.0,
                         compute_s: float = 0.0) -> PlanDecision:
    """Plan the MoE *combine* (return path) for one EP mesh slice —
    independent of the dispatch decision: the return path's redundancy is
    spread over the holders' rails (and may face asymmetric return
    bandwidth), so its crossover sits elsewhere.  ``compute_s`` is the
    overlap context (see :func:`moe_dispatch_decision`): the combine of
    chunk k-1 hides behind the expert FFN of chunk k."""
    planner = planner or default_planner()
    topo = _ep_topology(num_pods, ep_per_pod, topo)
    return planner.choose(
        "combine", float(tokens_per_rank) * token_bytes, topo, hw,
        num_experts=num_experts, top_k=top_k, token_bytes=token_bytes,
        skew=skew, compute_s=compute_s)


def emergent_crossover_bytes(topo: Topology,
                              hw: Optional[HardwareModel] = None,
                              lo: float = 64 * 2 ** 10,
                              hi: float = 64 * 2 ** 20,
                              planner: Optional[Planner] = None) -> float:
    """Smallest payload bucket where the planner stops choosing baseline
    (the emergent Fig 7 crossover).  Returns ``inf`` if baseline always
    wins in [lo, hi]."""
    planner = planner or default_planner()
    size = float(lo)
    while size <= hi:
        d = planner.choose("allgather", size, topo, hw)
        if d.plan != "baseline":
            return float(d.payload_bytes)
        size *= 2
    return math.inf


def emergent_flip_batch(op: str, topo: Topology,
                        token_bytes: int = 7168,
                        batches: tuple = (16, 32, 64, 128, 256, 512,
                                          1024, 2048, 4096),
                        hw: Optional[HardwareModel] = None,
                        planner: Optional[Planner] = None,
                        **scenario_kw) -> float:
    """Smallest per-rank token batch where the planner stops choosing the
    baseline plan for ``op`` ("dispatch"/"combine") — the Fig 8 flip
    point as an emergent quantity.  ``inf`` if the baseline always wins
    over ``batches`` (e.g. on a full mesh with no slow axis)."""
    planner = planner or default_planner()
    base = plan_ir.BASELINE_PLAN[op]
    for batch in batches:
        d = planner.choose(op, float(batch) * token_bytes, topo, hw,
                           token_bytes=token_bytes, **scenario_kw)
        if d.plan != base:
            return float(batch)
    return math.inf
