"""The MultiWrite semantic: recursive multi-destination one-sided write.

Faithful implementation of paper §4.3:

    MultiWrite(S, M, B_S) with M = {(D_1, B_1) ... (D_n, B_n)} atomically
    writes buffer B_S of node S to buffer B_i at every destination D_i.

Execution model (§4.3.3), identical logic at every node:
  1. a node receives a MultiWrite targeting destination set M;
  2. if |M| == 1 → degenerate to a standard write;
  3. if |M| > 1  → partition M into subsets by next-hop relay (from the
     *unicast* forwarding table, §4.1) and issue one child MultiWrite per
     subset, with the bitmap metadata rewritten to that subset.

This module provides :class:`MultiWriteSimulator`, a packet-level executor
over a :class:`~repro.core.topology.Topology` that

- maintains per-node memories (dict buffers) so semantic properties
  (per-destination atomicity, exactly-once delivery, statelessness) are
  directly testable;
- keeps a per-link **byte ledger** — the quantity the whole paper is about:
  redundant bytes on bottleneck links.  The ledger feeds
  ``latency_model.py``.

The simulator is intentionally pure-python/NumPy: it is the semantic oracle
against which the JAX ``shard_map`` collectives (collectives.py) and the
Pallas dispatch kernels are validated.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Mapping, Sequence

import numpy as np

from . import bitmap as bm
from .topology import Topology


@dataclasses.dataclass(frozen=True)
class WriteRecord:
    """One hop of one (Multi)Write packet, for the ledger/trace."""

    src: int
    dst: int
    nbytes: int
    dest_bitmap: int      # metadata carried on this hop (post-rewrite)
    step: int             # schedule step the packet belongs to
    is_multiwrite: bool   # |M| > 1 on this hop


class DeliveryError(AssertionError):
    pass


class MultiWriteSimulator:
    """Packet-level executor for write / multiwrite over a Topology."""

    def __init__(self, topo: Topology) -> None:
        self.topo = topo
        # node -> buffer name -> np.ndarray
        self.memory: list[dict[str, np.ndarray]] = [
            {} for _ in range(topo.num_nodes)]
        self.trace: list[WriteRecord] = []
        # (src,dst) -> bytes carried, and same restricted to distinct payloads
        self.link_bytes: dict[tuple[int, int], int] = defaultdict(int)
        self._payload_seen: dict[tuple[int, int], set[bytes]] = defaultdict(set)
        self.link_unique_bytes: dict[tuple[int, int], int] = defaultdict(int)
        self.delivery_count: dict[tuple[int, str], int] = defaultdict(int)
        # node -> bytes moved through it as a relay (rx + tx of forwarded
        # traffic) — drives the AICPU-style relay processing cost (§6.4).
        self.relay_bytes: dict[int, int] = defaultdict(int)
        # tx-only component of relay_bytes: what the relay's forwarding
        # engine serializes onto egress links (§6.4 data plane) — plans
        # whose relays forward in software charge this separately.
        self.relay_tx_bytes: dict[int, int] = defaultdict(int)
        self.max_hops = 0

    # -- the standard write (baseline primitive) ----------------------------
    def write(self, src: int, dst: int, buf_name: str, data: np.ndarray,
              step: int = 0, *, _meta: int | None = None,
              _mw: bool = False) -> None:
        """One-sided unicast write src -> dst following the forwarding table.

        Multi-hop routes inject the payload on every traversed link (that is
        what store-and-forward relaying costs — and what the ledger must
        see).
        """
        data = np.asarray(data)
        nbytes = int(data.nbytes)
        meta = bm.encode([dst], self.topo.num_nodes) if _meta is None else _meta
        path = self.topo.path(src, dst)
        self.max_hops = max(self.max_hops, len(path) - 1)
        for a, b in zip(path[:-1], path[1:]):
            self._account(a, b, data, nbytes, meta, step, _mw)
        for mid in path[1:-1]:  # store-and-forward relays on multi-hop routes
            self.relay_bytes[mid] += 2 * nbytes
            self.relay_tx_bytes[mid] += nbytes
        self._deliver(dst, buf_name, data)

    # -- MultiWrite (§4.3) ---------------------------------------------------
    def multiwrite(self, src: int, dests: Mapping[int, str] | Sequence[tuple[int, str]],
                   data: np.ndarray, step: int = 0,
                   relay: int | None = None) -> None:
        """MultiWrite(S, M, B_S).

        Args:
          src: source node S.
          dests: destination-memory pairs M — mapping node -> buffer name.
          data: source buffer content B_S.
          step: schedule step tag for the ledger.
          relay: optional explicit first hop (schedule-level path selection,
            as used by the paired-relaying AllGather §3.1/§5.2).  The
            recursion below the first hop always follows the plain unicast
            forwarding table — same code at every node (§4.3.3).
        """
        data = np.asarray(data)
        pairs = dict(dests).items() if isinstance(dests, Mapping) else list(dests)
        m = {int(d): str(buf) for d, buf in pairs}
        if not m:
            return
        if relay is not None and relay != src:
            meta = bm.encode(m.keys(), self.topo.num_nodes)
            nbytes = int(data.nbytes)
            # The hint names the relay, not the route: on fabrics without a
            # direct src->relay link (e.g. cross-server non-rail peers) the
            # packet follows the unicast forwarding table to the relay,
            # paying store-and-forward at every intermediate node.
            hop_path = self.topo.path(src, relay)
            self.max_hops = max(self.max_hops, len(hop_path) - 1)
            for a, b in zip(hop_path[:-1], hop_path[1:]):
                self._account(a, b, data, nbytes, meta, step, len(m) > 1)
            for mid in hop_path[1:-1]:
                self.relay_bytes[mid] += 2 * nbytes
                self.relay_tx_bytes[mid] += nbytes
            if set(m) != {relay}:
                self.relay_bytes[relay] += nbytes  # rx at relay
            self._recurse(relay, m, data, step, origin=src)
        else:
            self._recurse(src, m, data, step, origin=src)

    def _recurse(self, node: int, m: dict[int, str], data: np.ndarray,
                 step: int, origin: int) -> None:
        nbytes = int(data.nbytes)
        # Rule 2: degenerate to a standard write.
        if len(m) == 1:
            ((dst, buf),) = m.items()
            if dst == node:
                self._deliver(dst, buf, data)
            else:
                if node != origin:
                    self.relay_bytes[node] += nbytes  # tx of forwarded data
                    self.relay_tx_bytes[node] += nbytes
                self.write(node, dst, buf, data, step,
                           _meta=bm.encode([dst], self.topo.num_nodes),
                           _mw=False)
            return
        # Rule 3: partition by next hop; one child MultiWrite per subset,
        # metadata rewritten to the subset (§4.1 "update of in-packet
        # metadata at relay nodes").
        groups = self.topo.partition_by_next_hop(node, list(m.keys()))
        for hop, subset in sorted(groups.items()):
            sub = {d: m[d] for d in subset}
            if hop == node:
                # local delivery for ourselves if we are a destination
                for d, buf in sub.items():
                    self._deliver(d, buf, data)
                continue
            meta = bm.encode(sub.keys(), self.topo.num_nodes)
            self._account(node, hop, data, nbytes, meta, step,
                          len(sub) > 1)
            if node != origin:
                self.relay_bytes[node] += nbytes  # tx of forwarded data
                self.relay_tx_bytes[node] += nbytes
            if len(sub) == 1 and hop in sub:
                self._deliver(hop, sub[hop], data)
            else:
                # the relay re-executes the same three rules (statelessness:
                # everything it needs is in (meta, payload)) and first
                # receives the payload into its relay buffer.
                self.relay_bytes[hop] += nbytes  # rx at next relay
                self._recurse(hop, sub, data, step, origin=origin)

    # -- internals -----------------------------------------------------------
    def _account(self, a: int, b: int, data: np.ndarray, nbytes: int,
                 meta: int, step: int, is_mw: bool) -> None:
        if not self.topo.has_link(a, b):
            raise ValueError(f"packet on nonexistent link {a}->{b}")
        nbytes_wire = nbytes + bm.metadata_bytes(self.topo.num_nodes)
        self.link_bytes[(a, b)] += nbytes_wire
        key = data.tobytes()
        if key not in self._payload_seen[(a, b)]:
            self._payload_seen[(a, b)].add(key)
            self.link_unique_bytes[(a, b)] += nbytes_wire
        self.trace.append(WriteRecord(a, b, nbytes_wire, meta, step, is_mw))

    def _deliver(self, node: int, buf: str, data: np.ndarray) -> None:
        self.delivery_count[(node, buf)] += 1
        if self.delivery_count[(node, buf)] > 1:
            prev = self.memory[node][buf]
            if not np.array_equal(prev, data):
                raise DeliveryError(
                    f"conflicting duplicate delivery at node {node} buf {buf}")
        # per-destination atomicity: the whole buffer lands at once.
        self.memory[node][buf] = np.array(data, copy=True)

    # -- ledger views ---------------------------------------------------------
    def redundant_bytes(self) -> dict[tuple[int, int], int]:
        """Per-link duplicate payload bytes (total - unique): the quantity
        MultiWrite exists to eliminate."""
        return {k: self.link_bytes[k] - self.link_unique_bytes.get(k, 0)
                for k in self.link_bytes}

    def bytes_crossing(self, pred) -> int:
        """Total bytes on links selected by ``pred(src,dst) -> bool``."""
        return sum(v for (a, b), v in self.link_bytes.items() if pred(a, b))

    def reset_ledger(self) -> None:
        self.trace.clear()
        self.link_bytes.clear()
        self.link_unique_bytes.clear()
        self._payload_seen.clear()
        self.delivery_count.clear()
        self.max_hops = 0
