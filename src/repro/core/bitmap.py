"""Fixed-width rank-bitmap destination encoding (paper §4.1).

The paper replaces multicast group IDs with a fixed-size bitmap carried in
each packet: bit ``i`` set ⇔ rank ``i`` is a destination.  A 64-bit field
covers domains up to 64 ranks; larger domains spill extra words into the
payload (paper §6.4: 1024 ranks cost 128 bytes ≈ 3.13% of a 4 KiB payload).

Two implementations live here:

- plain-python helpers used by the simulator / schedules (arbitrary width,
  int-backed);
- jnp helpers operating on ``uint32`` word arrays, used by the MoE router
  and by the Pallas ``dispatch_pack`` kernel (TPU has no native uint64
  lanes, so the packed representation is little-endian uint32 words).
"""

from __future__ import annotations

from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


# ---------------------------------------------------------------------------
# Python-side (simulator)
# ---------------------------------------------------------------------------

def encode(dests: Iterable[int], num_ranks: int) -> int:
    """Encode a destination set as an int bitmap (bit i == rank i)."""
    bm = 0
    for d in dests:
        if not 0 <= d < num_ranks:
            raise ValueError(f"rank {d} out of range [0,{num_ranks})")
        bm |= 1 << d
    return bm


def decode(bitmap: int, num_ranks: int) -> list[int]:
    """Decode an int bitmap into a sorted destination list."""
    if bitmap < 0 or bitmap >> num_ranks:
        raise ValueError(f"bitmap {bitmap:#x} has bits >= {num_ranks}")
    return [i for i in range(num_ranks) if (bitmap >> i) & 1]


def popcount(bitmap: int) -> int:
    return bin(bitmap).count("1")


def subset_mask(dests: Sequence[int]) -> int:
    return encode(dests, max(dests) + 1 if dests else 1)


def metadata_bytes(num_ranks: int) -> int:
    """Header/payload overhead of the bitmap in bytes (§6.4).

    Domains <= 64 ranks ride in the write_with_immediate field: 0 extra
    bytes on the wire.  Larger domains embed ceil(num_ranks/8) bytes in the
    payload.
    """
    if num_ranks <= 64:
        return 0
    return (num_ranks + 7) // 8


# ---------------------------------------------------------------------------
# jnp-side (router / kernels): bitmaps as little-endian uint32 word arrays
# ---------------------------------------------------------------------------

def num_words(num_ranks: int) -> int:
    return (num_ranks + WORD_BITS - 1) // WORD_BITS


def encode_onehot(onehot, num_ranks: int):
    """Pack a boolean destination matrix into uint32 bitmap words.

    Args:
      onehot: bool/int array ``[..., num_ranks]``; nonzero ⇔ destination.
      num_ranks: domain size.

    Returns:
      uint32 array ``[..., num_words(num_ranks)]``.
    """
    w = num_words(num_ranks)
    pad = w * WORD_BITS - num_ranks
    oh = jnp.asarray(onehot, dtype=jnp.uint32)
    if pad:
        pad_shape = oh.shape[:-1] + (pad,)
        oh = jnp.concatenate([oh, jnp.zeros(pad_shape, jnp.uint32)], axis=-1)
    oh = oh.reshape(oh.shape[:-1] + (w, WORD_BITS))
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(oh << shifts, axis=-1, dtype=jnp.uint32)


def decode_onehot(words, num_ranks: int):
    """Unpack uint32 bitmap words into a boolean matrix ``[..., num_ranks]``."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))
    return flat[..., :num_ranks].astype(jnp.bool_)


def popcount_words(words):
    """Number of set bits per bitmap (sum over words)."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., :, None] >> shifts) & jnp.uint32(1)
    return jnp.sum(bits, axis=(-1, -2)).astype(jnp.int32)


def mask_range(words, lo: int, hi: int, num_ranks: int):
    """Zero all bits outside [lo, hi) — the relay's metadata rewrite (§4.1):
    after forwarding to a next hop responsible for ranks [lo,hi), the
    remaining metadata keeps only that slice so downstream nodes do not
    re-replicate (avoids duplicate delivery / routing loops)."""
    oh = decode_onehot(words, num_ranks)
    ranks = jnp.arange(num_ranks)
    keep = (ranks >= lo) & (ranks < hi)
    return encode_onehot(oh & keep, num_ranks)


def np_encode_rows(onehot: np.ndarray, num_ranks: int) -> np.ndarray:
    """NumPy twin of :func:`encode_onehot` for test oracles."""
    w = num_words(num_ranks)
    out = np.zeros(onehot.shape[:-1] + (w,), dtype=np.uint32)
    for r in range(num_ranks):
        word, bit = divmod(r, WORD_BITS)
        out[..., word] |= (onehot[..., r].astype(np.uint32) << np.uint32(bit))
    return out
