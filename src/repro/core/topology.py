"""Topology models for MultiWrite routing and latency analysis.

A :class:`Topology` is a directed multigraph of nodes (accelerators) and
links, each link with a bandwidth (bytes/s).  It provides the *unicast
forwarding table* that MultiWrite reuses (paper §4.1: "we fully reuse the
unicast forwarding table that each node already employs").

Three concrete constructors cover the paper's scenarios plus the TPU target:

- :func:`full_mesh`          — paper §3.1 (8-NPU HCCS full mesh, 56 GB/s links)
- :func:`two_server_cluster` — paper §3.2 / §6.1 (2 servers x 8 NPUs; HCCS
                               intra-server full mesh + oversubscribed,
                               rail-optimized inter-server links)
- :func:`tpu_pods`           — TPU adaptation: pods of chips with fast
                               intra-pod ICI and slow inter-pod DCN, used by
                               the collective layer's cost accounting.

All bandwidths are bytes/second.  Latency modelling lives in
``latency_model.py``; this module is purely structural.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Iterable, Mapping, Sequence


# ---------------------------------------------------------------------------
# Hardware constants (paper §6.1 + prompt-supplied TPU v5e numbers)
# ---------------------------------------------------------------------------
HCCS_LINK_BW = 56e9          # bytes/s, Huawei Cache Coherence System per link
ROCE_LINK_BW = 200e9 / 8     # 200 Gbps RoCE NIC -> 25 GB/s
TPU_ICI_LINK_BW = 50e9       # bytes/s per ICI link (prompt constant)
TPU_DCN_LINK_BW = 6.25e9     # bytes/s per chip inter-pod (50 Gbps class DCN)
TPU_PEAK_FLOPS = 197e12      # bf16 per chip
TPU_HBM_BW = 819e9           # bytes/s per chip


@dataclasses.dataclass(frozen=True)
class Link:
    """A directed physical link ``src -> dst`` with bandwidth ``bw`` bytes/s."""

    src: int
    dst: int
    bw: float

    @property
    def key(self) -> tuple[int, int]:
        return (self.src, self.dst)


class Topology:
    """Directed graph of nodes + links with unicast forwarding tables.

    Forwarding tables are computed by bandwidth-weighted shortest path
    (Dijkstra on 1/bw edge costs, hop count then node id as tie-breaks) and
    may be partially overridden by ``fwd_override`` — the paper's
    "preconfigured mapping rules" (§4.1).  ``next_hop(u, d)`` returns the
    neighbor ``u`` forwards to for destination ``d`` — exactly the lookup
    MultiWrite relays perform.
    """

    def __init__(self, num_nodes: int, links: Iterable[Link],
                 name: str = "topology",
                 fwd_override: Mapping[tuple[int, int], int] | None = None,
                 ) -> None:
        self.name = name
        self.num_nodes = int(num_nodes)
        self.links: dict[tuple[int, int], Link] = {}
        for ln in links:
            if not (0 <= ln.src < num_nodes and 0 <= ln.dst < num_nodes):
                raise ValueError(f"link {ln} out of range for {num_nodes} nodes")
            if ln.src == ln.dst:
                raise ValueError(f"self-link {ln}")
            self.links[ln.key] = ln
        self._adj: dict[int, list[Link]] = {n: [] for n in range(num_nodes)}
        for ln in self.links.values():
            self._adj[ln.src].append(ln)
        self._fwd: dict[int, dict[int, int]] | None = None
        self._override = dict(fwd_override or {})
        for (src, dst), hop in self._override.items():
            if (src, hop) not in self.links:
                raise ValueError(
                    f"fwd_override ({src},{dst})->{hop}: no link {src}->{hop}")

    # -- structural queries -------------------------------------------------
    def neighbors(self, node: int) -> list[int]:
        return sorted(ln.dst for ln in self._adj[node])

    def link(self, src: int, dst: int) -> Link:
        return self.links[(src, dst)]

    def has_link(self, src: int, dst: int) -> bool:
        return (src, dst) in self.links

    # -- unicast forwarding table (reused by MultiWrite, §4.1) --------------
    def _build_forwarding(self) -> None:
        fwd: dict[int, dict[int, int]] = {}
        for src in range(self.num_nodes):
            dist: dict[int, tuple[float, int, int]] = {src: (0.0, 0, -1)}
            first_hop: dict[int, int] = {}
            pq: list[tuple[float, int, int, int, int]] = [(0.0, 0, -1, src, -1)]
            seen: set[int] = set()
            while pq:
                d, hops, fh_key, u, fh = heapq.heappop(pq)
                if u in seen:
                    continue
                seen.add(u)
                if u != src:
                    first_hop[u] = fh
                for ln in sorted(self._adj[u], key=lambda l: l.dst):
                    v = ln.dst
                    if v in seen:
                        continue
                    nfh = v if u == src else fh
                    cand = (d + 1.0 / ln.bw, hops + 1, nfh)
                    if v not in dist or cand < dist[v]:
                        dist[v] = cand
                        heapq.heappush(pq, (*cand, v, nfh))
            fwd[src] = first_hop
        self._fwd = fwd

    def next_hop(self, node: int, dest: int) -> int:
        """Unicast forwarding lookup: from ``node``, first hop toward ``dest``."""
        if node == dest:
            raise ValueError("next_hop queried for self")
        ov = self._override.get((node, dest))
        if ov is not None:
            return ov
        if self._fwd is None:
            self._build_forwarding()
        assert self._fwd is not None
        try:
            return self._fwd[node][dest]
        except KeyError as e:
            raise ValueError(f"no route {node} -> {dest} in {self.name}") from e

    def path(self, src: int, dst: int, max_hops: int = 64) -> list[int]:
        """Full unicast path src..dst (inclusive), following next_hop."""
        out = [src]
        cur = src
        for _ in range(max_hops):
            if cur == dst:
                return out
            cur = self.next_hop(cur, dst)
            out.append(cur)
        raise RuntimeError(f"routing loop {src}->{dst} in {self.name}: {out}")

    def partition_by_next_hop(self, node: int,
                              dests: Sequence[int]) -> dict[int, list[int]]:
        """Group a destination set by next hop (paper §4.3.3 rule 3).

        Destinations equal to ``node`` itself are grouped under ``node``
        (local delivery).  The number of distinct keys excluding ``node`` is
        the number of packet copies injected on ``node``'s egress links.
        """
        groups: dict[int, list[int]] = {}
        for d in dests:
            hop = node if d == node else self.next_hop(node, d)
            groups.setdefault(hop, []).append(d)
        return groups


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def full_mesh(num_nodes: int = 8, link_bw: float = HCCS_LINK_BW,
              name: str = "full_mesh") -> Topology:
    """Paper §3.1: every node pair has a dedicated bidirectional link."""
    links = [Link(a, b, link_bw)
             for a, b in itertools.permutations(range(num_nodes), 2)]
    return Topology(num_nodes, links, name=name)


def split_tp_full_mesh(num_nodes: int = 8, tp: int = 4,
                       link_bw: float = HCCS_LINK_BW,
                       ) -> tuple[Topology, list[list[int]]]:
    """Paper §3.1 experiment config: full mesh split into ``num_nodes//tp``
    TP domains.  Returns (topology, domains)."""
    topo = full_mesh(num_nodes, link_bw, name=f"full_mesh_tp{tp}")
    domains = [list(range(i, i + tp)) for i in range(0, num_nodes, tp)]
    return topo, domains


def two_server_cluster(npus_per_server: int = 8, num_servers: int = 2,
                       intra_bw: float = HCCS_LINK_BW,
                       inter_bw: float = ROCE_LINK_BW,
                       name: str = "two_server") -> Topology:
    """Paper §3.2/§6.1: full-mesh HCCS inside each server; rail-optimized
    inter-server RoCE (each NPU's NIC reaches only the same-index NPU on
    remote servers — the deployment shape the paper's "same-index NPU"
    relay language describes).

    Cross-server routes are overridden rail-first ("get onto the
    destination server via your own rail, then hop intra-server"), so that
    ``partition_by_next_hop`` at a source groups ALL destinations on a
    remote server under the single same-index peer — one rail crossing per
    MultiWrite, replication at the relay, exactly §3.2.  Plain unicast
    dispatch under the same table sends k copies of a token over the same
    rail, which is the redundant-bottleneck baseline of Table 1.
    """
    n = npus_per_server * num_servers
    links: list[Link] = []
    override: dict[tuple[int, int], int] = {}
    for s in range(num_servers):
        base = s * npus_per_server
        for a, b in itertools.permutations(range(npus_per_server), 2):
            links.append(Link(base + a, base + b, intra_bw))
    for sa in range(num_servers):
        for sb in range(num_servers):
            if sa == sb:
                continue
            for i in range(npus_per_server):
                src = sa * npus_per_server + i
                rail = sb * npus_per_server + i
                links.append(Link(src, rail, inter_bw))
                for j in range(npus_per_server):
                    dst = sb * npus_per_server + j
                    override[(src, dst)] = rail
    return Topology(n, links, name=name, fwd_override=override)


def tpu_pods(chips_per_pod: int = 16, num_pods: int = 2,
             ici_bw: float = TPU_ICI_LINK_BW,
             dcn_bw: float = TPU_DCN_LINK_BW,
             name: str = "tpu_pods") -> Topology:
    """TPU adaptation for the collective cost ledger.

    The intra-pod ICI torus is abstracted as a full mesh of per-chip logical
    paths at one ICI link bandwidth each (XLA pipelines ring collectives
    across the torus; per-link serialization is what the latency model
    accounts).  Inter-pod traffic is rail-optimized per chip over DCN at
    ``dcn_bw`` — the oversubscribed slow axis, the paper's §3.2 shape with
    pod ≡ server and DCN ≡ RoCE.
    """
    return two_server_cluster(npus_per_server=chips_per_pod,
                              num_servers=num_pods,
                              intra_bw=ici_bw, inter_bw=dcn_bw, name=name)


def server_of(node: int, npus_per_server: int = 8) -> int:
    return node // npus_per_server


def same_index_peer(node: int, dst_server: int,
                    npus_per_server: int = 8) -> int:
    """Rail (same-index) peer of ``node`` on ``dst_server`` (§3.2)."""
    return dst_server * npus_per_server + node % npus_per_server
