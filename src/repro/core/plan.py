"""Collective-plan IR: one uniform description of a collective scheme.

Before this module the repo had three disconnected descriptions of the
same collective — free-function simulator schedules (schedules.py),
closed-form latency entries (latency_model.ALLGATHER_LINK_LOAD) and
hard-coded shard_map kwargs at every JAX call site.  A
:class:`CollectivePlan` unifies them:

  * ``name`` / ``op``      — identity in the plan registry;
  * ``knobs``              — the declared tunables (``split``, ``mode``,
                             ``microbatch``) with candidate grids, seeded
                             by the §5.2 analytic optimum
                             (:func:`repro.core.schedules.optimal_split`);
  * ``simulate(scenario, payload_bytes, **knobs) -> Ledger``
                           — drives the :class:`MultiWriteSimulator`
                             packet oracle at a small probe size and
                             scales the per-link byte ledger to the real
                             payload (the ledger is linear in payload
                             bytes for every scheme in the paper);
  * ``shard_map_kwargs(**knobs)``
                           — what the JAX layer needs to execute the
                             winning plan (``mode=``/``split=`` for the
                             §3.1 AllGather, ``moe_scheme`` for §3.2
                             dispatch).

The registry is the extension point: a new topology or scheme in a later
PR is ONE ``register_plan`` call — the planner, the benchmarks and the
JAX layer pick it up without edits (the TACCL-style "synthesis from a
cost model" architecture, arXiv 2305.13479).

:class:`~repro.core.planner.Planner` sweeps registered plans x knob
grids and scores each ledger with the calibrated latency model.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Iterator, Mapping, Sequence

from .multiwrite import MultiWriteSimulator
from .topology import Topology


# ---------------------------------------------------------------------------
# Ledger: the scored artifact of a simulated plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ledger:
    """Per-link / per-relay byte accounting for one executed plan.

    ``link_bytes``   (src, dst) -> bytes carried (incl. §4.1 metadata).
    ``relay_bytes``  node -> rx+tx bytes moved as a relay (§6.4 AICPU
                     copy/forward cost).
    ``flow_counts``  (src, dst) -> distinct concurrent flows (drives the
                     unicast-multipath interference derate).
    ``stages``       schedule chunks (microbatching = ``stages`` chunks),
                     each paying the operator startup alpha.
    ``overlap``      chunks are SOFTWARE-PIPELINED (dispatch of chunk k+1
                     overlaps compute of chunk k and combine of chunk
                     k-1): scoring pays ``max(stage) + (G-1)*bottleneck``
                     derated by the calibrated overlap efficiency instead
                     of the serial ``G*sum`` — the Fig 8 relay-pipeline
                     idea applied across whole chunks.  False = the
                     chunks serialize (the pre-pipeline ``lax.map`` loop).
    ``compute_s``    per-full-payload compute time (expert FFN) the
                     pipelined network chunks hide behind — the stage
                     BETWEEN dispatch and combine.  Charged to serial
                     scores too so G==1 and G>1 compare apples-to-apples.
    ``relayed``      whether any relay stage exists (pays ``alpha_hop``).
    ``alpha_extra_s``  schedule-specific fixed setup beyond the generic
                     alphas (the Fig 8 relay pipeline establishment).
    ``engine_serial``  node -> egress bytes that serialize through ONE
                     forwarding engine (§6.4 AICPU software relay).
                     Populated only by plans whose relays forward in
                     software (MoE dispatch); hardware-parallel relays
                     (§3.1 paired relaying over distinct links) leave it
                     empty.  Scored at the node's fastest egress link.
    """

    topo: Topology
    link_bytes: Mapping[tuple[int, int], float]
    relay_bytes: Mapping[int, float]
    flow_counts: Mapping[tuple[int, int], int]
    stages: int = 1
    overlap: bool = False
    compute_s: float = 0.0
    relayed: bool = False
    alpha_extra_s: float = 0.0
    engine_serial: Mapping[int, float] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def from_sim(cls, sim: MultiWriteSimulator, stages: int = 1,
                 alpha_extra_s: float = 0.0) -> "Ledger":
        flows: dict[tuple[int, int], set[int]] = {}
        for rec in sim.trace:
            flows.setdefault((rec.src, rec.dst), set()).add(rec.dest_bitmap)
        return cls(topo=sim.topo,
                   link_bytes=dict(sim.link_bytes),
                   relay_bytes=dict(sim.relay_bytes),
                   flow_counts={k: len(v) for k, v in flows.items()},
                   stages=stages,
                   relayed=bool(sim.relay_bytes),
                   alpha_extra_s=alpha_extra_s)

    def scaled(self, factor: float) -> "Ledger":
        """Ledger for a payload ``factor`` x larger (bytes are linear in
        payload size; flow structure is size-independent)."""
        if factor == 1.0:
            return self
        return dataclasses.replace(
            self,
            link_bytes={k: v * factor for k, v in self.link_bytes.items()},
            relay_bytes={k: v * factor for k, v in self.relay_bytes.items()},
            engine_serial={k: v * factor
                           for k, v in self.engine_serial.items()})

    @property
    def bottleneck_link(self) -> tuple[tuple[int, int], float]:
        key = max(self.link_bytes,
                  key=lambda k: self.link_bytes[k] / self.topo.link(*k).bw)
        return key, self.link_bytes[key]

    def total_bytes(self) -> float:
        return float(sum(self.link_bytes.values()))


# ---------------------------------------------------------------------------
# Scenarios: the static context a plan runs against
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AllGatherScenario:
    """§3.1 split-TP AllGather: ``domains`` partition ``topo``'s nodes."""

    topo: Topology
    domains: tuple[tuple[int, ...], ...]

    @classmethod
    def split_tp(cls, topo: Topology,
                 num_domains: int = 2) -> "AllGatherScenario":
        n = topo.num_nodes
        tp = n // num_domains
        doms = tuple(tuple(range(i, i + tp)) for i in range(0, n, tp))
        return cls(topo=topo, domains=doms)

    def cache_key(self):
        return ("allgather", self.domains)


@dataclasses.dataclass(frozen=True)
class DispatchScenario:
    """§3.2 MoE AlltoAll dispatch over an oversubscribed cluster.

    ``skew`` prices non-uniform (hot-expert) routing: 0 = balanced
    (paper §6.1 "expert load balancing is enabled"); larger values draw
    expert choices from a Zipf-like popularity law, concentrating
    traffic on the hot experts' owners — the imbalanced-MoE regime the
    planner must price for production routers.

    ``compute_s`` is the overlap context: the expert-FFN time (for the
    FULL payload) a chunked dispatch can hide behind.  0 = score the
    dispatch in isolation (the pre-overlap model — ``microbatch > 1``
    can then never win and the planner keeps G == 1)."""

    topo: Topology
    num_experts: int = 64
    top_k: int = 8
    token_bytes: int = 7168
    seed: int = 0
    skew: float = 0.0
    compute_s: float = 0.0

    def cache_key(self):
        return ("dispatch", self.num_experts, self.top_k, self.token_bytes,
                self.skew, self.compute_s)


@dataclasses.dataclass(frozen=True)
class CombineScenario:
    """Return path of the MoE AlltoAll: expert partials travel back to the
    token owners (the dual of :class:`DispatchScenario`).  The paper plans
    only the dispatch half; combine is a first-class op here because the
    return path hits the same physical bottleneck — or, on asymmetric
    fabrics, a *different* one."""

    topo: Topology
    num_experts: int = 64
    top_k: int = 8
    token_bytes: int = 7168
    seed: int = 0
    skew: float = 0.0          # hot-expert routing skew (see DispatchScenario)
    compute_s: float = 0.0     # overlap context (see DispatchScenario)

    def cache_key(self):
        return ("combine", self.num_experts, self.top_k, self.token_bytes,
                self.skew, self.compute_s)


def default_scenarios(topo: Topology) -> dict:
    """One representative scenario per op for ``topo`` — the grid the CI
    fabric smoke iterates (every registered plan must simulate on every
    registered fabric without raising)."""
    return {"allgather": AllGatherScenario.split_tp(topo, 2),
            "dispatch": DispatchScenario(topo=topo),
            "combine": CombineScenario(topo=topo)}


# ---------------------------------------------------------------------------
# The plan IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """One registered collective scheme with declared knobs.

    ``simulate_fn(scenario, payload_bytes, **knobs) -> Ledger`` is the
    semantic oracle; ``kwargs_fn(**knobs)`` produces the JAX-layer kwargs
    of the winning configuration.  ``executable`` marks plans that have a
    shard_map lowering (unicast multipath exists only as a paper
    comparison point, so the planner excludes it when asked for an
    executable choice).
    """

    name: str
    op: str                            # "allgather" | "dispatch" | "combine"
    knobs: Mapping[str, tuple]                # knob -> candidate grid
    simulate_fn: Callable[..., Ledger]
    kwargs_fn: Callable[..., dict] = lambda **kw: dict(kw)
    executable: bool = True

    def knob_grid(self) -> Iterator[dict]:
        if not self.knobs:
            yield {}
            return
        names = sorted(self.knobs)
        for combo in itertools.product(*(self.knobs[k] for k in names)):
            yield dict(zip(names, combo))

    def default_knobs(self) -> dict:
        return {k: v[0] for k, v in self.knobs.items()}

    def simulate(self, scenario, payload_bytes: float, **knobs) -> Ledger:
        kn = {**self.default_knobs(), **knobs}
        return self.simulate_fn(scenario, float(payload_bytes), **kn)

    def shard_map_kwargs(self, **knobs) -> dict:
        kn = {**self.default_knobs(), **knobs}
        return self.kwargs_fn(**kn)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PLAN_REGISTRY: dict[tuple[str, str], CollectivePlan] = {}
BASELINE_PLAN = {"allgather": "baseline", "dispatch": "unicast",
                 "combine": "unicast"}


def register_plan(plan: CollectivePlan) -> CollectivePlan:
    key = (plan.op, plan.name)
    PLAN_REGISTRY[key] = plan
    return plan


def get_plan(op: str, name: str) -> CollectivePlan:
    try:
        return PLAN_REGISTRY[(op, name)]
    except KeyError:
        raise KeyError(
            f"no plan {name!r} registered for op {op!r}; have "
            f"{sorted(n for o, n in PLAN_REGISTRY if o == op)}") from None


def plans_for(op: str, executable_only: bool = False
              ) -> list[CollectivePlan]:
    """Registered plans for ``op`` in registration order."""
    out = [p for (o, _), p in PLAN_REGISTRY.items() if o == op]
    if executable_only:
        out = [p for p in out if p.executable]
    return out


# ---------------------------------------------------------------------------
# probe-size helpers shared by plan implementations
# ---------------------------------------------------------------------------

PROBE_FRAG_BYTES = 1 << 14        # AllGather probe fragment (16 KiB)
PROBE_TOKEN_BYTES = 128           # dispatch probe token payload
PROBE_BATCH = 32                  # dispatch probe tokens per NPU


def probe_scale(payload_bytes: float, probe_bytes: float) -> float:
    return float(payload_bytes) / float(probe_bytes) if probe_bytes else 1.0
