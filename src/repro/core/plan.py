"""Collective-plan IR: one uniform description of a collective scheme.

Before this module the repo had three disconnected descriptions of the
same collective — free-function simulator schedules (schedules.py),
closed-form latency entries (latency_model.ALLGATHER_LINK_LOAD) and
hard-coded shard_map kwargs at every JAX call site.  A
:class:`CollectivePlan` unifies them:

  * ``name`` / ``op``      — identity in the plan registry;
  * ``knobs``              — the declared tunables (``split``, ``mode``,
                             ``microbatch``) with candidate grids, seeded
                             by the §5.2 analytic optimum
                             (:func:`repro.core.schedules.optimal_split`);
  * ``simulate(scenario, payload_bytes, **knobs) -> Ledger``
                           — drives the :class:`MultiWriteSimulator`
                             packet oracle at a small probe size and
                             scales the per-link byte ledger to the real
                             payload (the ledger is linear in payload
                             bytes for every scheme in the paper);
  * ``shard_map_kwargs(**knobs)``
                           — what the JAX layer needs to execute the
                             winning plan (``mode=``/``split=`` for the
                             §3.1 AllGather, ``moe_scheme`` for §3.2
                             dispatch).

The registry is the extension point: a new topology or scheme in a later
PR is ONE ``register_plan`` call — the planner, the benchmarks and the
JAX layer pick it up without edits (the TACCL-style "synthesis from a
cost model" architecture, arXiv 2305.13479).

:class:`~repro.core.planner.Planner` sweeps registered plans x knob
grids and scores each ledger with the calibrated latency model.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
from typing import Callable, Iterator, Mapping, Optional, Sequence

from .multiwrite import MultiWriteSimulator
from .topology import Topology


# ---------------------------------------------------------------------------
# bucketing helpers (shared by the planner's LRU keys and the declarative
# CollectiveSite keys, so a bound ExecutionPlan and a trace-time lookup
# can never disagree about which cell a payload falls into)
# ---------------------------------------------------------------------------

def bucket_payload(payload_bytes: float) -> int:
    """Power-of-two payload bucket: plan choice is scored at the bucket
    size, so nearby payloads share one cache entry."""
    if payload_bytes <= 1:
        return 1
    return 1 << int(math.ceil(math.log2(float(payload_bytes))))


def batch_bucket(batch: int) -> int:
    """Power-of-two decode-batch bucket — the serving tier's admission
    granularity.  Batch-bucket plans are planned and prefetched at these
    sizes, so growing the decode batch WITHIN a bucket never re-plans
    and growing it ACROSS a bucket boundary is a staged
    ``PlanBinder`` pointer flip rather than a cold retrace."""
    if batch <= 1:
        return 1
    return 1 << int(math.ceil(math.log2(float(batch))))


def bucket_compute_s(compute_s: float) -> float:
    """Power-of-two bucket (in nanoseconds) for the overlap-context
    compute time, mirroring :func:`bucket_payload`: nearby compute
    estimates share one scenario cache entry instead of fragmenting the
    LRU per traced dtype/shape.  Rounded to the NEAREST power of two in
    log space (not up): the bucketed value is baked into the decision's
    serial/ideal endpoints that fit_overlap_eff measures against, and a
    systematically inflated compute stage would bias the fitted
    efficiency upward."""
    if compute_s <= 0:
        return 0.0
    return float(2.0 ** round(math.log2(compute_s * 1e9))) / 1e9


# ---------------------------------------------------------------------------
# Ledger: the scored artifact of a simulated plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ledger:
    """Per-link / per-relay byte accounting for one executed plan.

    ``link_bytes``   (src, dst) -> bytes carried (incl. §4.1 metadata).
    ``relay_bytes``  node -> rx+tx bytes moved as a relay (§6.4 AICPU
                     copy/forward cost).
    ``flow_counts``  (src, dst) -> distinct concurrent flows (drives the
                     unicast-multipath interference derate).
    ``stages``       schedule chunks (microbatching = ``stages`` chunks),
                     each paying the operator startup alpha.
    ``overlap``      chunks are SOFTWARE-PIPELINED (dispatch of chunk k+1
                     overlaps compute of chunk k and combine of chunk
                     k-1): scoring pays ``max(stage) + (G-1)*bottleneck``
                     derated by the calibrated overlap efficiency instead
                     of the serial ``G*sum`` — the Fig 8 relay-pipeline
                     idea applied across whole chunks.  False = the
                     chunks serialize (the pre-pipeline ``lax.map`` loop).
    ``compute_s``    per-full-payload compute time (expert FFN) the
                     pipelined network chunks hide behind — the stage
                     BETWEEN dispatch and combine.  Charged to serial
                     scores too so G==1 and G>1 compare apples-to-apples.
    ``relayed``      whether any relay stage exists (pays ``alpha_hop``).
    ``alpha_extra_s``  schedule-specific fixed setup beyond the generic
                     alphas (the Fig 8 relay pipeline establishment).
    ``engine_serial``  node -> egress bytes that serialize through ONE
                     forwarding engine (§6.4 AICPU software relay).
                     Populated only by plans whose relays forward in
                     software (MoE dispatch); hardware-parallel relays
                     (§3.1 paired relaying over distinct links) leave it
                     empty.  Scored at the node's fastest egress link.
    """

    topo: Topology
    link_bytes: Mapping[tuple[int, int], float]
    relay_bytes: Mapping[int, float]
    flow_counts: Mapping[tuple[int, int], int]
    stages: int = 1
    overlap: bool = False
    compute_s: float = 0.0
    relayed: bool = False
    alpha_extra_s: float = 0.0
    engine_serial: Mapping[int, float] = dataclasses.field(
        default_factory=dict)

    @classmethod
    def from_sim(cls, sim: MultiWriteSimulator, stages: int = 1,
                 alpha_extra_s: float = 0.0) -> "Ledger":
        flows: dict[tuple[int, int], set[int]] = {}
        for rec in sim.trace:
            flows.setdefault((rec.src, rec.dst), set()).add(rec.dest_bitmap)
        return cls(topo=sim.topo,
                   link_bytes=dict(sim.link_bytes),
                   relay_bytes=dict(sim.relay_bytes),
                   flow_counts={k: len(v) for k, v in flows.items()},
                   stages=stages,
                   relayed=bool(sim.relay_bytes),
                   alpha_extra_s=alpha_extra_s)

    def scaled(self, factor: float) -> "Ledger":
        """Ledger for a payload ``factor`` x larger (bytes are linear in
        payload size; flow structure is size-independent)."""
        if factor == 1.0:
            return self
        return dataclasses.replace(
            self,
            link_bytes={k: v * factor for k, v in self.link_bytes.items()},
            relay_bytes={k: v * factor for k, v in self.relay_bytes.items()},
            engine_serial={k: v * factor
                           for k, v in self.engine_serial.items()})

    @property
    def bottleneck_link(self) -> tuple[tuple[int, int], float]:
        key = max(self.link_bytes,
                  key=lambda k: self.link_bytes[k] / self.topo.link(*k).bw)
        return key, self.link_bytes[key]

    def total_bytes(self) -> float:
        return float(sum(self.link_bytes.values()))


# ---------------------------------------------------------------------------
# Scenarios: the static context a plan runs against
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AllGatherScenario:
    """§3.1 split-TP AllGather: ``domains`` partition ``topo``'s nodes."""

    topo: Topology
    domains: tuple[tuple[int, ...], ...]

    @classmethod
    def split_tp(cls, topo: Topology,
                 num_domains: int = 2) -> "AllGatherScenario":
        n = topo.num_nodes
        tp = n // num_domains
        doms = tuple(tuple(range(i, i + tp)) for i in range(0, n, tp))
        return cls(topo=topo, domains=doms)

    def cache_key(self):
        return ("allgather", self.domains)


@dataclasses.dataclass(frozen=True)
class DispatchScenario:
    """§3.2 MoE AlltoAll dispatch over an oversubscribed cluster.

    ``skew`` prices non-uniform (hot-expert) routing: 0 = balanced
    (paper §6.1 "expert load balancing is enabled"); larger values draw
    expert choices from a Zipf-like popularity law, concentrating
    traffic on the hot experts' owners — the imbalanced-MoE regime the
    planner must price for production routers.

    ``compute_s`` is the overlap context: the expert-FFN time (for the
    FULL payload) a chunked dispatch can hide behind.  0 = score the
    dispatch in isolation (the pre-overlap model — ``microbatch > 1``
    can then never win and the planner keeps G == 1)."""

    topo: Topology
    num_experts: int = 64
    top_k: int = 8
    token_bytes: int = 7168
    seed: int = 0
    skew: float = 0.0
    compute_s: float = 0.0

    def cache_key(self):
        return ("dispatch", self.num_experts, self.top_k, self.token_bytes,
                self.skew, self.compute_s)


@dataclasses.dataclass(frozen=True)
class CombineScenario:
    """Return path of the MoE AlltoAll: expert partials travel back to the
    token owners (the dual of :class:`DispatchScenario`).  The paper plans
    only the dispatch half; combine is a first-class op here because the
    return path hits the same physical bottleneck — or, on asymmetric
    fabrics, a *different* one."""

    topo: Topology
    num_experts: int = 64
    top_k: int = 8
    token_bytes: int = 7168
    seed: int = 0
    skew: float = 0.0          # hot-expert routing skew (see DispatchScenario)
    compute_s: float = 0.0     # overlap context (see DispatchScenario)

    def cache_key(self):
        return ("combine", self.num_experts, self.top_k, self.token_bytes,
                self.skew, self.compute_s)


@dataclasses.dataclass(frozen=True)
class LinkProbeScenario:
    """Directed point-to-point microbenchmark: every rail link from
    ``src_server`` to ``dst_server`` carries the payload simultaneously
    (the telemetry probe that fits a direction which NEVER bottlenecks
    any real collective — 2x8asym forward rails — instead of leaving it
    nominal).  ``src_server == dst_server`` probes the server's intra
    full mesh."""

    topo: Topology
    src_server: int = 0
    dst_server: int = 1

    def cache_key(self):
        return ("linkprobe", self.src_server, self.dst_server)


@dataclasses.dataclass(frozen=True)
class ReduceScenario:
    """Gradient synchronization over the data-parallel replicas: every
    node holds a full gradient of ``payload_bytes`` and the collective
    produces the elementwise sum — on every node for ``allreduce``, as
    1/R shards for ``reduce_scatter``.

    ``compute_s`` is the overlap context: the BACKWARD-pass compute time
    remaining when gradient sync of this payload can start.  Gradient
    buckets become ready back-to-front as the backward pass proceeds, so
    a chunked (microbatch > 1) sync overlaps earlier chunks' wire time
    with later layers' backward compute — the same pipelined scoring
    mode the MoE dispatch path uses.  0 = score the sync in isolation
    (G == 1 always wins then: per-chunk alpha with nothing to hide
    behind)."""

    topo: Topology
    compute_s: float = 0.0

    def cache_key(self):
        return ("reduce", self.compute_s)


def default_scenarios(topo: Topology) -> dict:
    """One representative scenario per op for ``topo`` — the grid the CI
    fabric smoke iterates (every registered plan must simulate on every
    registered fabric without raising)."""
    return {"allgather": AllGatherScenario.split_tp(topo, 2),
            "dispatch": DispatchScenario(topo=topo),
            "combine": CombineScenario(topo=topo),
            "linkprobe": LinkProbeScenario(
                topo, 0, 1 if topo.meta.num_servers > 1 else 0),
            "allreduce": ReduceScenario(topo=topo),
            "reduce_scatter": ReduceScenario(topo=topo)}


# ---------------------------------------------------------------------------
# The plan IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """One registered collective scheme with declared knobs.

    ``simulate_fn(scenario, payload_bytes, **knobs) -> Ledger`` is the
    semantic oracle; ``kwargs_fn(**knobs)`` produces the JAX-layer kwargs
    of the winning configuration.  ``executable`` marks plans that have a
    shard_map lowering (unicast multipath exists only as a paper
    comparison point, so the planner excludes it when asked for an
    executable choice).
    """

    name: str
    op: str                            # "allgather" | "dispatch" | "combine"
    knobs: Mapping[str, tuple]                # knob -> candidate grid
    simulate_fn: Callable[..., Ledger]
    kwargs_fn: Callable[..., dict] = lambda **kw: dict(kw)
    executable: bool = True

    def knob_grid(self) -> Iterator[dict]:
        if not self.knobs:
            yield {}
            return
        names = sorted(self.knobs)
        for combo in itertools.product(*(self.knobs[k] for k in names)):
            yield dict(zip(names, combo))

    def default_knobs(self) -> dict:
        return {k: v[0] for k, v in self.knobs.items()}

    def simulate(self, scenario, payload_bytes: float, **knobs) -> Ledger:
        kn = {**self.default_knobs(), **knobs}
        return self.simulate_fn(scenario, float(payload_bytes), **kn)

    def shard_map_kwargs(self, **knobs) -> dict:
        kn = {**self.default_knobs(), **knobs}
        return self.kwargs_fn(**kn)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

PLAN_REGISTRY: dict[tuple[str, str], CollectivePlan] = {}
BASELINE_PLAN = {"allgather": "baseline", "dispatch": "unicast",
                 "combine": "unicast",
                 # directed point-to-point link microbenchmark (telemetry):
                 # pure serialization, so its records feed the alpha/beta
                 # regression like the real baselines do
                 "linkprobe": "p2p",
                 # gradient sync: the flat bandwidth-optimal ring is what
                 # GSPMD lowers an unannotated psum to — the thing the
                 # smarter schemes must beat
                 "allreduce": "ring",
                 "reduce_scatter": "ring"}


def register_plan(plan: CollectivePlan) -> CollectivePlan:
    key = (plan.op, plan.name)
    PLAN_REGISTRY[key] = plan
    return plan


def get_plan(op: str, name: str) -> CollectivePlan:
    try:
        return PLAN_REGISTRY[(op, name)]
    except KeyError:
        raise KeyError(
            f"no plan {name!r} registered for op {op!r}; have "
            f"{sorted(n for o, n in PLAN_REGISTRY if o == op)}") from None


def plans_for(op: str, executable_only: bool = False
              ) -> list[CollectivePlan]:
    """Registered plans for ``op`` in registration order."""
    out = [p for (o, _), p in PLAN_REGISTRY.items() if o == op]
    if executable_only:
        out = [p for p in out if p.executable]
    return out


# ---------------------------------------------------------------------------
# Declarative collective programs (the bindable planning surface)
# ---------------------------------------------------------------------------
#
# A model's collectives used to be planned one call site at a time: every
# consumer asked ``ParallelContext.resolve_*`` for its own op at trace
# time, so coupled sites (the MoE dispatch and its return-path combine,
# which execute inside ONE chunk pipeline) could never be optimized
# together.  The declarative surface inverts that: callers REGISTER their
# sites up-front as a :class:`CollectiveProgram`, one
# ``Planner.plan_program`` sweep decides every site (coupled groups
# jointly, under the shared-pipeline scorer), and the resulting immutable
# :class:`ExecutionPlan` is bound into the ``ParallelContext`` — trace
# time is pure lookup.

@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One declared collective call site of a model.

    ``op``        planner op ("allgather" | "dispatch" | "combine");
    ``role``      unique name within the program ("train/moe_dispatch");
    ``payload_bytes``  per-participant payload of the site;
    ``scenario_kw``    sorted (key, value) pairs completing the planner
                  scenario (num_experts / top_k / token_bytes /
                  num_domains);
    ``compute_ctx``    overlap context: the modeled compute time (expert
                  FFN) chunked transfers of this site hide behind;
    ``skew``      hot-expert routing skew the site is priced under;
    ``coupled_with``   role of the site sharing this site's chunk
                  pipeline (the MoE combine declares
                  ``coupled_with="…/moe_dispatch"``) — coupled groups are
                  swept jointly over one shared microbatch G;
    ``topo``      optional site-specific fabric (the split-TP AllGather
                  runs on the §3.1 full-mesh fixture, not the EP fabric).
    """

    op: str
    role: str
    payload_bytes: float
    scenario_kw: tuple = ()
    compute_ctx: float = 0.0
    skew: float = 0.0
    coupled_with: Optional[str] = None
    topo: Optional[Topology] = None

    @property
    def phase(self) -> str:
        """Phase prefix of the role ("train/grad_sync" -> "train"); sites
        sharing a phase execute concurrently and contend for links."""
        return self.role.partition("/")[0]

    def scenario_args(self) -> dict:
        """kwargs for ``Planner._scenario`` (skew/compute folded in)."""
        return {**dict(self.scenario_kw), "skew": self.skew,
                "compute_s": self.compute_ctx}

    def key(self) -> tuple:
        """Workload identity of the site — what a trace-time lookup can
        reconstruct from live shapes.  Deliberately excludes ``role``,
        ``coupled_with`` and ``topo``: the consumer inside ``shard_map``
        knows its op, payload and scenario, nothing else."""
        return (self.op, bucket_payload(self.payload_bytes),
                tuple(sorted(dict(self.scenario_kw).items())),
                float(self.skew), bucket_compute_s(self.compute_ctx))


def site_key(op: str, payload_bytes: float, *, skew: float = 0.0,
             compute_s: float = 0.0, **scenario_kw) -> tuple:
    """The :meth:`CollectiveSite.key` a trace-time consumer derives from
    its live quantities (one shared construction, so bind-time and
    trace-time keys cannot drift)."""
    return (op, bucket_payload(payload_bytes),
            tuple(sorted(scenario_kw.items())),
            float(skew), bucket_compute_s(compute_s))


def moe_sites(phase: str, *, num_experts: int, top_k: int,
              tokens_per_rank: int, token_bytes: int,
              skew: float = 0.0, compute_s: float = 0.0,
              topo: Optional[Topology] = None
              ) -> tuple[CollectiveSite, CollectiveSite]:
    """The canonical coupled (dispatch, combine) site pair of one MoE
    phase — both halves of the token round trip, declared as ONE group
    so the planner sweeps (dispatch scheme, combine scheme, shared G)
    jointly under the shared-pipeline scorer."""
    kw = (("num_experts", int(num_experts)), ("top_k", int(top_k)),
          ("token_bytes", int(token_bytes)))
    payload = float(tokens_per_rank) * token_bytes
    dispatch = CollectiveSite(
        op="dispatch", role=f"{phase}/moe_dispatch", payload_bytes=payload,
        scenario_kw=kw, compute_ctx=compute_s, skew=skew, topo=topo)
    combine = CollectiveSite(
        op="combine", role=f"{phase}/moe_combine", payload_bytes=payload,
        scenario_kw=kw, compute_ctx=compute_s, skew=skew,
        coupled_with=dispatch.role, topo=topo)
    return dispatch, combine


def allgather_site(phase: str, *, frag_bytes: float, num_domains: int = 2,
                   topo: Optional[Topology] = None) -> CollectiveSite:
    """The §3.1 split-TP AllGather site of one phase."""
    return CollectiveSite(
        op="allgather", role=f"{phase}/split_tp_gather",
        payload_bytes=float(frag_bytes),
        scenario_kw=(("num_domains", int(num_domains)),), topo=topo)


def grad_sync_site(phase: str, *, payload_bytes: float,
                   compute_s: float = 0.0,
                   topo: Optional[Topology] = None) -> CollectiveSite:
    """The per-step gradient AllReduce site of one training phase.

    Uncoupled: gradient sync shares no chunk pipeline with the MoE round
    trip (it runs after the backward pass produces each bucket), so
    ``plan_program`` sweeps it alone — but under the same pipelined
    scorer, with the tail of the backward pass as overlap context."""
    return CollectiveSite(
        op="allreduce", role=f"{phase}/grad_sync",
        payload_bytes=float(payload_bytes), compute_ctx=float(compute_s),
        topo=topo)


@dataclasses.dataclass(frozen=True)
class CollectiveProgram:
    """Every collective site a workload will issue, declared up-front.

    ``name`` identifies the launch surface ("train", "serve", "dryrun");
    sites carry their phase in the role prefix ("prefill/moe_dispatch").
    Roles must be unique; ``coupled_with`` references must resolve and
    must not chain (a group is one pipeline).

    ``phase_budgets`` optionally caps a phase's contention-aware latency
    (phase name -> seconds): a decode SLO declared here constrains the
    OTHER phases' plans during the joint sweep — their candidate
    combinations are rejected when their background traffic would push
    the budgeted phase past its cap (see ``Planner.plan_program``).
    """

    name: str
    sites: tuple[CollectiveSite, ...]
    phase_budgets: Mapping[str, float] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        phases = {s.phase for s in self.sites}
        for ph, budget in self.phase_budgets.items():
            if ph not in phases:
                raise ValueError(
                    f"budget for unknown phase {ph!r} in program "
                    f"{self.name!r}; have {sorted(phases)}")
            if not budget > 0:
                raise ValueError(
                    f"phase budget must be positive: {ph!r} -> {budget!r}")
        roles = [s.role for s in self.sites]
        if len(set(roles)) != len(roles):
            dup = sorted({r for r in roles if roles.count(r) > 1})
            raise ValueError(f"duplicate site roles in program "
                             f"{self.name!r}: {dup}")
        by_role = {s.role: s for s in self.sites}
        for s in self.sites:
            if s.coupled_with is None:
                continue
            anchor = by_role.get(s.coupled_with)
            if anchor is None:
                raise ValueError(
                    f"site {s.role!r} couples to unknown role "
                    f"{s.coupled_with!r}")
            if anchor.coupled_with is not None:
                raise ValueError(
                    f"coupling chains are not a pipeline: {s.role!r} -> "
                    f"{s.coupled_with!r} -> {anchor.coupled_with!r}")

    def site(self, role: str) -> CollectiveSite:
        for s in self.sites:
            if s.role == role:
                return s
        raise KeyError(f"no site {role!r} in program {self.name!r}; have "
                       f"{[s.role for s in self.sites]}")

    def groups(self) -> list[tuple[CollectiveSite, ...]]:
        """Sites partitioned into jointly-planned groups: each coupled
        pair (anchor, satellite) is one group, everything else plans
        alone.  Declaration order is preserved."""
        by_anchor: dict[str, list[CollectiveSite]] = {}
        for s in self.sites:
            if s.coupled_with is not None:
                by_anchor.setdefault(s.coupled_with, []).append(s)
        out: list[tuple[CollectiveSite, ...]] = []
        for s in self.sites:
            if s.coupled_with is not None:
                continue
            out.append((s, *by_anchor.get(s.role, [])))
        return out

    def phases(self) -> dict[str, list[tuple[CollectiveSite, ...]]]:
        """Jointly-planned groups partitioned by phase (declaration
        order preserved): groups within one phase execute concurrently
        and are scored under the merged phase ledger; distinct phases
        never overlap (except through an explicit budget constraint)."""
        out: dict[str, list[tuple[CollectiveSite, ...]]] = {}
        for group in self.groups():
            out.setdefault(group[0].phase, []).append(group)
        return out

    def cache_key(self) -> tuple:
        return (self.name,
                tuple(sorted(self.phase_budgets.items())),
                tuple((s.role, s.key(), s.coupled_with,
                       None if s.topo is None else s.topo.fingerprint())
                      for s in self.sites))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The planner's immutable verdict for one whole program.

    ``decisions``   role -> per-site PlanDecision (marginal view: the
                    site's own predicted/baseline times at the jointly
                    chosen configuration);
    ``joint``       group anchor role -> combined PlanDecision of the
                    coupled pipeline (op "dispatch+combine", merged
                    shard_map kwargs, joint serial/ideal endpoints — the
                    row step-time telemetry measures against);
    ``group_of``    role -> anchor role of its coupled group (anchors
                    map to themselves; uncoupled sites are absent).
    ``phase_report``  phase -> contention breakdown of the chosen
                    combination (solo/merged-wire/contention seconds,
                    budget verdict, per-phase search statistics).
    ``planner_stats``  whole-program sweep statistics (candidates
                    enumerated, combinations scored vs the exhaustive
                    product, search mode, planning wall-time).

    Bound into a :class:`~repro.parallel.context.ParallelContext` via
    ``pctx.bind(plan)``; consumers resolve their site by
    :func:`site_key` lookup and execute the stored kwargs verbatim.
    """

    program: CollectiveProgram
    topo_fingerprint: tuple
    hw_fingerprint: tuple
    decisions: Mapping[str, object]
    joint: Mapping[str, object] = dataclasses.field(default_factory=dict)
    group_of: Mapping[str, str] = dataclasses.field(default_factory=dict)
    phase_report: Mapping[str, dict] = dataclasses.field(
        default_factory=dict)
    planner_stats: Mapping[str, object] = dataclasses.field(
        default_factory=dict)

    # -- identity ------------------------------------------------------------
    @property
    def fingerprint(self) -> str:
        """Stable content hash: program sites + fabrics + calibration +
        every chosen (plan, knobs).  Two plans with the same fingerprint
        execute identically; a re-plan that changes any decision changes
        the fingerprint (what launch surfaces log across recalibrations)."""
        parts = [repr(self.program.cache_key()),
                 repr(self.topo_fingerprint), repr(self.hw_fingerprint)]
        for role in sorted(self.decisions):
            d = self.decisions[role]
            parts.append(f"{role}={d.plan}{sorted(dict(d.knobs).items())}")
        return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]

    # -- lookup --------------------------------------------------------------
    def decision(self, role: str):
        try:
            return self.decisions[role]
        except KeyError:
            raise KeyError(
                f"no decision for role {role!r}; have "
                f"{sorted(self.decisions)}") from None

    def find_role(self, op: str, payload_bytes: float, *,
                  skew: float = 0.0, compute_s: float = 0.0,
                  **scenario_kw) -> Optional[str]:
        """Role of the site matching a trace-time workload, or None (the
        traced shape was not declared — consumers fall back to their
        policy default)."""
        key = site_key(op, payload_bytes, skew=skew, compute_s=compute_s,
                       **scenario_kw)
        for s in self.program.sites:
            if s.key() == key:
                return s.role
        return None

    def site_kwargs(self, role: str) -> dict:
        """The kwargs the consumer of ``role`` executes: the coupled
        group's merged kwargs when the site is part of one (dispatch
        scheme + combine scheme + the SHARED microbatch G), else the
        site's own decision kwargs."""
        anchor = self.group_of.get(role)
        if anchor is not None and anchor in self.joint:
            return dict(self.joint[anchor].shard_map_kwargs)
        return dict(self.decision(role).shard_map_kwargs)

    # -- reporting -----------------------------------------------------------
    def report(self) -> dict:
        out = {"program": self.program.name,
               "fingerprint": self.fingerprint,
               "sites": {}, "joint": {}}
        for role in sorted(self.decisions):
            out["sites"][role] = self.decisions[role].report()
        for anchor in sorted(self.joint):
            out["joint"][anchor] = self.joint[anchor].report()
        if self.phase_report:
            out["phases"] = {ph: dict(rep)
                             for ph, rep in self.phase_report.items()}
        if self.planner_stats:
            out["planner"] = dict(self.planner_stats)
        return out

    def summary(self) -> str:
        lines = [f"program {self.program.name} [{self.fingerprint}]"]
        done = set()
        for anchor, d in self.joint.items():
            lines.append(f"  {anchor} (+coupled): {d.summary()}")
            done.update(r for r, a in self.group_of.items() if a == anchor)
        for role in sorted(self.decisions):
            if role not in done:
                lines.append(f"  {role}: {self.decisions[role].summary()}")
        for ph, rep in self.phase_report.items():
            if rep.get("contention_s", 0.0) > 0 or rep.get("budget_s"):
                line = (f"  phase {ph}: {rep['score_s'] * 1e6:.0f}us"
                        f" (contention +{rep['contention_s'] * 1e6:.0f}us)")
                if rep.get("budget_s"):
                    verdict = "ok" if rep.get("budget_ok") else "VIOLATED"
                    line += (f", budget {rep['budget_s'] * 1e6:.0f}us"
                             f" {verdict}")
                lines.append(line)
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class PinnedDecision:
    """A hand-pinned site decision (no sweep behind it): what
    :func:`pinned_execution_plan` installs.  Mirrors the PlanDecision
    surface ExecutionPlan consumers touch (kwargs, knobs, report)."""

    op: str
    plan: str
    knobs: tuple
    shard_map_kwargs: Mapping
    predicted_s: float = 0.0
    baseline_s: float = 0.0
    predicted_serial_s: float = 0.0
    predicted_ideal_s: float = 0.0

    @property
    def microbatch(self) -> int:
        return int(dict(self.knobs).get("microbatch", 1))

    def report(self) -> dict:
        # same key schema as PlanDecision.report so report consumers
        # (serve.py's stats printout, dryrun tables) never branch on
        # whether a decision was swept or pinned
        return {"plan": self.plan, "knobs": dict(self.knobs),
                "pinned": True, "predicted_us": self.predicted_s * 1e6,
                "baseline_us": self.baseline_s * 1e6,
                "delta_vs_baseline_us":
                    (self.baseline_s - self.predicted_s) * 1e6,
                "speedup_pct": 0.0}

    def summary(self) -> str:
        kn = ", ".join(f"{k}={v}" for k, v in self.knobs)
        return f"{self.op}: pinned {self.plan}({kn})"


def pinned_execution_plan(program: CollectiveProgram,
                          kwargs_by_role: Mapping[str, Mapping]
                          ) -> ExecutionPlan:
    """An :class:`ExecutionPlan` with hand-pinned per-group kwargs — the
    operational override path (force a known-good configuration without
    a sweep) and the test fixture for bound-plan execution.

    ``kwargs_by_role`` maps each group ANCHOR role to the execution
    kwargs its consumers should get verbatim (for a coupled MoE pair:
    ``{"moe_scheme", "moe_combine", "microbatch"}``)."""
    decisions: dict = {}
    joint: dict = {}
    group_of: dict = {}
    for group in program.groups():
        anchor = group[0]
        kw = dict(kwargs_by_role[anchor.role])
        g = int(kw.get("microbatch", 1))
        if len(group) == 1:
            decisions[anchor.role] = PinnedDecision(
                op=anchor.op, plan="pinned",
                knobs=tuple(sorted(kw.items())), shard_map_kwargs=kw)
            continue
        joint[anchor.role] = PinnedDecision(
            op="+".join(s.op for s in group), plan="pinned",
            knobs=(("microbatch", g),), shard_map_kwargs=kw)
        for s in group:
            group_of[s.role] = anchor.role
            decisions[s.role] = PinnedDecision(
                op=s.op, plan="pinned", knobs=(("microbatch", g),),
                shard_map_kwargs=kw)
    return ExecutionPlan(program=program, topo_fingerprint=("pinned",),
                         hw_fingerprint=("pinned",), decisions=decisions,
                         joint=joint, group_of=group_of)


# ---------------------------------------------------------------------------
# probe-size helpers shared by plan implementations
# ---------------------------------------------------------------------------

PROBE_FRAG_BYTES = 1 << 14        # AllGather probe fragment (16 KiB)
PROBE_TOKEN_BYTES = 128           # dispatch probe token payload
PROBE_BATCH = 32                  # dispatch probe tokens per NPU


def probe_scale(payload_bytes: float, probe_bytes: float) -> float:
    return float(payload_bytes) / float(probe_bytes) if probe_bytes else 1.0
