"""Executable collective-communication schedules (paper §3.1, §3.2, §5.2).

A *schedule* is a function that drives a :class:`MultiWriteSimulator` to
perform one collective operation over a :class:`Topology`, producing

- the delivered buffers (for correctness assertions), and
- the per-link byte ledger (for the latency model).

Schedules implemented (one per paper scheme):

AllGather on a full-mesh split into TP domains (§3.1 / §5.2):
  * :func:`allgather_baseline`            — intra-domain unicast only
  * :func:`allgather_unicast_multipath`   — paired relaying, unicast (3 copies
                                            cross the pair link)
  * :func:`allgather_multiwrite`          — paired relaying, MultiWrite (ONE
                                            copy crosses the pair link; the
                                            relay replicates)
  * :func:`allgather_full_multipath`      — full multi-path relaying in both
                                            unicast and multiwrite modes

AlltoAll dispatch on the 2-server oversubscribed cluster (§3.2 / §6.3):
  * :func:`dispatch_unicast`              — one unicast write per
                                            (token, destination NPU): k_remote
                                            redundant copies cross the rail
  * :func:`dispatch_multiwrite`           — one MultiWrite per token: a single
                                            copy per remote server crosses the
                                            rail, replication at the
                                            same-index relay (§3.2)

Every AllGather schedule takes a ``split`` — the fraction of each fragment
sent over direct intra-domain links (paper §5.2 step (1): "split ratio is
dynamically calculated based on the measured bandwidth of both link types").
:func:`optimal_split` computes the ratio that equalizes path completion
times, which is what "arrives simultaneously to minimize overall latency"
requires.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .multiwrite import MultiWriteSimulator
from .topology import Topology, same_index_peer

# Buffer naming convention: AllGather output slot for source ``i`` is
# ``ag/<i>``; segment suffixes ``/d`` (direct part) and ``/x`` (cross part)
# keep the two data segments distinct (§5.2 step (1) splits them).


def _split_payload(data: np.ndarray, split: float) -> tuple[np.ndarray, np.ndarray]:
    """Split a 1-D byte payload into (direct, cross) segments."""
    n = data.shape[0]
    cut = int(round(n * split))
    return data[:cut], data[cut:]


def partner_of(node: int, domains: Sequence[Sequence[int]]) -> int:
    """Paired-relaying partner (§3.1): same index in the other domain."""
    (da, db) = domains
    if node in da:
        return db[list(da).index(node)]
    return da[list(db).index(node)]


def domain_of(node: int, domains: Sequence[Sequence[int]]) -> list[int]:
    for d in domains:
        if node in d:
            return list(d)
    raise ValueError(f"node {node} in no domain")


# ---------------------------------------------------------------------------
# AllGather schedules (§3.1, §5.2)
# ---------------------------------------------------------------------------

def allgather_baseline(sim: MultiWriteSimulator,
                       domains: Sequence[Sequence[int]],
                       payloads: Sequence[np.ndarray]) -> None:
    """Traditional AllGather: three concurrent unicast writes per node over
    direct intra-domain links (paper §5.2 baseline workflow, step (2))."""
    for dom in domains:
        for src in dom:
            for dst in dom:
                if dst == src:
                    continue
                sim.write(src, dst, f"ag/{src}", payloads[src], step=0)
            sim.memory[src][f"ag/{src}"] = np.array(payloads[src])  # local


def allgather_unicast_multipath(sim: MultiWriteSimulator,
                                domains: Sequence[Sequence[int]],
                                payloads: Sequence[np.ndarray],
                                split: float = 0.75) -> None:
    """Paired-relay multipath with *unicast* cross transfers (§3.1).

    Each node sends the direct segment on its intra-domain links and issues
    one unicast write PER PEER routed through its partner: three identical
    copies of the cross segment traverse the node->partner link.
    """
    for dom in domains:
        for src in dom:
            direct, cross = _split_payload(payloads[src], split)
            peers = [d for d in dom if d != src]
            for dst in peers:
                sim.write(src, dst, f"ag/{src}/d", direct, step=0)
            partner = partner_of(src, domains)
            # unicast: one write per destination; every copy crosses the
            # src->partner link, then the partner forwards (store&forward).
            for dst in peers:
                sim.write(src, partner, f"relay/{src}/{dst}", cross, step=0)
                sim.write(partner, dst, f"ag/{src}/x", cross, step=0)
                # store-and-forward processing at the relay (rx + tx), kept
                # in the same ledger the MultiWrite recursion feeds:
                sim.relay_bytes[partner] += 2 * int(cross.nbytes)
            sim.memory[src][f"ag/{src}/d"] = np.array(direct)
            sim.memory[src][f"ag/{src}/x"] = np.array(cross)


def allgather_multiwrite(sim: MultiWriteSimulator,
                         domains: Sequence[Sequence[int]],
                         payloads: Sequence[np.ndarray],
                         split: float = 0.5) -> None:
    """Paired-relay multipath with a single cross-TP MultiWrite (§5.2).

    Workflow (paper §5.2 optimized): (1) split each fragment by ``split``;
    (2) three standard unicast writes intra-domain plus ONE MultiWrite whose
    destination set is the three peers, first hop forced through the partner
    (the relay), which replicates — one copy on the bottleneck link.
    """
    for dom in domains:
        for src in dom:
            direct, cross = _split_payload(payloads[src], split)
            peers = [d for d in dom if d != src]
            for dst in peers:
                sim.write(src, dst, f"ag/{src}/d", direct, step=0)
            partner = partner_of(src, domains)
            sim.multiwrite(src, {dst: f"ag/{src}/x" for dst in peers},
                           cross, step=0, relay=partner)
            sim.memory[src][f"ag/{src}/d"] = np.array(direct)
            sim.memory[src][f"ag/{src}/x"] = np.array(cross)


def allgather_full_multipath(sim: MultiWriteSimulator,
                             domains: Sequence[Sequence[int]],
                             payloads: Sequence[np.ndarray],
                             split: float,
                             multicast: bool) -> None:
    """Full multi-path relaying (§3.1): every node in the opposite domain
    relays an equal slice of the cross segment.

    unicast mode:   one write per (relay, destination) — three copies of each
                    slice cross the src->relay link.
    multicast mode: one MultiWrite per relay — one copy per slice crosses.
    """
    for dom in domains:
        other = [d for d in domains if list(d) != list(dom)][0]
        for src in dom:
            direct, cross = _split_payload(payloads[src], split)
            peers = [d for d in dom if d != src]
            for dst in peers:
                sim.write(src, dst, f"ag/{src}/d", direct, step=0)
            # slice the cross segment over all opposite-domain relays
            slices = np.array_split(cross, len(other))
            for ri, relay in enumerate(other):
                sl = slices[ri]
                if sl.size == 0:
                    continue
                if multicast:
                    sim.multiwrite(src, {dst: f"ag/{src}/x{ri}" for dst in peers},
                                   sl, step=0, relay=relay)
                else:
                    for dst in peers:
                        sim.write(src, relay, f"relay/{src}/{dst}/{ri}", sl, step=0)
                        sim.write(relay, dst, f"ag/{src}/x{ri}", sl, step=0)
                        sim.relay_bytes[relay] += 2 * int(sl.nbytes)
            sim.memory[src][f"ag/{src}/d"] = np.array(direct)
            for ri in range(len(other)):
                sl = slices[ri]
                if sl.size:
                    sim.memory[src][f"ag/{src}/x{ri}"] = np.array(sl)


def check_allgather(sim: MultiWriteSimulator,
                    domains: Sequence[Sequence[int]],
                    payloads: Sequence[np.ndarray]) -> None:
    """Assert every node holds every domain-peer's full fragment."""
    for dom in domains:
        for node in dom:
            for src in dom:
                got = [v for k, v in sorted(sim.memory[node].items())
                       if k.startswith(f"ag/{src}")]
                assert got, f"node {node} missing fragment {src}"
                np.testing.assert_array_equal(np.concatenate(got), payloads[src])


# ---------------------------------------------------------------------------
# AlltoAll dispatch schedules (§3.2, §6.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchRouting:
    """MoE dispatch routing decisions for one batch.

    token_owner[t]   source NPU of token t
    token_dests[t]   sorted list of destination NPUs (expert owners) — the
                     per-token destination SET the bitmap metadata encodes.
    """
    token_owner: np.ndarray          # [T] int
    token_dests: list[list[int]]     # [T][<=k]


def make_routing(num_tokens_per_npu: int, num_npus: int, num_experts: int,
                 top_k: int, seed: int,
                 experts_per_npu: int | None = None) -> DispatchRouting:
    """Random balanced top-k routing (paper §6.1: 'expert load balancing is
    enabled'), experts round-robin across NPUs."""
    if experts_per_npu is None:
        experts_per_npu = num_experts // num_npus
    assert experts_per_npu * num_npus == num_experts
    rng = np.random.default_rng(seed)
    owners = np.repeat(np.arange(num_npus), num_tokens_per_npu)
    dests: list[list[int]] = []
    for _ in owners:
        experts = rng.choice(num_experts, size=top_k, replace=False)
        npus = sorted(set(int(e) // experts_per_npu for e in experts))
        dests.append(npus)
    return DispatchRouting(owners, dests)


def dispatch_unicast(sim: MultiWriteSimulator, routing: DispatchRouting,
                     token_bytes: int) -> None:
    """Baseline dispatch: one unicast write per (token, destination NPU).

    Under the rail-first forwarding table of :func:`two_server_cluster`,
    each remote-server copy crosses the source's rail link — k_remote
    redundant copies of the same token on the bottleneck (§3.2, Table 1
    'w/ redundant').
    """
    for t, (src, dests) in enumerate(zip(routing.token_owner, routing.token_dests)):
        payload = _token_payload(t, token_bytes)
        for dst in dests:
            if dst == int(src):
                sim.memory[dst][f"tok/{t}"] = payload
            else:
                sim.write(int(src), dst, f"tok/{t}", payload, step=0)


def dispatch_multiwrite(sim: MultiWriteSimulator, routing: DispatchRouting,
                        token_bytes: int) -> None:
    """MultiWrite dispatch (§3.2): ONE MultiWrite per token.

    ``partition_by_next_hop`` over the rail-first table groups all
    destinations on a remote server under the same-index relay, so exactly
    one copy crosses the rail; the relay replicates intra-server.
    """
    for t, (src, dests) in enumerate(zip(routing.token_owner, routing.token_dests)):
        payload = _token_payload(t, token_bytes)
        sim.multiwrite(int(src), {d: f"tok/{t}" for d in dests}, payload, step=0)


def _token_payload(token_id: int, token_bytes: int) -> np.ndarray:
    rng = np.random.default_rng(token_id + 1)
    return rng.integers(0, 256, size=token_bytes, dtype=np.uint8)


def check_dispatch(sim: MultiWriteSimulator, routing: DispatchRouting,
                   token_bytes: int) -> None:
    """Every destination received exactly its tokens, bit-exact, once."""
    for t, dests in enumerate(routing.token_dests):
        expect = _token_payload(t, token_bytes)
        for d in dests:
            np.testing.assert_array_equal(sim.memory[d][f"tok/{t}"], expect)
            assert sim.delivery_count[(d, f"tok/{t}")] <= 1 or \
                int(routing.token_owner[t]) == d
    # no token delivered anywhere it was not routed
    for (node, buf), cnt in sim.delivery_count.items():
        if buf.startswith("tok/"):
            t = int(buf.split("/")[1])
            assert node in routing.token_dests[t], \
                f"token {t} spuriously delivered to {node}"


# ---------------------------------------------------------------------------
# Optimal split ratios (paper §5.2 step (1))
# ---------------------------------------------------------------------------

def optimal_split(scheme: str, num_relays: int = 1) -> float:
    """Fraction of the fragment to send on the direct path so both paths
    finish simultaneously (per-link serialization, uniform link bw ``w``).

    Derivations (§3.1, fragment size s, TP=4 so 3 peers):

    baseline              direct only                          -> 1.0
    unicast paired        direct r*s/w  == cross 3(1-r)s/w     -> r = 3/4
    multiwrite paired     direct r*s/w  == cross (1-r)s/w      -> r = 1/2
    unicast full          cross link carries 3p + 3p' = 6(1-r)s/4
                          (3 copies up per relay slice, 3 relayed-in slices)
                          r = 6(1-r)/4                         -> r = 3/5
    multiwrite full       cross link carries p + 3p' = 4(1-r)s/4
                          r = (1-r)                            -> r = 1/2
    """
    return {
        "baseline": 1.0,
        "unicast_paired": 0.75,
        "multiwrite_paired": 0.5,
        "unicast_full": 0.6,
        "multiwrite_full": 0.5,
    }[scheme]


ALLGATHER_SCHEMES: dict[str, Callable] = {
    "baseline": lambda sim, dom, pay: allgather_baseline(sim, dom, pay),
    "unicast_paired": lambda sim, dom, pay: allgather_unicast_multipath(
        sim, dom, pay, split=optimal_split("unicast_paired")),
    "multiwrite_paired": lambda sim, dom, pay: allgather_multiwrite(
        sim, dom, pay, split=optimal_split("multiwrite_paired")),
    "unicast_full": lambda sim, dom, pay: allgather_full_multipath(
        sim, dom, pay, split=optimal_split("unicast_full"), multicast=False),
    "multiwrite_full": lambda sim, dom, pay: allgather_full_multipath(
        sim, dom, pay, split=optimal_split("multiwrite_full"), multicast=True),
}
