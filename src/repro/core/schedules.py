"""Executable collective-communication schedules (paper §3.1, §3.2, §5.2).

A *schedule* is a function that drives a :class:`MultiWriteSimulator` to
perform one collective operation over a :class:`Topology`, producing

- the delivered buffers (for correctness assertions), and
- the per-link byte ledger (for the latency model).

Every schedule is exposed twice:

  * the low-level driver function below (the packet-level oracle the
    correctness tests exercise directly), and
  * a registered :class:`~repro.core.plan.CollectivePlan` (bottom of
    this module) with declared knob grids and a
    ``simulate(scenario, payload_bytes) -> Ledger`` method — the unit
    the :class:`~repro.core.planner.Planner` sweeps and scores.  Adding
    a scheme in a later PR is one driver + one ``register_plan`` call.

Schedules implemented (one per paper scheme):

AllGather on a full-mesh split into TP domains (§3.1 / §5.2):
  * :func:`allgather_baseline`            — intra-domain unicast only
  * :func:`allgather_unicast_multipath`   — paired relaying, unicast (3 copies
                                            cross the pair link)
  * :func:`allgather_multiwrite`          — paired relaying, MultiWrite (ONE
                                            copy crosses the pair link; the
                                            relay replicates)
  * :func:`allgather_full_multipath`      — full multi-path relaying in both
                                            unicast and multiwrite modes

AlltoAll dispatch on the oversubscribed cluster fabrics (§3.2 / §6.3):
  * :func:`dispatch_unicast`              — one unicast write per
                                            (token, destination NPU): k_remote
                                            redundant copies cross the rail
  * :func:`dispatch_multiwrite`           — one MultiWrite per token: a single
                                            copy per remote server (and rail
                                            stripe) crosses, replication at
                                            the rail relay (§3.2)

AlltoAll combine — the return path, planned as a first-class op:
  * :func:`combine_unicast`               — every expert partial returns
                                            individually (redundant dual)
  * :func:`combine_multiwrite`            — relay-side partial reduction:
                                            ONE reduced partial per (token,
                                            remote server, rail stripe)
                                            crosses back — the mirror of
                                            dispatch_multiwrite

Every AllGather schedule takes a ``split`` — the fraction of each fragment
sent over direct intra-domain links (paper §5.2 step (1): "split ratio is
dynamically calculated based on the measured bandwidth of both link types").
:func:`optimal_split` computes the ratio that equalizes path completion
times, which is what "arrives simultaneously to minimize overall latency"
requires.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Sequence

import numpy as np

from . import plan as plan_ir
from .multiwrite import MultiWriteSimulator
from .topology import Topology

# Buffer naming convention: AllGather output slot for source ``i`` is
# ``ag/<i>``; segment suffixes ``/d`` (direct part) and ``/x`` (cross part)
# keep the two data segments distinct (§5.2 step (1) splits them).


def _split_payload(data: np.ndarray, split: float) -> tuple[np.ndarray, np.ndarray]:
    """Split a 1-D byte payload into (direct, cross) segments."""
    n = data.shape[0]
    cut = int(round(n * split))
    return data[:cut], data[cut:]


def partner_of(node: int, domains: Sequence[Sequence[int]]) -> int:
    """Paired-relaying partner (§3.1): same index in the other domain."""
    (da, db) = domains
    if node in da:
        return db[list(da).index(node)]
    return da[list(db).index(node)]


def domain_of(node: int, domains: Sequence[Sequence[int]]) -> list[int]:
    for d in domains:
        if node in d:
            return list(d)
    raise ValueError(f"node {node} in no domain")


# ---------------------------------------------------------------------------
# AllGather schedules (§3.1, §5.2)
# ---------------------------------------------------------------------------

def allgather_baseline(sim: MultiWriteSimulator,
                       domains: Sequence[Sequence[int]],
                       payloads: Sequence[np.ndarray]) -> None:
    """Traditional AllGather: three concurrent unicast writes per node over
    direct intra-domain links (paper §5.2 baseline workflow, step (2))."""
    for dom in domains:
        for src in dom:
            for dst in dom:
                if dst == src:
                    continue
                sim.write(src, dst, f"ag/{src}", payloads[src], step=0)
            sim.memory[src][f"ag/{src}"] = np.array(payloads[src])  # local


def allgather_unicast_multipath(sim: MultiWriteSimulator,
                                domains: Sequence[Sequence[int]],
                                payloads: Sequence[np.ndarray],
                                split: float = 0.75) -> None:
    """Paired-relay multipath with *unicast* cross transfers (§3.1).

    Each node sends the direct segment on its intra-domain links and issues
    one unicast write PER PEER routed through its partner: three identical
    copies of the cross segment traverse the node->partner link.
    """
    for dom in domains:
        for src in dom:
            direct, cross = _split_payload(payloads[src], split)
            peers = [d for d in dom if d != src]
            for dst in peers:
                sim.write(src, dst, f"ag/{src}/d", direct, step=0)
            partner = partner_of(src, domains)
            # unicast: one write per destination; every copy crosses the
            # src->partner link, then the partner forwards (store&forward).
            for dst in peers:
                sim.write(src, partner, f"relay/{src}/{dst}", cross, step=0)
                sim.write(partner, dst, f"ag/{src}/x", cross, step=0)
                # store-and-forward processing at the relay (rx + tx), kept
                # in the same ledger the MultiWrite recursion feeds:
                sim.relay_bytes[partner] += 2 * int(cross.nbytes)
            sim.memory[src][f"ag/{src}/d"] = np.array(direct)
            sim.memory[src][f"ag/{src}/x"] = np.array(cross)


def allgather_multiwrite(sim: MultiWriteSimulator,
                         domains: Sequence[Sequence[int]],
                         payloads: Sequence[np.ndarray],
                         split: float = 0.5) -> None:
    """Paired-relay multipath with a single cross-TP MultiWrite (§5.2).

    Workflow (paper §5.2 optimized): (1) split each fragment by ``split``;
    (2) three standard unicast writes intra-domain plus ONE MultiWrite whose
    destination set is the three peers, first hop forced through the partner
    (the relay), which replicates — one copy on the bottleneck link.
    """
    for dom in domains:
        for src in dom:
            direct, cross = _split_payload(payloads[src], split)
            peers = [d for d in dom if d != src]
            for dst in peers:
                sim.write(src, dst, f"ag/{src}/d", direct, step=0)
            partner = partner_of(src, domains)
            sim.multiwrite(src, {dst: f"ag/{src}/x" for dst in peers},
                           cross, step=0, relay=partner)
            sim.memory[src][f"ag/{src}/d"] = np.array(direct)
            sim.memory[src][f"ag/{src}/x"] = np.array(cross)


def allgather_full_multipath(sim: MultiWriteSimulator,
                             domains: Sequence[Sequence[int]],
                             payloads: Sequence[np.ndarray],
                             split: float,
                             multicast: bool) -> None:
    """Full multi-path relaying (§3.1): every node in the opposite domain
    relays an equal slice of the cross segment.

    unicast mode:   one write per (relay, destination) — three copies of each
                    slice cross the src->relay link.
    multicast mode: one MultiWrite per relay — one copy per slice crosses.
    """
    for dom in domains:
        other = [d for d in domains if list(d) != list(dom)][0]
        for src in dom:
            direct, cross = _split_payload(payloads[src], split)
            peers = [d for d in dom if d != src]
            for dst in peers:
                sim.write(src, dst, f"ag/{src}/d", direct, step=0)
            # slice the cross segment over all opposite-domain relays
            slices = np.array_split(cross, len(other))
            for ri, relay in enumerate(other):
                sl = slices[ri]
                if sl.size == 0:
                    continue
                if multicast:
                    sim.multiwrite(src, {dst: f"ag/{src}/x{ri}" for dst in peers},
                                   sl, step=0, relay=relay)
                else:
                    for dst in peers:
                        sim.write(src, relay, f"relay/{src}/{dst}/{ri}", sl, step=0)
                        sim.write(relay, dst, f"ag/{src}/x{ri}", sl, step=0)
                        sim.relay_bytes[relay] += 2 * int(sl.nbytes)
            sim.memory[src][f"ag/{src}/d"] = np.array(direct)
            for ri in range(len(other)):
                sl = slices[ri]
                if sl.size:
                    sim.memory[src][f"ag/{src}/x{ri}"] = np.array(sl)


def check_allgather(sim: MultiWriteSimulator,
                    domains: Sequence[Sequence[int]],
                    payloads: Sequence[np.ndarray]) -> None:
    """Assert every node holds every domain-peer's full fragment."""
    for dom in domains:
        for node in dom:
            for src in dom:
                got = [v for k, v in sorted(sim.memory[node].items())
                       if k.startswith(f"ag/{src}")]
                assert got, f"node {node} missing fragment {src}"
                np.testing.assert_array_equal(np.concatenate(got), payloads[src])


# ---------------------------------------------------------------------------
# AlltoAll dispatch schedules (§3.2, §6.3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DispatchRouting:
    """MoE dispatch routing decisions for one batch.

    token_owner[t]   source NPU of token t
    token_dests[t]   sorted list of destination NPUs (expert owners) — the
                     per-token destination SET the bitmap metadata encodes.
    """
    token_owner: np.ndarray          # [T] int
    token_dests: list[list[int]]     # [T][<=k]


def make_routing(num_tokens_per_npu: int, num_npus: int, num_experts: int,
                 top_k: int, seed: int,
                 experts_per_npu: int | None = None,
                 skew: float = 0.0) -> DispatchRouting:
    """Random top-k routing, experts round-robin across NPUs.

    ``skew == 0`` is balanced (paper §6.1: 'expert load balancing is
    enabled').  ``skew > 0`` draws each token's experts from a Zipf-like
    popularity law p_e ∝ (e+1)^-skew — hot experts concentrate traffic on
    their owning NPUs (and rails), the imbalanced-MoE regime the planner
    prices through the scenario's ``skew`` knob."""
    if experts_per_npu is None:
        experts_per_npu = num_experts // num_npus
    assert experts_per_npu * num_npus == num_experts
    rng = np.random.default_rng(seed)
    owners = np.repeat(np.arange(num_npus), num_tokens_per_npu)
    probs = None
    if skew > 0.0:
        w = (np.arange(num_experts) + 1.0) ** -float(skew)
        probs = w / w.sum()
    dests: list[list[int]] = []
    for _ in owners:
        experts = rng.choice(num_experts, size=top_k, replace=False, p=probs)
        npus = sorted(set(int(e) // experts_per_npu for e in experts))
        dests.append(npus)
    return DispatchRouting(owners, dests)


def dispatch_unicast(sim: MultiWriteSimulator, routing: DispatchRouting,
                     token_bytes: int) -> None:
    """Baseline dispatch: one unicast write per (token, destination NPU).

    Under the rail-first forwarding table of :func:`two_server_cluster`,
    each remote-server copy crosses the source's rail link — k_remote
    redundant copies of the same token on the bottleneck (§3.2, Table 1
    'w/ redundant').
    """
    for t, (src, dests) in enumerate(zip(routing.token_owner, routing.token_dests)):
        payload = _token_payload(t, token_bytes)
        for dst in dests:
            if dst == int(src):
                sim.memory[dst][f"tok/{t}"] = payload
            else:
                sim.write(int(src), dst, f"tok/{t}", payload, step=0)


def dispatch_multiwrite(sim: MultiWriteSimulator, routing: DispatchRouting,
                        token_bytes: int) -> None:
    """MultiWrite dispatch (§3.2): ONE MultiWrite per token.

    ``partition_by_next_hop`` over the rail-first table groups all
    destinations on a remote server under the same-index relay, so exactly
    one copy crosses the rail; the relay replicates intra-server.
    """
    for t, (src, dests) in enumerate(zip(routing.token_owner, routing.token_dests)):
        payload = _token_payload(t, token_bytes)
        sim.multiwrite(int(src), {d: f"tok/{t}" for d in dests}, payload, step=0)


def _token_payload(token_id: int, token_bytes: int) -> np.ndarray:
    rng = np.random.default_rng(token_id + 1)
    return rng.integers(0, 256, size=token_bytes, dtype=np.uint8)


# ---------------------------------------------------------------------------
# AlltoAll combine schedules (the return path — dual of dispatch)
# ---------------------------------------------------------------------------

def combine_unicast(sim: MultiWriteSimulator, routing: DispatchRouting,
                    token_bytes: int) -> None:
    """Baseline combine: every expert NPU returns its weighted partial to
    the token owner individually — one rail crossing per (token, remote
    holder), the redundant-return dual of :func:`dispatch_unicast`.

    Unlike unicast dispatch (whose k copies all leave on the SOURCE's
    rail), unicast-combine crossings leave on each *holder's* rail, so
    the redundancy is spread across rails — which is exactly why the
    combine crossover sits at a different payload than dispatch and must
    be planned independently.
    """
    for t, (src, dests) in enumerate(zip(routing.token_owner,
                                         routing.token_dests)):
        src = int(src)
        payload = _token_payload(t, token_bytes)
        for d in dests:
            if d == src:
                sim.memory[src][f"par/{t}/{d}"] = payload
            else:
                sim.write(d, src, f"par/{t}/{d}", payload, step=0)


def combine_multiwrite(sim: MultiWriteSimulator, routing: DispatchRouting,
                       token_bytes: int) -> None:
    """Relay-reduced combine (mirror of :func:`dispatch_multiwrite`).

    Per (token, dispatch relay group): the holders forward their partials
    intra-server to the rail relay the dispatch replicated from; the
    relay REDUCES them (AICPU software data plane, like the dispatch
    relay's replication) and sends ONE reduced partial back across the
    rail.  The relay groups come from ``partition_by_next_hop`` on the
    OWNER's forwarding table — the same lookup the dispatch MultiWrite
    performs, so the two directions stripe identically by construction.
    Under a symmetric fabric the resulting link ledger is the exact
    reverse of the dispatch-multiwrite ledger.
    """
    topo = sim.topo
    for t, (src, dests) in enumerate(zip(routing.token_owner,
                                         routing.token_dests)):
        src = int(src)
        payload = _token_payload(t, token_bytes)
        nbytes = int(payload.nbytes)
        local = [d for d in dests if topo.server_of(d) == topo.server_of(src)]
        remote = [d for d in dests if topo.server_of(d) != topo.server_of(src)]
        for d in local:
            if d == src:
                sim.memory[src][f"par/{t}/{d}"] = payload
            else:
                sim.write(d, src, f"par/{t}/{d}", payload, step=0)
        for relay, ds in sorted(topo.partition_by_next_hop(src,
                                                           remote).items()):
            for d in ds:
                if d != relay:
                    sim.write(d, relay, f"red/{t}/{relay}/{d}", payload,
                              step=0)
                sim.relay_bytes[relay] += nbytes     # reduce: rx processing
            sim.relay_tx_bytes[relay] += nbytes      # reduced-partial egress
            sim.write(relay, src, f"par/{t}/{relay}", payload, step=0)


def check_combine(sim: MultiWriteSimulator, routing: DispatchRouting,
                  token_bytes: int) -> None:
    """Every owner received at least one partial per remote server holding
    its token, and one per local holder, all bit-exact."""
    topo = sim.topo
    for t, (src, dests) in enumerate(zip(routing.token_owner,
                                         routing.token_dests)):
        src = int(src)
        expect = _token_payload(t, token_bytes)
        got = [v for k, v in sim.memory[src].items()
               if k.startswith(f"par/{t}/")]
        servers = {topo.server_of(d) for d in dests}
        assert len(got) >= len(servers), (t, len(got), servers)
        for v in got:
            np.testing.assert_array_equal(v, expect)


def check_dispatch(sim: MultiWriteSimulator, routing: DispatchRouting,
                   token_bytes: int) -> None:
    """Every destination received exactly its tokens, bit-exact, once."""
    for t, dests in enumerate(routing.token_dests):
        expect = _token_payload(t, token_bytes)
        for d in dests:
            np.testing.assert_array_equal(sim.memory[d][f"tok/{t}"], expect)
            assert sim.delivery_count[(d, f"tok/{t}")] <= 1 or \
                int(routing.token_owner[t]) == d
    # no token delivered anywhere it was not routed
    for (node, buf), cnt in sim.delivery_count.items():
        if buf.startswith("tok/"):
            t = int(buf.split("/")[1])
            assert node in routing.token_dests[t], \
                f"token {t} spuriously delivered to {node}"


# ---------------------------------------------------------------------------
# Optimal split ratios (paper §5.2 step (1))
# ---------------------------------------------------------------------------

def optimal_split(scheme: str, num_relays: int = 1) -> float:
    """Fraction of the fragment to send on the direct path so both paths
    finish simultaneously (per-link serialization, uniform link bw ``w``).

    Derivations (§3.1, fragment size s, TP=4 so 3 peers):

    baseline              direct only                          -> 1.0
    unicast paired        direct r*s/w  == cross 3(1-r)s/w     -> r = 3/4
    multiwrite paired     direct r*s/w  == cross (1-r)s/w      -> r = 1/2
    unicast full          cross link carries 3p + 3p' = 6(1-r)s/4
                          (3 copies up per relay slice, 3 relayed-in slices)
                          r = 6(1-r)/4                         -> r = 3/5
    multiwrite full       cross link carries p + 3p' = 4(1-r)s/4
                          r = (1-r)                            -> r = 1/2

    Schemes registered by later PRs without an entry here fall back to
    their plan's declared knob seed (head of the split grid).
    """
    table = {
        "baseline": 1.0,
        "unicast_paired": 0.75,
        "multiwrite_paired": 0.5,
        "unicast_full": 0.6,
        "multiwrite_full": 0.5,
    }
    if scheme in table:
        return table[scheme]
    plan = plan_ir.PLAN_REGISTRY.get(("allgather", scheme))
    if plan is not None and "split" in plan.knobs:
        return plan.knobs["split"][0]
    raise KeyError(scheme)


# ---------------------------------------------------------------------------
# Plan registration: every scheme becomes a CollectivePlan in the registry
# ---------------------------------------------------------------------------

_AG_DRIVERS: dict[str, Callable] = {
    # scheme -> driver(sim, domains, payloads, split)
    "baseline": lambda sim, dom, pay, split: allgather_baseline(
        sim, dom, pay),
    "unicast_paired": allgather_unicast_multipath,
    "multiwrite_paired": allgather_multiwrite,
    "unicast_full": lambda sim, dom, pay, split: allgather_full_multipath(
        sim, dom, pay, split, multicast=False),
    "multiwrite_full": lambda sim, dom, pay, split: allgather_full_multipath(
        sim, dom, pay, split, multicast=True),
}


def register_allgather_driver(scheme: str, driver: Callable) -> None:
    """Legacy-driver hook for schemes registered by later PRs: makes the
    scheme callable through ALLGATHER_SCHEMES / run_allgather_scheme in
    addition to the plan registry."""
    _AG_DRIVERS[scheme] = driver


def run_allgather_scheme(scheme: str, sim: MultiWriteSimulator,
                         domains: Sequence[Sequence[int]],
                         payloads: Sequence[np.ndarray],
                         split: float | None = None) -> None:
    """Drive one AllGather scheme at its (or an explicit) split ratio."""
    if scheme not in _AG_DRIVERS:
        plan_ir.get_plan("allgather", scheme)   # raise if truly unknown
        raise KeyError(
            f"scheme {scheme!r} is registered as a plan but has no "
            f"simulator driver; add one via register_allgather_driver()")
    if split is None:
        split = optimal_split(scheme)
    _AG_DRIVERS[scheme](sim, domains, payloads, split)


def _split_grid(scheme: str, steps=(0.0, -0.125, 0.125, -0.25, 0.25)
                ) -> tuple[float, ...]:
    """Knob grid seeded on the §5.2 analytic optimum (seed listed first;
    1.0 excluded for relayed schemes — that degenerates to baseline)."""
    seed = optimal_split(scheme)
    grid = []
    for d in steps:
        v = round(min(0.96875, max(0.125, seed + d)), 5)
        if v not in grid:
            grid.append(v)
    return tuple(grid)


def _simulate_allgather(scheme: str):
    def simulate(scenario: plan_ir.AllGatherScenario, payload_bytes: float,
                 *, split: float) -> plan_ir.Ledger:
        probe = plan_ir.PROBE_FRAG_BYTES
        sim = MultiWriteSimulator(scenario.topo)
        payloads = [np.arange(probe, dtype=np.uint8) % 251
                    for _ in range(scenario.topo.num_nodes)]
        _AG_DRIVERS[scheme](sim, [list(d) for d in scenario.domains],
                            payloads, split)
        ledger = plan_ir.Ledger.from_sim(sim)
        return ledger.scaled(plan_ir.probe_scale(payload_bytes, probe))
    return simulate


def _ag_kwargs(mode):
    def kwargs_fn(*, split: float) -> dict:
        # what collectives.multiwrite_allgather / allgather_reference take
        return {"mode": mode, "split": (1.0 if mode is None else split)}
    return kwargs_fn


for _scheme, _mode, _exec in [
        ("baseline", None, True),
        ("unicast_paired", None, False),     # no shard_map lowering: paper
        ("multiwrite_paired", "paired", True),
        ("unicast_full", None, False),       # comparison schemes only
        ("multiwrite_full", "full", True),
]:
    plan_ir.register_plan(plan_ir.CollectivePlan(
        name=_scheme, op="allgather",
        knobs=({"split": (1.0,)} if _scheme == "baseline"
               else {"split": _split_grid(_scheme)}),
        simulate_fn=_simulate_allgather(_scheme),
        kwargs_fn=_ag_kwargs(_mode),
        executable=_exec))


@functools.lru_cache(maxsize=128)
def _moe_base_ledger(topo, num_experts: int, top_k: int, seed: int,
                     skew: float, probe_batch: int, op: str,
                     multiwrite: bool) -> plan_ir.Ledger:
    """Unscaled single-chunk ledger of one dispatch/combine probe run —
    cached so the microbatch knob sweep (which only re-labels stages and
    re-scales bytes) never re-runs the packet simulator.  Keyed on the
    fields the simulation actually reads (NOT the whole scenario:
    ``compute_s`` varies per batch and would fragment the cache across
    operating points that share one probe run).  Topologies hash by
    identity."""
    n_npus = topo.num_nodes
    if num_experts % n_npus:
        per_npu = max(1, num_experts // n_npus)
        num_experts = per_npu * n_npus
        top_k = min(top_k, num_experts)
    sim = MultiWriteSimulator(topo)
    routing = make_routing(probe_batch, n_npus, num_experts, top_k,
                           seed=seed, skew=skew)
    if op == "dispatch":
        fn = dispatch_multiwrite if multiwrite else dispatch_unicast
    else:
        fn = combine_multiwrite if multiwrite else combine_unicast
    fn(sim, routing, plan_ir.PROBE_TOKEN_BYTES)
    from .latency_model import RELAY_SETUP_S
    ledger = plan_ir.Ledger.from_sim(
        sim, alpha_extra_s=RELAY_SETUP_S if multiwrite else 0.0)
    if multiwrite:
        # the relay forwards (dispatch: replicates; combine: reduces) in
        # SOFTWARE (§6.4 AICPU data plane): its egress copies serialize
        # through one engine — the term that makes Fig 8's small-batch
        # unicast preference emerge (cf. dispatch_e2e_time's relay_fwd)
        ledger = dataclasses.replace(
            ledger, engine_serial=dict(sim.relay_tx_bytes))
    return ledger


def _simulate_moe(op: str, multiwrite: bool):
    def simulate(scenario, payload_bytes: float,
                 *, microbatch: int = 1) -> plan_ir.Ledger:
        batch = max(1, int(round(payload_bytes / scenario.token_bytes)))
        probe_batch = min(batch, plan_ir.PROBE_BATCH)
        ledger = _moe_base_ledger(scenario.topo, scenario.num_experts,
                                  scenario.top_k, scenario.seed,
                                  scenario.skew, probe_batch, op,
                                  multiwrite)
        probe_bytes = probe_batch * plan_ir.PROBE_TOKEN_BYTES
        ledger = ledger.scaled(
            plan_ir.probe_scale(batch * scenario.token_bytes, probe_bytes))
        g = max(1, int(microbatch))
        # G > 1 is the double-buffered moe_ffn pipeline (overlap=True):
        # scoring pays max(stage) + (G-1)*bottleneck derated by
        # hw.overlap_eff instead of the serial G*sum.  compute_s is the
        # scenario's expert-FFN stage the chunks hide behind (charged to
        # G == 1 too, so the comparison is apples-to-apples).
        return dataclasses.replace(
            ledger, stages=g, overlap=g > 1,
            compute_s=float(getattr(scenario, "compute_s", 0.0)))
    return simulate


def _simulate_dispatch(multiwrite: bool):
    return _simulate_moe("dispatch", multiwrite)


def _dispatch_kwargs(scheme: str):
    def kwargs_fn(*, microbatch: int = 1) -> dict:
        # what models/moe.moe_ffn consumes (pctx-level knobs)
        return {"moe_scheme": scheme, "microbatch": int(microbatch)}
    return kwargs_fn


# The microbatch grid (G = pipeline chunks, mapping onto
# pctx.moe_microbatch).  The latency model's pipelined scoring mode
# (score_ledger on overlap=True ledgers) lets G > 1 genuinely win when
# the scenario carries an overlap context (compute_s > 0): chunked
# dispatch hides behind the previous chunk's expert FFN.  Without
# overlap context the per-chunk alpha keeps G == 1 optimal — the grid
# head — so scenario-free sweeps behave exactly as before.  Powers of
# two only: moe_ffn clamps the chosen G to a divisor of the local token
# count via gcd, and pow-2 G always divides pow-2 batches.
MICROBATCH_GRID = (1, 2, 4, 8)

plan_ir.register_plan(plan_ir.CollectivePlan(
    name="unicast", op="dispatch",
    knobs={"microbatch": MICROBATCH_GRID},
    simulate_fn=_simulate_dispatch(multiwrite=False),
    kwargs_fn=_dispatch_kwargs("baseline")))
plan_ir.register_plan(plan_ir.CollectivePlan(
    name="multiwrite", op="dispatch",
    knobs={"microbatch": MICROBATCH_GRID},
    simulate_fn=_simulate_dispatch(multiwrite=True),
    kwargs_fn=_dispatch_kwargs("hierarchical")))


def _simulate_combine(multiwrite: bool):
    return _simulate_moe("combine", multiwrite)


def _combine_kwargs(scheme: str):
    def kwargs_fn(*, microbatch: int = 1) -> dict:
        # what models/moe.moe_ffn consumes (return-path lowering selector)
        return {"moe_combine": scheme, "microbatch": int(microbatch)}
    return kwargs_fn


def _simulate_linkprobe(scenario, payload_bytes: float) -> plan_ir.Ledger:
    """Ledger of the directed p2p microbenchmark: the payload on every
    link from ``src_server`` to ``dst_server`` at once (and nothing
    else), so the record's bottleneck ROLE is exactly that direction and
    the telemetry fitter regresses its bandwidth even though no real
    collective ever bottlenecks there."""
    topo = scenario.topo
    links = [k for k in topo.links
             if topo.server_of(k[0]) == scenario.src_server
             and topo.server_of(k[1]) == scenario.dst_server]
    if not links:
        raise ValueError(
            f"no links {scenario.src_server}->{scenario.dst_server} "
            f"in {topo.name}")
    return plan_ir.Ledger(
        topo=topo,
        link_bytes={k: float(payload_bytes) for k in links},
        relay_bytes={}, flow_counts={k: 1 for k in links})


plan_ir.register_plan(plan_ir.CollectivePlan(
    name="p2p", op="linkprobe", knobs={},
    simulate_fn=_simulate_linkprobe,
    kwargs_fn=lambda **kw: {}))


plan_ir.register_plan(plan_ir.CollectivePlan(
    name="unicast", op="combine",
    knobs={"microbatch": MICROBATCH_GRID},
    simulate_fn=_simulate_combine(multiwrite=False),
    kwargs_fn=_combine_kwargs("baseline")))
plan_ir.register_plan(plan_ir.CollectivePlan(
    name="multiwrite", op="combine",
    knobs={"microbatch": MICROBATCH_GRID},
    simulate_fn=_simulate_combine(multiwrite=True),
    kwargs_fn=_combine_kwargs("hierarchical")))


# ---------------------------------------------------------------------------
# Gradient-sync schedules: AllReduce / ReduceScatter as planner ops
# ---------------------------------------------------------------------------
#
# Unlike the MoE ops (whose routing is data-dependent, so their ledgers
# come from the packet simulator), reduce collectives are fully regular:
# every node holds the same payload and the schedule is a fixed
# communication pattern.  The ledgers below are therefore built
# ANALYTICALLY — closed-form per-link byte loads charged onto the real
# fabric links (via ``topo.path`` so missing direct links store-and-
# forward exactly like the packet oracle would) — which keeps the
# planner sweep free of per-payload simulation.  Byte loads and step
# counts follow the classic scheme family (ring, recursive-doubling
# tree, hierarchical RS->exchange->AG; cf. "Network-Offloaded
# Bandwidth-Optimal Broadcast and Allgather" / "In-Network Collective
# Operations", PAPERS.md), plus a multiwrite variant that reuses the
# combine-wire reduce-direction accounting (relay-side reduction, one
# copy per rail, software-engine egress serialization).

# Per-ring/tree-step launch cost beyond the generic per-stage alpha_base
# (one step is covered by alpha_base itself; the rest land here).  A
# fraction of alpha_base: steps within one fused collective don't re-pay
# the full operator launch, just the per-round synchronization.
REDUCE_STEP_ALPHA_S = 5e-6


def _reduce_step_alpha(steps: int) -> float:
    return max(0, int(steps) - 1) * REDUCE_STEP_ALPHA_S


def _charge_path(topo: Topology, link_bytes: dict, flow_counts: dict,
                 relay_bytes: dict, src: int, dst: int,
                 nbytes: float) -> None:
    """Charge ``nbytes`` from src to dst along the fabric's forwarding
    path; intermediate hops pay store-and-forward relay processing."""
    path = topo.path(src, dst)
    for a, b in zip(path, path[1:]):
        link_bytes[(a, b)] = link_bytes.get((a, b), 0.0) + nbytes
        flow_counts[(a, b)] = flow_counts.get((a, b), 0) + 1
    for mid in path[1:-1]:
        relay_bytes[mid] = relay_bytes.get(mid, 0.0) + 2.0 * nbytes


def _ring_order(topo: Topology) -> list[int]:
    """Serpentine node order: ascend even servers, descend odd ones, so
    every intra hop is a full-mesh link and every server boundary is
    crossed at a matching NPU index (a direct rail link)."""
    meta = topo.meta
    order: list[int] = []
    for s in range(meta.num_servers):
        idx = (range(meta.npus_per_server) if s % 2 == 0
               else range(meta.npus_per_server - 1, -1, -1))
        order.extend(s * meta.npus_per_server + i for i in idx)
    return order


def reduce_ring_ledger(topo: Topology, nbytes: float,
                       phases: int = 2) -> plan_ir.Ledger:
    """Flat bandwidth-optimal ring: ``phases == 2`` is AllReduce
    (reduce-scatter pass + allgather pass), ``phases == 1`` is
    ReduceScatter alone.  Every directed ring edge carries
    ``phases * (R-1)/R * N``; the whole load crosses every server
    boundary — which is exactly why the flat ring (what an unannotated
    GSPMD psum lowers to) is the scheme to beat on multi-server
    fabrics."""
    R = topo.num_nodes
    if R < 2:
        return plan_ir.Ledger(topo=topo, link_bytes={}, relay_bytes={},
                              flow_counts={})
    per_edge = float(phases) * nbytes * (R - 1) / R
    order = _ring_order(topo)
    link_bytes: dict = {}
    flows: dict = {}
    relay: dict = {}
    for u, v in zip(order, order[1:] + order[:1]):
        _charge_path(topo, link_bytes, flows, relay, u, v, per_edge)
    return plan_ir.Ledger(
        topo=topo, link_bytes=link_bytes, relay_bytes=relay,
        flow_counts=flows, relayed=bool(relay),
        alpha_extra_s=_reduce_step_alpha(phases * (R - 1)))


def reduce_tree_depth(topo: Topology) -> int:
    """Rounds of the dimension-ordered recursive-doubling tree:
    ``ceil(log2 P)`` intra rounds then ``ceil(log2 S)`` inter rounds
    (non-power-of-two counts round up — stragglers fold in)."""
    meta = topo.meta
    intra = (int(math.ceil(math.log2(meta.npus_per_server)))
             if meta.npus_per_server > 1 else 0)
    inter = (int(math.ceil(math.log2(meta.num_servers)))
             if meta.num_servers > 1 else 0)
    return intra + inter


def reduce_tree_ledger(topo: Topology, nbytes: float) -> plan_ir.Ledger:
    """Recursive-doubling butterfly tree: every round each node
    exchanges the FULL payload with its XOR partner and reduces —
    log-depth, so it is the latency-optimal endpoint of the scheme
    family (the bandwidth-optimal halving/doubling variant coincides
    with ``hierarchical``'s byte accounting on these fabrics).  Rounds
    serialize through each node's NIC, so the cumulative per-class load
    (``intra_rounds * N`` intra, ``inter_rounds * N`` on the rails) is
    charged onto one representative link per class."""
    meta = topo.meta
    S, P = meta.num_servers, meta.npus_per_server
    intra_rounds = int(math.ceil(math.log2(P))) if P > 1 else 0
    inter_rounds = int(math.ceil(math.log2(S))) if S > 1 else 0
    link_bytes: dict = {}
    flows: dict = {}
    relay: dict = {}
    for s in range(S):
        for i in range(P):
            u = s * P + i
            if intra_rounds:
                v = s * P + (i + 1) % P
                _charge_path(topo, link_bytes, flows, relay, u, v,
                             intra_rounds * nbytes)
            if inter_rounds:
                v = ((s + 1) % S) * P + i
                _charge_path(topo, link_bytes, flows, relay, u, v,
                             inter_rounds * nbytes)
    return plan_ir.Ledger(
        topo=topo, link_bytes=link_bytes, relay_bytes=relay,
        flow_counts=flows, relayed=bool(relay),
        alpha_extra_s=_reduce_step_alpha(reduce_tree_depth(topo)))


def reduce_hierarchical_ledger(topo: Topology, nbytes: float,
                               phases: int = 2) -> plan_ir.Ledger:
    """Hierarchical reduce: intra-server ring ReduceScatter, inter-server
    ring exchange of the 1/P shard over same-index rail peers, then
    (``phases == 2``) intra-server ring AllGather.  Rail links carry only
    ``2 (S-1)/S * N/P`` — the P-fold cross-server saving over the flat
    ring.  Degrades to the intra ring alone on single-server fabrics."""
    meta = topo.meta
    S, P = meta.num_servers, meta.npus_per_server
    link_bytes: dict = {}
    flows: dict = {}
    relay: dict = {}
    steps = 0
    shard = nbytes / P if P > 1 else nbytes
    if P > 1:
        per_edge = float(phases) * nbytes * (P - 1) / P
        for s in range(S):
            order = [s * P + i for i in range(P)]
            for u, v in zip(order, order[1:] + order[:1]):
                _charge_path(topo, link_bytes, flows, relay, u, v, per_edge)
        steps += phases * (P - 1)
    if S > 1:
        per_edge = 2.0 * shard * (S - 1) / S
        for i in range(P):
            order = [s * P + i for s in range(S)]
            for u, v in zip(order, order[1:] + order[:1]):
                _charge_path(topo, link_bytes, flows, relay, u, v, per_edge)
        steps += 2 * (S - 1)
    return plan_ir.Ledger(
        topo=topo, link_bytes=link_bytes, relay_bytes=relay,
        flow_counts=flows, relayed=bool(relay),
        alpha_extra_s=_reduce_step_alpha(steps))


def reduce_multiwrite_ledger(topo: Topology, nbytes: float,
                             scatter_only: bool = False) -> plan_ir.Ledger:
    """MultiWrite reduce: the combine-wire reduce-direction accounting
    applied to gradient sync.  The payload is sliced 1/P by NPU index;
    slice ``i``'s peers funnel it intra-server to relay ``i``, the relay
    REDUCES (AICPU software data plane, like combine_multiwrite) and
    exchanges ONE reduced copy per rail with its same-index peers, then
    replicates the global slice back intra-server (AllReduce) or
    scatters the 1/R sub-slices (ReduceScatter).  Relay rx processing
    lands in ``relay_bytes``; relay egress serializes through one
    forwarding engine (``engine_serial``), and the schedule pays the
    Fig 8 relay-pipeline establishment cost."""
    from .latency_model import RELAY_SETUP_S
    meta = topo.meta
    S, P = meta.num_servers, meta.npus_per_server
    R = topo.num_nodes
    slice_b = nbytes / P
    link_bytes: dict = {}
    flows: dict = {}
    relay: dict = {}
    engine: dict = {}

    def charge(u, v, b):
        _charge_path(topo, link_bytes, flows, relay, u, v, b)

    for s in range(S):
        for i in range(P):
            r = s * P + i                      # relay owning slice i
            for j in range(P):                 # intra funnel j -> relay
                if j != i:
                    charge(s * P + j, r, slice_b)
            relay[r] = relay.get(r, 0.0) + (P - 1) * slice_b
            if S > 1:                          # rail exchange, one copy each
                for s2 in range(S):
                    if s2 != s:
                        charge(r, s2 * P + i, slice_b)
                relay[r] += (S - 1) * slice_b
            egress = (S - 1) * slice_b
            if scatter_only:                   # scatter 1/R sub-slices back
                for j in range(P):
                    if j != i:
                        charge(r, s * P + j, nbytes / R)
                egress += (P - 1) * nbytes / R
            else:                              # replicate global slice back
                for j in range(P):
                    if j != i:
                        charge(r, s * P + j, slice_b)
                egress += (P - 1) * slice_b
            engine[r] = engine.get(r, 0.0) + egress
    return plan_ir.Ledger(
        topo=topo, link_bytes=link_bytes, relay_bytes=relay,
        flow_counts=flows, relayed=True, alpha_extra_s=RELAY_SETUP_S,
        engine_serial=engine)


def reduce_scatter_a2a_ledger(topo: Topology, nbytes: float
                              ) -> plan_ir.Ledger:
    """Direct AlltoAll ReduceScatter: every node sends each peer its
    1/R shard in one step (latency-optimal; redundant-free by
    construction).  Cross-server transfers to non-matching indices
    store-and-forward through the rail-first table, and the per-link
    flow fan-in drives the interference derate."""
    R = topo.num_nodes
    link_bytes: dict = {}
    flows: dict = {}
    relay: dict = {}
    shard = nbytes / R
    for u in range(R):
        for v in range(R):
            if u != v:
                _charge_path(topo, link_bytes, flows, relay, u, v, shard)
    return plan_ir.Ledger(
        topo=topo, link_bytes=link_bytes, relay_bytes=relay,
        flow_counts=flows, relayed=bool(relay))


_REDUCE_LEDGERS: dict[tuple[str, str], Callable] = {
    # (op, scheme) -> builder(topo, nbytes)
    ("allreduce", "ring"):
        lambda topo, n: reduce_ring_ledger(topo, n, phases=2),
    ("allreduce", "tree"): reduce_tree_ledger,
    ("allreduce", "hierarchical"):
        lambda topo, n: reduce_hierarchical_ledger(topo, n, phases=2),
    ("allreduce", "multiwrite"):
        lambda topo, n: reduce_multiwrite_ledger(topo, n),
    ("allreduce", "compressed"):
        # int8 error-feedback ring (compression.compressed_psum): wire
        # bytes quartered, same step structure.  Lossy — registered for
        # comparison sweeps, never auto-bound (executable=False).
        lambda topo, n: reduce_ring_ledger(topo, n / 4.0, phases=2),
    ("reduce_scatter", "ring"):
        lambda topo, n: reduce_ring_ledger(topo, n, phases=1),
    ("reduce_scatter", "a2a"): reduce_scatter_a2a_ledger,
    ("reduce_scatter", "multiwrite"):
        lambda topo, n: reduce_multiwrite_ledger(topo, n,
                                                 scatter_only=True),
}


def _simulate_reduce(op: str, scheme: str):
    builder = _REDUCE_LEDGERS[(op, scheme)]

    def simulate(scenario, payload_bytes: float,
                 *, microbatch: int = 1) -> plan_ir.Ledger:
        ledger = builder(scenario.topo, float(payload_bytes))
        g = max(1, int(microbatch))
        # G > 1 chunks the gradient into G buckets synced back-to-front
        # as the backward pass produces them (overlap=True): the
        # pipelined scoring mode hides earlier chunks' wire time behind
        # the scenario's remaining backward compute, exactly like the
        # MoE dispatch pipeline.
        return dataclasses.replace(
            ledger, stages=g, overlap=g > 1,
            compute_s=float(getattr(scenario, "compute_s", 0.0)))
    return simulate


def _reduce_kwargs(scheme: str):
    def kwargs_fn(*, microbatch: int = 1) -> dict:
        # what collectives.planned_psum consumes
        return {"reduce_scheme": scheme, "microbatch": int(microbatch)}
    return kwargs_fn


for _op, _scheme, _exec in [
        ("allreduce", "ring", True),          # lax.psum's own lowering
        ("allreduce", "tree", True),          # ppermute butterfly
        ("allreduce", "hierarchical", True),  # hierarchical_psum
        ("allreduce", "multiwrite", True),    # hierarchical_psum lowering
        ("allreduce", "compressed", False),   # lossy: explicit opt-in only
        ("reduce_scatter", "ring", True),     # lax.psum_scatter
        ("reduce_scatter", "a2a", True),      # lax.psum_scatter tiled
        ("reduce_scatter", "multiwrite", False),   # accounting-only
]:
    plan_ir.register_plan(plan_ir.CollectivePlan(
        name=_scheme, op=_op,
        knobs={"microbatch": MICROBATCH_GRID},
        simulate_fn=_simulate_reduce(_op, _scheme),
        kwargs_fn=_reduce_kwargs(_scheme),
        executable=_exec))


class _SchemeView(dict):
    """Back-compat view: ALLGATHER_SCHEMES[name](sim, domains, payloads)
    runs the registered plan's driver at its analytic-seed split."""

    def __missing__(self, key):
        plan_ir.get_plan("allgather", key)   # raises with a useful message
        return lambda sim, dom, pay: run_allgather_scheme(key, sim, dom, pay)


ALLGATHER_SCHEMES: dict[str, Callable] = _SchemeView()
for _scheme in _AG_DRIVERS:
    ALLGATHER_SCHEMES[_scheme] = (
        lambda sim, dom, pay, _s=_scheme: run_allgather_scheme(
            _s, sim, dom, pay))
