"""α–β bottleneck-link latency model (paper §3, §6).

The model maps a schedule's per-link byte ledger (from
:class:`~repro.core.multiwrite.MultiWriteSimulator`) — or closed-form byte
counts — to end-to-end operator latency:

    t = alpha_base                         (operator startup, API->first byte)
      + max_link (bytes_link / bw_link)    (per-link serialization; concurrent
                                            links overlap — the *bottleneck
                                            link* sets the pace, paper §3.3)
      + [alpha_hop]                        (pipeline-fill cost of one relay
                                            stage, if the schedule relays)
      + max_node (relay_bytes / copy_bw)   (relay-side replication processing:
                                            the paper's AICPU packet
                                            copy/forward cost, §6.4)

Two regimes:

- ``ideal=True``  — zero overheads.  This is the paper's §3.1 derivation
  regime and the model reproduces it EXACTLY:
      baseline s/w | unicast-paired 3s/4w | multiwrite-paired s/2w
      unicast-full 3s/5w | multiwrite-full s/2w
  giving the claimed 50% (mw vs baseline), 33% (mw vs unicast-paired) and
  16.7% (mw vs unicast-full) latency reductions.

- calibrated — finite overheads fitted once against the paper's reported
  endpoints (Fig 6: ~30% at 16 MB; Fig 7: crossover ≈ 2 MB; Table 1), then
  used *predictively* everywhere else.  Calibration constants:

      alpha_base = 20 us   operator launch (warm) — HCCL-class startup
      alpha_hop  = 12 us   relay stage fill: bitmap parse + WQE re-post
      copy_bw    = 800 GB/s relay-node buffer copy (HBM-class memcpy)
      token      = 7168 B  dispatch payload/token (DeepSeek-V3 hidden 7168,
                           fp8 dispatch — the post-V3 regime the paper cites)
      rail_bw    = 25 GB/s 200 Gbps RoCE NIC (§6.1)
      hccs_bw    = 56 GB/s (§6.1)

Checks against the paper (see tests/test_paper_claims.py and
benchmarks/paper_figures.py):

  Fig 6 (16 MB):   model −30.0% vs baseline (paper ≈30%); −22.6% vs unicast
                   multipath (paper 17% — same ordering, within the run
                   variance the paper itself reports for unicast multipath).
  Fig 7:           crossover at ≈1.9 MB (paper: "around 2 MB").
  Table 1:         per-point agreement within ≈12% (w/ redundant) and ≈8%
                   (w/o redundant) across batch 64→2k.
  Fig 8:           qualitative shape reproduced: mw worse at batch 64,
                   ~parity at 128, gains at 1k/2k growing with batch.
"""

from __future__ import annotations

import dataclasses
import math

from .multiwrite import MultiWriteSimulator
from .plan import Ledger
from .topology import HCCS_LINK_BW, ROCE_LINK_BW


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Calibrated overhead constants (seconds / bytes-per-second)."""

    alpha_base: float = 20e-6     # operator startup
    alpha_hop: float = 12e-6      # relay-stage pipeline fill
    copy_bw: float = 800e9        # relay buffer copy bandwidth
    flow_interference: float = 1.0  # <1 derates a link shared by >=3
    # distinct concurrent unicast flows (paper: unicast multipath "more
    # susceptible to mutual interference"); 1.0 = mean behaviour.
    overlap_eff: float = 0.75     # fraction of the theoretical chunk-
    # pipeline overlap actually achieved (1 = perfect dispatch/compute/
    # combine overlap, 0 = chunks serialize).  Seeded conservatively;
    # telemetry fits it from Planner.decision_log measured rows
    # (repro.telemetry.fit.fit_overlap_eff) like the link bandwidths.
    link_bw: tuple = ()           # MEASURED per-link bandwidth overrides
    # (((src, dst), bytes/s), ...) from recalibrated(); scoring prefers a
    # measured value over the topology's nominal one.  Stored as a sorted
    # tuple so the model stays hashable (it keys the planner's LRU cache).

    def ideal(self) -> "HardwareModel":
        return HardwareModel(alpha_base=0.0, alpha_hop=0.0,
                             copy_bw=math.inf, flow_interference=1.0,
                             overlap_eff=1.0)

    def recalibrated(self, measurements, topo=None) -> "HardwareModel":
        """Fold measured numbers back into the model (ROADMAP: online
        re-calibration).  ``measurements`` is a mapping — typically a
        parsed benchmark JSON — with any of the scalar constants
        (``alpha_base``, ``alpha_hop``, ``copy_bw``,
        ``flow_interference``) and/or ``"links"``: measured per-link
        bandwidths keyed by ``(src, dst)`` tuples or ``"src->dst"``
        strings.  Pass ``topo`` to reject measurements for links the
        fabric doesn't have (typo'd keys would otherwise be stored but
        never match a ledger — a silent no-op).  Returns a NEW model;
        since the model is part of the planner cache key, recalibrating
        invalidates stale decisions automatically."""
        measurements = dict(measurements)
        scalars = {k: float(measurements[k])
                   for k in ("alpha_base", "alpha_hop", "copy_bw",
                             "flow_interference", "overlap_eff")
                   if k in measurements}
        links = dict(self.link_bw)
        for key, bw in dict(measurements.get("links", {})).items():
            if isinstance(key, str):
                a, b = key.split("->")
                key = (int(a), int(b))
            key = tuple(key)
            if topo is not None and not topo.has_link(*key):
                raise KeyError(f"measured link {key} not in {topo.name}")
            links[key] = float(bw)
        return dataclasses.replace(
            self, link_bw=tuple(sorted(links.items())), **scalars)

    def measured_link_bw(self) -> dict:
        """The per-link overrides as a plain dict."""
        return dict(self.link_bw)

    def fingerprint(self) -> tuple:
        """Hashable identity of the calibration state.  The planner keys
        its LRU cache on this (not on the object), so an in-place
        ``planner.hw`` swap after :meth:`recalibrated` can never serve a
        decision scored under the old constants — and two value-equal
        models share cache entries."""
        return ("hw", self.alpha_base, self.alpha_hop, self.copy_bw,
                self.flow_interference, self.overlap_eff, self.link_bw)


IDEAL = HardwareModel(alpha_base=0.0, alpha_hop=0.0, copy_bw=math.inf,
                      overlap_eff=1.0)
DEFAULT = HardwareModel()


# ---------------------------------------------------------------------------
# Ledger-driven latency (works for ANY plan / schedule run on the simulator)
# ---------------------------------------------------------------------------

def ledger_wire_s(ledger: Ledger, hw: HardwareModel = DEFAULT) -> float:
    """Full-payload serialization time of one ledger: the bottleneck-link
    transfer plus relay-copy and software-forwarding-engine terms — no
    startup alphas, no compute stage (those are charged separately so the
    shared-pipeline scorer can combine several ledgers without
    double-counting)."""
    if not ledger.link_bytes:
        return 0.0
    measured = dict(hw.link_bw) if hw.link_bw else None
    link_time = 0.0
    for key, nbytes in ledger.link_bytes.items():
        bw = ledger.topo.link(*key).bw
        if measured is not None:
            bw = measured.get(key, bw)
        if ledger.flow_counts.get(key, 0) >= 3:
            bw *= hw.flow_interference
        link_time = max(link_time, nbytes / bw)
    relay_time = 0.0
    if ledger.relay_bytes:
        relay_time = max(ledger.relay_bytes.values()) / hw.copy_bw
    engine_time = 0.0
    for node, nbytes in ledger.engine_serial.items():
        # software forwarding engine (§6.4 AICPU): per-copy egress
        # serializes at the node's fastest egress link
        bw = max((ln.bw for ln in ledger.topo.links.values()
                  if ln.src == node), default=math.inf)
        engine_time = max(engine_time, nbytes / bw)
    return link_time + relay_time + engine_time


def ledger_fixed_s(ledger: Ledger, hw: HardwareModel = DEFAULT) -> float:
    """Payload-independent overheads of one ledger: per-chunk operator
    startup (``alpha_base * G``), schedule-specific setup and the relay
    pipeline-fill alpha."""
    g = max(1, ledger.stages)
    return (hw.alpha_base * g + ledger.alpha_extra_s
            + (hw.alpha_hop if ledger.relayed else 0.0))


def score_ledger(ledger: Ledger, hw: HardwareModel = DEFAULT) -> float:
    """End-to-end latency of any plan's :class:`~repro.core.plan.Ledger`.

    This is THE scoring function of the planner: every registered
    CollectivePlan's simulated ledger runs through the same alpha-beta
    bottleneck model, so plan choice is an emergent property of the
    calibration (Fig 7's ~2 MB crossover falls out of ``alpha_hop`` and
    ``copy_bw`` — nothing scheme-specific is hard-coded here).

    Chunked ledgers (``stages == G > 1``) score in one of two modes:

    * serial (``overlap=False``) — the pre-pipeline chunk loop: G
      startup alphas plus the full wire+compute time, so G > 1 can only
      lose (memory, not latency, was the reason to microbatch).
    * pipelined (``overlap=True``) — dispatch of chunk k+1 overlaps the
      compute of chunk k (``ledger.compute_s``) and the combine of
      chunk k-1: the ideal G-chunk pipeline pays
      ``sum(stage)/G + (G-1) * max(stage)/G`` instead of the serial
      sum, derated by the calibrated ``hw.overlap_eff``.  The per-chunk
      ``alpha_base`` penalty grows linearly in G while the overlap win
      saturates, which is what makes SMALL G optimal.
    """
    if not ledger.link_bytes:
        return 0.0
    wire = ledger_wire_s(ledger, hw)
    g = max(1, ledger.stages)
    fixed = ledger_fixed_s(ledger, hw)
    compute = max(0.0, ledger.compute_s)
    serial = fixed + wire + compute
    if g <= 1 or not ledger.overlap:
        return serial
    eta = min(1.0, max(0.0, hw.overlap_eff))
    w, c = wire / g, compute / g
    pipelined = fixed + w + c + (g - 1) * max(w, c)
    return (1.0 - eta) * serial + eta * pipelined


def score_pipeline(ledgers, hw: HardwareModel = DEFAULT) -> float:
    """Combined latency of COUPLED collectives sharing one chunk pipeline
    (the moe_ffn dispatch -> expert FFN -> combine scan).

    Scoring each half alone and summing would double-count the compute
    stage and — worse — let each half pick its own microbatch G even
    though the executed pipeline chunks everything at ONE G.  This
    scorer is the shared-pipeline ledger of the joint sweep: every
    ledger's wire time is a pipeline stage, the (shared) compute stage
    is charged once, per-chunk alphas accumulate across ALL coupled
    collectives (G chunks now pay dispatch + combine startup each), and
    the pipelined bound pays ``sum(stage)/G + (G-1) * max(stage)/G``
    over the full stage set, derated by ``hw.overlap_eff`` exactly like
    :func:`score_ledger`.  All ledgers must agree on ``stages``; a
    single-ledger call reduces to :func:`score_ledger`.
    """
    ledgers = [l for l in ledgers if l.link_bytes]
    if not ledgers:
        return 0.0
    gs = {max(1, l.stages) for l in ledgers}
    if len(gs) != 1:
        raise ValueError(f"coupled ledgers disagree on chunk count: {gs}")
    g = gs.pop()
    wires = [ledger_wire_s(l, hw) for l in ledgers]
    fixed = sum(ledger_fixed_s(l, hw) for l in ledgers)
    # the compute stage BETWEEN the coupled collectives is one shared
    # quantity carried redundantly by each scenario — charge it once
    compute = max([0.0] + [l.compute_s for l in ledgers])
    serial = fixed + sum(wires) + compute
    if g <= 1 or not all(l.overlap for l in ledgers):
        return serial
    eta = min(1.0, max(0.0, hw.overlap_eff))
    per_chunk = [w / g for w in wires] + [compute / g]
    pipelined = fixed + sum(per_chunk) + (g - 1) * max(per_chunk)
    return (1.0 - eta) * serial + eta * pipelined


# ---------------------------------------------------------------------------
# Phase-level contention: the multi-commodity-flow view of one program phase
# ---------------------------------------------------------------------------
#
# Sites declared concurrent within one program phase (the MoE round trip
# and the grad-sync AllReduce of a training step; the collectives of one
# serving phase) put their bytes on the SAME physical links.  Scoring each
# site on its private ledger treats every rail as dedicated — two plans
# that each look fastest alone can saturate one shared rail together.
# The flow formulation ("Rethinking ML Collective Communication as a
# Multi-Commodity Flow Problem"): per-link demand SUMS across concurrent
# flows, and the phase pays the bottleneck of the summed demand.

def merge_ledgers(ledgers) -> tuple[Ledger, ...]:
    """Phase ledger(s): per-link bytes, flow counts, relay bytes and
    forwarding-engine bytes SUMMED across ``ledgers`` — the joint demand
    of sites concurrent in one phase.  Ledgers merge per fabric (one
    merged ledger per distinct topology fingerprint): sites on disjoint
    fabrics (the split-TP gather's model-axis mesh vs the EP cluster)
    share no physical link, so their demands never add.  The merged
    ledgers are pure demand accounting (``stages=1``, no overlap/compute
    context) — score them with :func:`ledger_wire_s`, not
    :func:`score_ledger`."""
    acc: dict[tuple, list] = {}
    order: list[tuple] = []
    for led in ledgers:
        if not led.link_bytes:
            continue
        fp = led.topo.fingerprint()
        if fp not in acc:
            acc[fp] = [led.topo, {}, {}, {}, {}]
            order.append(fp)
        _, lb, rb, fc, es = acc[fp]
        for k, v in led.link_bytes.items():
            lb[k] = lb.get(k, 0.0) + v
        for k, v in led.relay_bytes.items():
            rb[k] = rb.get(k, 0.0) + v
        for k, v in led.flow_counts.items():
            fc[k] = fc.get(k, 0) + v
        for k, v in led.engine_serial.items():
            es[k] = es.get(k, 0.0) + v
    return tuple(
        Ledger(topo=acc[fp][0], link_bytes=acc[fp][1],
               relay_bytes=acc[fp][2], flow_counts=acc[fp][3],
               engine_serial=acc[fp][4])
        for fp in order)


def phase_wire_s(ledgers, hw: HardwareModel = DEFAULT) -> float:
    """Shared-link serialization floor of concurrently executing
    ledgers: the bottleneck over the per-fabric MERGED demand
    (:func:`merge_ledgers`).  Disjoint fabrics proceed in parallel — the
    slowest sets the pace."""
    return max((ledger_wire_s(m, hw) for m in merge_ledgers(ledgers)),
               default=0.0)


def score_phase(entries, hw: HardwareModel = DEFAULT,
                background=()) -> float:
    """Contention-aware latency of one program phase.

    ``entries``: one ``(score_s, ledgers)`` pair per jointly-planned
    group executing concurrently in the phase — ``score_s`` the group's
    own (contention-free) combined score, ``ledgers`` its site ledgers.
    ``background``: extra ledgers whose bytes contend for the phase's
    links without contributing a latency term of their own (another
    phase's traffic under a continuous-batching SLO check).

    The model: concurrent groups overlap, so the phase pays its SLOWEST
    group — plus the EXCESS serialization of the shared rails.  The
    summed-demand bottleneck (:func:`phase_wire_s` over all ledgers) is
    compared against the largest single group's own wire floor; any
    excess is contention no overlap can hide and is charged on top:

        t_phase = max_g score_g + max(0, wire(sum of demands)
                                         - max_g wire(demands_g))

    With disjoint links the merged bottleneck equals the largest own
    bottleneck and the penalty vanishes — the phase scores exactly like
    independent planning.  Shared links make the penalty positive, and a
    scheme that routes around the shared rail can win jointly even when
    it loses on its private ledger.  Background demand only counts on
    fabrics the phase's OWN ledgers touch: traffic on a disjoint fabric
    shares no link with this phase and cannot slow it."""
    solo, contention = _phase_terms(entries, hw, background)[:2]
    return solo + contention


def phase_breakdown(entries, hw: HardwareModel = DEFAULT,
                    background=()) -> dict:
    """Reporting view of :func:`score_phase`: the solo (slowest-group)
    term, the merged shared-link wire floor and the contention excess,
    plus the final phase score."""
    solo, contention, merged = _phase_terms(entries, hw, background)
    return {"score_s": solo + contention, "solo_s": solo,
            "phase_wire_s": merged, "contention_s": contention}


def _phase_terms(entries, hw, background):
    """(solo_s, contention_s, merged_wire_s) of one phase."""
    entries = list(entries)
    solo = max((s for s, _ in entries), default=0.0)
    own_ledgers = [l for _, ls in entries for l in ls]
    own = max((phase_wire_s(ls, hw) for _, ls in entries), default=0.0)
    own_fps = {l.topo.fingerprint() for l in own_ledgers if l.link_bytes}
    merged = phase_wire_s(
        own_ledgers + [l for l in background
                       if l.topo.fingerprint() in own_fps], hw)
    return solo, max(0.0, merged - own), merged


def pipeline_overlap_endpoints(ledgers, hw: HardwareModel = DEFAULT
                               ) -> tuple[float, float]:
    """(serial_s, ideal_s) endpoints of a coupled pipeline's overlap
    interpolation (:func:`overlap_endpoints` generalized to the shared
    pipeline of :func:`score_pipeline`)."""
    serial = score_pipeline(
        ledgers, dataclasses.replace(hw, overlap_eff=0.0))
    ideal_ = score_pipeline(
        ledgers, dataclasses.replace(hw, overlap_eff=1.0))
    return serial, ideal_


def overlap_endpoints(ledger: Ledger,
                      hw: HardwareModel = DEFAULT) -> tuple[float, float]:
    """(serial_s, ideal_s) endpoints of a ledger's overlap interpolation:
    the score at ``overlap_eff`` 0 and 1.  ``measured`` times landing
    between them identify the achieved efficiency — the quantity
    ``repro.telemetry.fit.fit_overlap_eff`` regresses from
    ``Planner.decision_log`` rows (equal endpoints carry no signal)."""
    serial = score_ledger(ledger, dataclasses.replace(hw, overlap_eff=0.0))
    ideal_ = score_ledger(ledger, dataclasses.replace(hw, overlap_eff=1.0))
    return serial, ideal_


def expert_compute_time_s(tokens_per_rank: int, top_k: int, d_model: int,
                          d_ff_shard: int,
                          peak_flops: float = None) -> float:
    """Modeled per-rank expert-FFN time for one MoE layer — the compute
    stage a pipelined dispatch/combine hides network chunks behind.

    Balanced routing sends ``tokens_per_rank * top_k`` (token, expert)
    pairs through each rank's experts; the gated FFN is three matmuls
    (w1, w3, w2) of ``2 * d_model * d_ff_shard`` FLOPs each, where
    ``d_ff_shard`` is the TP-local expert hidden width."""
    from .topology import TPU_PEAK_FLOPS
    if peak_flops is None:
        peak_flops = TPU_PEAK_FLOPS
    flops = tokens_per_rank * top_k * 3 * 2 * d_model * d_ff_shard
    return float(flops) / float(peak_flops)


def moe_overlap_compute_s(tokens_per_rank: int, top_k: int, d_model: int,
                          d_ff: int, tp: int = 1) -> float:
    """:func:`expert_compute_time_s` from the GLOBAL expert hidden width
    and the TP degree — the ONE derivation of the overlap context every
    surface shares (moe_ffn at trace time, train/serve reports, dryrun
    cells), so the shard math and its zero-guards cannot diverge."""
    return expert_compute_time_s(tokens_per_rank, top_k, d_model,
                                 max(1, d_ff // max(1, tp)))


def backward_compute_s(num_params: int, tokens_per_rank: int,
                       tp: int = 1, peak_flops: float = None) -> float:
    """Modeled per-rank backward-pass time — the compute stage a chunked
    gradient sync hides behind (gradient buckets become ready
    back-to-front as backprop proceeds, so chunk k's wire time overlaps
    the backward compute of the layers before it).

    Dense-transformer backward is ~2x the forward's ``2 * params *
    tokens`` matmul FLOPs; TP shards the parameter matmuls ``tp``
    ways."""
    from .topology import TPU_PEAK_FLOPS
    if peak_flops is None:
        peak_flops = TPU_PEAK_FLOPS
    flops = 4.0 * float(num_params) * float(tokens_per_rank)
    return flops / (float(peak_flops) * max(1, tp))


def ledger_latency(sim: MultiWriteSimulator | Ledger,
                   hw: HardwareModel = DEFAULT) -> float:
    """Latency of a simulator run (or a pre-built Ledger)."""
    if isinstance(sim, Ledger):
        return score_ledger(sim, hw)
    return score_ledger(Ledger.from_sim(sim), hw)


# ---------------------------------------------------------------------------
# Closed forms: AllGather on the split-TP full mesh (§3.1)
# ---------------------------------------------------------------------------

ALLGATHER_LINK_LOAD = {
    # scheme -> (bottleneck-link bytes as fraction of fragment s,
    #            relay rx+tx bytes as fraction of s,  uses relay stage)
    "baseline":          (1.0, 0.0, False),
    "unicast_paired":    (0.75, 1.5, True),   # 3 copies of (1-r)s, r=3/4
    "multiwrite_paired": (0.5, 2.0, True),    # 1 copy of (1-r)s,  r=1/2
    "unicast_full":      (0.6, 2.4, True),    # 6(1-r)s/4 on cross, r=3/5;
    #                     relay rx+tx: 2*3*(1-r)/4 per source * 4 sources
    "multiwrite_full":   (0.5, 2.0, True),    # 4(1-r)s/4 on cross, r=1/2
}


def allgather_latency(scheme: str, frag_bytes: float,
                      link_bw: float = HCCS_LINK_BW,
                      hw: HardwareModel = DEFAULT) -> float:
    """Closed-form AllGather latency for a TP=4 domain pair on the 8-node
    full mesh.  ``ideal`` regime (hw=IDEAL) reproduces §3.1 exactly."""
    load, relay, relayed = ALLGATHER_LINK_LOAD[scheme]
    t = hw.alpha_base + load * frag_bytes / link_bw
    if relayed:
        t += hw.alpha_hop
        if not math.isinf(hw.copy_bw):
            t += relay * frag_bytes / hw.copy_bw
    return t


def allgather_crossover_bytes(link_bw: float = HCCS_LINK_BW,
                              hw: HardwareModel = DEFAULT) -> float:
    """Message size where multiwrite_paired == baseline (Fig 7 crossover).

    alpha_hop + 2s/copy_bw + s/(2w) = s/w  =>  s* = alpha_hop / (1/(2w) - 2/copy_bw)
    """
    denom = 1.0 / (2 * link_bw) - 2.0 / hw.copy_bw
    if denom <= 0:
        return math.inf
    return hw.alpha_hop / denom


# ---------------------------------------------------------------------------
# Closed forms: MoE AlltoAll dispatch on the 2-server cluster (§3.2, §6.3)
# ---------------------------------------------------------------------------

TOKEN_BYTES = 7168            # DeepSeek-V3 hidden size, fp8 dispatch payload
DISPATCH_ALPHA_UNICAST = 40e-6   # fitted once to Table 1 'w/ redundant'
DISPATCH_ALPHA_MW = 25e-6        # fitted once to Table 1 'w/o redundant'
RELAY_SETUP_S = 55e-6         # relay pipeline establishment (fitted to the
#                               Fig 8 parity point at decode batch 128);
#                               also charged to the multiwrite dispatch
#                               plan's ledger so the planner reproduces
#                               Fig 8's small-batch unicast preference.


def expected_remote_copies(num_experts: int = 64, top_k: int = 8,
                           num_servers: int = 2, npus_per_server: int = 8,
                           dedup_per_npu: bool = False) -> float:
    """Expected number of rail crossings per token under balanced routing.

    Token-by-token unicast (the mode the paper says multicast competes
    with) crosses once per remote *expert*: top_k * (S-1)/S in expectation.
    With per-destination-NPU aggregation it crosses once per distinct
    remote NPU.  MultiWrite crosses once per remote *server* that holds at
    least one selected expert.
    """
    remote_frac = (num_servers - 1) / num_servers
    if not dedup_per_npu:
        return top_k * remote_frac
    # distinct remote NPUs: 1 - C(E - e_npu, k)/C(E, k) per remote NPU
    e_npu = num_experts // (num_servers * npus_per_server)
    p_hit = 1.0 - (math.comb(num_experts - e_npu, top_k)
                   / math.comb(num_experts, top_k))
    return (num_servers - 1) * npus_per_server * p_hit


def expected_remote_servers(num_experts: int = 64, top_k: int = 8,
                            num_servers: int = 2,
                            npus_per_server: int = 8) -> float:
    e_srv = num_experts // num_servers
    p_hit = 1.0 - (math.comb(num_experts - e_srv, top_k)
                   / math.comb(num_experts, top_k))
    return (num_servers - 1) * p_hit


def dispatch_cross_server_time(batch: int, redundant: bool,
                               token_bytes: int = TOKEN_BYTES,
                               rail_bw: float = ROCE_LINK_BW) -> float:
    """Table 1 model: cross-server (rail) transfer time for `batch` tokens
    per NPU. 'w/ redundant' = unicast token-by-token (one crossing per
    remote expert); 'w/o redundant' = MultiWrite (one crossing per remote
    server holding a selected expert)."""
    if redundant:
        copies = expected_remote_copies()
        alpha = DISPATCH_ALPHA_UNICAST
    else:
        copies = expected_remote_servers()
        alpha = DISPATCH_ALPHA_MW
    return alpha + batch * copies * token_bytes / rail_bw


def dispatch_e2e_time(batch: int, scheme: str,
                      token_bytes: int = TOKEN_BYTES,
                      rail_bw: float = ROCE_LINK_BW,
                      hccs_bw: float = HCCS_LINK_BW,
                      hw: HardwareModel = DEFAULT) -> float:
    """Fig 8 model: end-to-end dispatch latency.

    unicast:    alpha_u + rail serialization of redundant copies
    multiwrite: alpha_u + alpha_relay_setup + single-copy rail time
                + relay replication processing (copies through the relay's
                buffer at copy_bw) + relay egress forwarding on HCCS.

    Reproduces the Fig 8 pattern: relay costs dominate the (small) rail
    saving at decode batch 64, parity near 128, growing gains at 1k/2k.
    """
    rail_uni = batch * expected_remote_copies() * token_bytes / rail_bw
    if scheme == "unicast":
        return DISPATCH_ALPHA_UNICAST + rail_uni
    assert scheme == "multiwrite"
    rail_mw = batch * expected_remote_servers() * token_bytes / rail_bw
    deliveries = expected_remote_copies(dedup_per_npu=True)  # fan-out at relay
    relay_copy = batch * deliveries * token_bytes / hw.copy_bw
    # relay forwards each copy over a distinct HCCS link; its egress engine
    # serializes the per-token copies (AICPU data plane, §6.4):
    relay_fwd = batch * deliveries * token_bytes / hccs_bw
    return (DISPATCH_ALPHA_UNICAST + RELAY_SETUP_S + rail_mw
            + relay_copy + relay_fwd)


# ---------------------------------------------------------------------------
# Paper reference numbers (for benchmarks / tests)
# ---------------------------------------------------------------------------

TABLE1_PAPER_US = {
    # batch: (w/ redundant, w/o redundant) microseconds — paper Table 1
    64: (112.90, 43.77),
    128: (210.53, 66.63),
    1024: (1231.18, 320.52),
    2048: (2429.72, 622.10),
}

FIG6_MESSAGE_BYTES = 16 * 2**20          # 16 MB per rank
FIG7_MESSAGE_BYTES = [256 * 2**10, 2**20, 2 * 2**20, 8 * 2**20,
                      16 * 2**20, 64 * 2**20, 200 * 2**20]
FIG8_BATCHES = [64, 128, 1024, 2048]
