"""MultiWrite collectives as JAX ``shard_map`` programs (TPU adaptation).

The paper implements MultiWrite as NPU-side software relaying: one copy of
each datum crosses the bottleneck link, and the landing ("same-index") node
replicates it locally (§3.2, §4.3.3).  TPUs expose no raw point-to-point
sends, so the recursive replication tree maps onto a *two-level collective
schedule* (DESIGN.md §2):

  stage 1  move exactly ONE copy of each datum across the slow axis
           (``pod``/DCN, or the cross-domain pair link in the split-TP
           scenario) — ``lax.ppermute`` / ``lax.all_to_all`` on that axis;
  stage 2  replicate at the landing chip with fast-axis collectives —
           the relay's packet copy/forward loop (cs_relay) becomes bitmap-
           driven packing + an intra-pod ``all_to_all``.

Contents:

AllGather (paper §3.1 / §5.2):
  * :func:`planned_allgather`    — planner-selected scheme + split (the
    §5.2 dynamic workflow: baseline below the Fig 7 crossover, MultiWrite
    above it — no hard-coded ``mode=``/``split=`` at call sites).
  * :func:`multiwrite_allgather` — split-TP AllGather using idle
    cross-domain links, paired or full relaying, one cross copy per chunk.
  * :func:`allgather_reference`  — plain subgroup all_gather (baseline).

MoE dispatch/combine (paper §3.2 / §6.3):
  * :func:`route_topk`            — gate -> (gates, expert ids).
  * :func:`pack_by_bitmap`        — bitmap-driven send-buffer packing; the
    pure-jnp twin of the Pallas ``dispatch_pack`` kernel (cs_send).
  * :func:`hierarchical_dispatch` — MultiWrite dispatch: one copy per
    (token, remote pod), relay replication intra-pod.
  * :func:`baseline_dispatch`     — unicast dispatch: one copy per
    (token, destination chip) crosses the pod axis (redundant baseline).
  * :func:`hierarchical_combine` / :func:`baseline_combine` — return path;
    hierarchical combine adds *relay-side partial reduction* (beyond-paper:
    the dual of dispatch dedup — one partial per (token, pod) crosses back).
  * :func:`hierarchical_combine_unicast` — unicast return path for the
    hierarchical dispatch (no relay reduction): the executable lowering of
    the combine planner's "unicast" plan, selected at trace time through
    ``ParallelContext.moe_pipeline_kwargs`` (jointly with the dispatch
    scheme and the shared microbatch G).

All functions are pure and must be called inside ``shard_map`` (they use
named axes).  Shapes are static; capacity semantics follow standard MoE
practice (priority = token order, overflow dropped & masked).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.compat import axis_size


# ===========================================================================
# AllGather over split TP domains (§3.1, §5.2)
# ===========================================================================

def _domain_groups(n: int, num_domains: int) -> list[list[int]]:
    d = n // num_domains
    return [list(range(i * d, (i + 1) * d)) for i in range(num_domains)]


def allgather_reference(x: jax.Array, axis_name: str,
                        num_domains: int = 2) -> jax.Array:
    """Baseline: all_gather over the local TP domain only (paper §5.2
    traditional workflow).  Returns [domain_size, *x.shape]."""
    n = axis_size(axis_name)
    groups = _domain_groups(n, num_domains)
    return lax.all_gather(x, axis_name, axis_index_groups=groups)


def multiwrite_allgather(x: jax.Array, axis_name: str, *,
                         num_domains: int = 2,
                         split: float = 0.5,
                         mode: str = "paired") -> jax.Array:
    """MultiWrite AllGather over a split-TP axis (paper §5.2 optimized).

    The axis of size ``n`` is split into ``num_domains`` equal TP domains
    (blocked).  Each chip all-gathers within its own domain, but routes a
    ``1 - split`` fraction of its fragment over the otherwise-idle
    cross-domain links: ONE copy to the same-index partner (the relay),
    which replicates to the source's domain peers — stage 1 + stage 2 of
    the MultiWrite tree.

    Args:
      x: local fragment, rank >= 1; the leading axis is split.
      axis_name: mesh axis carrying all domains (size = domain * num_domains).
      num_domains: number of TP domains sharing the axis (2 = paper §3.1).
      split: fraction sent over direct intra-domain links.  0.5 equalizes
        path times for the paired scheme (``optimal_split``); 1.0 degrades
        to the baseline.
      mode: "paired" (partner relays the whole cross chunk) or "full"
        (cross chunk sliced over every opposite-domain chip).

    Returns:
      [domain_size, *x.shape] — bit-identical to :func:`allgather_reference`.
    """
    if num_domains != 2:
        raise NotImplementedError("paired relaying is defined for 2 domains")
    n = axis_size(axis_name)
    half = n // 2
    rows = x.shape[0]
    cut = int(round(rows * split))
    cut = max(0, min(rows, cut))
    if cut == rows:  # pure baseline
        return allgather_reference(x, axis_name, num_domains)
    groups = _domain_groups(n, num_domains)
    xd, xc = x[:cut], x[cut:]

    # ---- direct part: intra-domain all_gather ------------------------------
    gd = lax.all_gather(xd, axis_name, axis_index_groups=groups)

    if mode == "paired":
        gc = _paired_relay_gather(xc, axis_name, n, half)
    elif mode == "full":
        gc = _full_relay_gather(xc, axis_name, n, half)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return jnp.concatenate([gd, gc], axis=1)


def planned_allgather(x: jax.Array, axis_name: str, *,
                      num_domains: int = 2,
                      planner=None, hw=None, decision=None) -> jax.Array:
    """AllGather whose scheme and split come from a planner decision
    (§5.2 dynamic workflow) instead of hard-coded ``mode=``/``split=``
    kwargs.

    ``decision`` is the per-site verdict of a bound
    :class:`~repro.core.plan.ExecutionPlan` (the declarative path —
    layers pass it through from ``ParallelContext.allgather_plan``).
    Without one, the process planner decides here: at trace time the
    fragment size and split-TP topology are static, so the (LRU-cached)
    decision selects among the registered executable plans — baseline
    below the Fig 7 crossover, multiwrite paired/full above it, at the
    split the latency model scored best.  Must be called inside
    ``shard_map``.
    """
    import math as _math

    from repro.core import planner as _planner_mod
    from repro.core.topology import split_tp_full_mesh

    if decision is None:
        n = axis_size(axis_name)
        frag_bytes = _math.prod(x.shape) * x.dtype.itemsize
        topo, _ = split_tp_full_mesh(n, tp=max(1, n // num_domains))
        pl = planner or _planner_mod.default_planner()
        decision = pl.choose("allgather", frag_bytes, topo, hw,
                             executable_only=True, num_domains=num_domains)
    kw = decision.shard_map_kwargs
    if kw["mode"] is None:
        return allgather_reference(x, axis_name, num_domains)
    return multiwrite_allgather(x, axis_name, num_domains=num_domains,
                                split=kw["split"], mode=kw["mode"])


def _paired_relay_gather(xc: jax.Array, axis_name: str, n: int,
                         half: int) -> jax.Array:
    """Stage 1: swap cross chunks with the same-index partner (ONE copy on
    each cross link).  Stage 2: each relay forwards its partner's chunk to
    the partner's domain peers, one ppermute round per peer offset —
    distinct physical links per round (§3.1 paired relaying)."""
    # stage 1: i <-> i+half
    swap = [(i, (i + half) % n) for i in range(n)]
    xr = lax.ppermute(xc, axis_name, swap)  # chunk of source partner(i)

    # stage 2: relay i holds source s(i) = (i+half)%n; peers of s(i) within
    # s(i)'s domain are offset r = 1..half-1.  Round r: relay i -> peer
    # (base(s)+ (idx(s)+r)%half).
    received = []
    for r in range(1, half):
        perm = []
        for i in range(n):
            s = (i + half) % n
            base, idx = (s // half) * half, s % half
            perm.append((i, base + (idx + r) % half))
        received.append(lax.ppermute(xr, axis_name, perm))
    # Rank j received, in round r, the cross chunk of source
    # base(j) + (idx(j) - r) % half.  Assemble domain-source order 0..half-1:
    me = lax.axis_index(axis_name)
    base, idx = (me // half) * half, me % half
    slots = [xc] + received          # slots[r] = source idx (idx - r) % half
    # gather into source order via a permutation matrix (static half x half
    # one-hot selected by the dynamic idx):
    stacked = jnp.stack(slots)       # [half, ...] in (idx - r) order
    offset = (idx - jnp.arange(half, dtype=idx.dtype)) % half  # src k at row?
    # slots[r] holds source (idx - r) % half -> source k sits at row
    # (idx - k) % half:
    rows_for_src = (idx - jnp.arange(half, dtype=idx.dtype)) % half
    del offset
    return stacked[rows_for_src]     # [half, ...] in source order


def _full_relay_gather(xc: jax.Array, axis_name: str, n: int,
                       half: int) -> jax.Array:
    """Full multi-path relaying (§3.1): the cross chunk is sliced over ALL
    ``half`` opposite-domain chips; each relay forwards its slice to the
    source's domain peers.

    Stage 1, round r: chip i sends slice ``(idx(i)+r) % half`` to the
    opposite-domain chip of that index — a true permutation per round, one
    slice copy per cross link.  After the rounds, relay j (index t) holds,
    from round r, slice t of the opposite source with index (t - r) % half.

    Stage 2, round (r, f) with f = 1..half-1: relay j forwards its round-r
    slice to the source's peer (source_domain, (t - r + f) % half).  Chip q
    (index iq) thereby receives, from round (r, f), slice (iq + r - f) %
    half of its domain-mate with index (iq - f) % half — every slice of
    every peer exactly once.  Per cross link: stage-1 one slice + stage-2
    (half-1) slices = (1-split)*s total, matching the §3.1 load derivation
    (r = 1/2 balance).
    """
    rows = xc.shape[0]
    pad = (-rows) % half
    if pad:
        xc = jnp.concatenate(
            [xc, jnp.zeros((pad,) + xc.shape[1:], xc.dtype)], axis=0)
    sliced = xc.reshape((half, xc.shape[0] // half) + xc.shape[1:])
    idx = lax.axis_index(axis_name) % half

    # ---- stage 1 ------------------------------------------------------------
    landed = []
    for r in range(half):
        perm = [(i, (((i // half) ^ 1) * half) + (i % half + r) % half)
                for i in range(n)]
        chunk = jnp.take(sliced, (idx + r) % half, axis=0)
        landed.append(lax.ppermute(chunk, axis_name, perm))

    # ---- stage 2 ------------------------------------------------------------
    out_rounds: list[list[jax.Array]] = [[] for _ in range(half)]  # per f
    for r in range(half):
        for f in range(1, half):
            perm = [(j, (((j // half) ^ 1) * half) + (j % half - r + f) % half)
                    for j in range(n)]
            out_rounds[f].append(lax.ppermute(landed[r], axis_name, perm))

    # ---- assembly -----------------------------------------------------------
    gathered = [sliced.reshape((-1,) + xc.shape[1:])]   # f = 0: own chunk
    for f in range(1, half):
        stacked = jnp.stack(out_rounds[f])               # [rounds r, ...]
        # round r carries slice (iq + r - f) % half -> slice sl sits at
        # round (sl - iq + f) % half:
        ordered = stacked[(jnp.arange(half) - idx + f) % half]
        gathered.append(ordered.reshape((-1,) + xc.shape[1:]))
    stackedg = jnp.stack(gathered)                       # [f, rows, ...]
    # gathered[f] = chunk of peer (iq - f) % half -> peer k at f=(iq-k)%half
    out = stackedg[(idx - jnp.arange(half, dtype=idx.dtype)) % half]
    if pad:
        out = out[:, :rows]
    return out


# ===========================================================================
# MoE routing
# ===========================================================================

def route_topk(logits: jax.Array, k: int,
               *, softmax_before_topk: bool = True
               ) -> tuple[jax.Array, jax.Array]:
    """Top-k gating. Returns (gates [.., k] f32 normalized, ids [.., k] i32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, ids = lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, ids.astype(jnp.int32)


# ===========================================================================
# Bitmap packing (cs_send analogue; jnp twin of the Pallas kernel)
# ===========================================================================

def pack_by_bitmap(tokens: jax.Array, bitmap: jax.Array, valid: jax.Array,
                   num_dests: int, capacity: int,
                   ) -> tuple[jax.Array, jax.Array]:
    """Pack rows into per-destination send buffers, bitmap-driven (§4.1).

    Args:
      tokens: [N, H] payload rows.
      bitmap: [N] int32 — bit d set ⇔ row goes to destination d (d < 32).
      valid:  [N] bool — row participates at all.
      num_dests: number of destinations D (<= 32).
      capacity: C, max rows per destination (overflow dropped, token order
        priority — standard MoE capacity semantics).

    Returns:
      out:     [D, C, H] packed rows (zeros where empty).
      src_idx: [D, C] int32 source row index, -1 where empty — the return
               map the combine path uses.
    """
    n, h = tokens.shape
    d_ids = jnp.arange(num_dests, dtype=jnp.int32)
    want = ((bitmap[None, :] >> d_ids[:, None]) & 1).astype(bool)  # [D, N]
    want = want & valid[None, :]
    pos = jnp.cumsum(want, axis=1) - 1                              # [D, N]
    keep = want & (pos < capacity)
    flat = jnp.where(keep, d_ids[:, None] * capacity + pos, num_dests * capacity)
    # one scatter over [D*C (+1 overflow slot)]
    src = jnp.full((num_dests * capacity + 1,), -1, jnp.int32)
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (num_dests, n))
    src = src.at[flat.reshape(-1)].set(rows.reshape(-1), mode="drop")
    src_idx = src[:num_dests * capacity].reshape(num_dests, capacity)
    gathered = jnp.where((src_idx >= 0)[..., None],
                         tokens[jnp.clip(src_idx, 0), :], 0)
    return gathered.astype(tokens.dtype), src_idx


def gather_rows(tokens: jax.Array, src_idx: jax.Array) -> jax.Array:
    """Gather rows by a pack map (-1 -> zeros)."""
    out = jnp.where((src_idx >= 0)[..., None],
                    tokens[jnp.clip(src_idx, 0)], 0)
    return out.astype(tokens.dtype)


# ===========================================================================
# Hierarchical (MultiWrite) MoE dispatch / combine
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class EPMesh:
    """Static description of the expert-parallel mesh slice."""
    pod_axis: str | None        # slow axis (DCN); None = single level
    ep_axis: str                # fast axis (ICI)
    num_pods: int
    ep_per_pod: int

    @property
    def num_ranks(self) -> int:
        return self.num_pods * self.ep_per_pod


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    num_experts: int
    top_k: int
    # capacity factors are vs. the no-drop worst case of each stage
    pod_capacity: float = 1.0   # stage-1 buffer = N * pod_capacity
    ep_capacity: float = 1.0    # stage-2 buffer = P*Cp*ep_capacity / D... see code
    expert_capacity: float = 1.0


def expert_placement(cfg: DispatchConfig, mesh: EPMesh):
    """Experts are placed in contiguous blocks over (pod, ep) ranks."""
    assert cfg.num_experts % mesh.num_ranks == 0, \
        f"{cfg.num_experts} experts over {mesh.num_ranks} EP ranks"
    per_rank = cfg.num_experts // mesh.num_ranks
    return per_rank


def _dest_coords(expert_ids: jax.Array, per_rank: int, ep_per_pod: int):
    """expert id -> (pod, ep) of owning rank."""
    rank = expert_ids // per_rank
    return rank // ep_per_pod, rank % ep_per_pod


def hierarchical_dispatch(tokens: jax.Array, expert_ids: jax.Array,
                          gates: jax.Array, cfg: DispatchConfig,
                          mesh: EPMesh):
    """MultiWrite MoE dispatch (paper §3.2 / §4).

    Per chip inputs: tokens [N, H]; expert_ids [N, K] i32; gates [N, K] f32.

    Stage 1 — ONE copy per (token, destination pod) crosses the pod axis,
    landing on the same-index chip (the rail relay).  The ep-rank bitmap
    (paper §4.1 metadata) travels with the payload.
    Stage 2 — relays parse the bitmap and replicate intra-pod via
    all_to_all over the ep axis (cs_relay).

    Returns (expert_inputs [E_local, Ce, H], DispatchState) where
    DispatchState carries every pack map needed by the combine path.
    """
    n, h = tokens.shape
    k = expert_ids.shape[-1]
    per_rank = expert_placement(cfg, mesh)
    p, d = mesh.num_pods, mesh.ep_per_pod
    pod_of, ep_of = _dest_coords(expert_ids, per_rank, d)   # [N, K] each

    assert per_rank <= 31 and d <= 31 and p <= 31, "bitmap words are int32"

    # ---- stage 1 pack: per destination pod, with ep bitmap metadata -------
    # pod bitmap (which pods does this token need — ONE copy each):
    pod_any = jnp.any(pod_of[..., None] == jnp.arange(p), axis=1)   # [N, P]
    pod_bits = jnp.sum(pod_any.astype(jnp.int32) << jnp.arange(p),
                       axis=-1).astype(jnp.int32)                   # [N]
    # per-pod ep-rank bitmap — the §4.1 in-packet metadata the relay parses:
    ep_onehot = (pod_of[..., None] == jnp.arange(p))[..., None] & \
        (ep_of[..., None] == jnp.arange(d))[:, :, None, :]          # [N,K,P,D]
    ep_any = jnp.any(ep_onehot, axis=1)                             # [N,P,D]
    ep_bits = jnp.sum(
        ep_any.astype(jnp.int32) << jnp.arange(d), axis=-1).astype(jnp.int32)

    cp = max(1, int(round(n * cfg.pod_capacity)))
    valid = jnp.ones((n,), bool)
    send_tok, map_pod = pack_by_bitmap(tokens, pod_bits, valid, p, cp)
    # metadata rides along (the §4.1 in-packet metadata): ep bitmap for the
    # DESTINATION pod + source row id + (ids, gates) for expert/combine use.
    ep_bits_dst = jnp.stack(
        [gather_rows(ep_bits[:, pp:pp + 1], map_pod[pp])[..., 0]
         for pp in range(p)])                                     # [P, Cp]
    meta_src = jnp.where(map_pod >= 0, map_pod, -1)               # [P, Cp]
    ids_dst = gather_rows(expert_ids, map_pod.reshape(-1)).reshape(p, cp, k)
    gates_dst = gather_rows(gates, map_pod.reshape(-1)).reshape(p, cp, k)

    # ---- stage 1 transport: all_to_all over the pod axis -------------------
    if mesh.pod_axis is not None and p > 1:
        a2a = functools.partial(lax.all_to_all, axis_name=mesh.pod_axis,
                                split_axis=0, concat_axis=0, tiled=True)
        recv_tok = a2a(send_tok.reshape(p * cp, h)).reshape(p, cp, h)
        recv_ep = a2a(ep_bits_dst.reshape(p * cp, 1)).reshape(p, cp)
        recv_src = a2a(meta_src.reshape(p * cp, 1)).reshape(p, cp)
        recv_ids = a2a(ids_dst.reshape(p * cp, k)).reshape(p, cp, k)
        recv_gates = a2a(gates_dst.reshape(p * cp, k)).reshape(p, cp, k)
    else:
        recv_tok, recv_ep = send_tok, ep_bits_dst
        recv_src, recv_ids, recv_gates = meta_src, ids_dst, gates_dst

    # ---- stage 2: relay replication over the ep axis (cs_relay) ------------
    flat_tok = recv_tok.reshape(p * cp, h)
    flat_ep = recv_ep.reshape(p * cp)
    flat_valid = (recv_src.reshape(p * cp) >= 0)
    cd = max(1, int(round(p * cp * cfg.ep_capacity)))
    relay_tok, map_ep = pack_by_bitmap(flat_tok, flat_ep, flat_valid, d, cd)
    relay_ids = gather_rows(recv_ids.reshape(p * cp, k), map_ep.reshape(-1)
                            ).reshape(d, cd, k)
    relay_gates = gather_rows(recv_gates.reshape(p * cp, k),
                              map_ep.reshape(-1)).reshape(d, cd, k)
    if d > 1:
        a2a_ep = functools.partial(lax.all_to_all, axis_name=mesh.ep_axis,
                                   split_axis=0, concat_axis=0, tiled=True)
        got_tok = a2a_ep(relay_tok.reshape(d * cd, h)).reshape(d, cd, h)
        got_ids = a2a_ep(relay_ids.reshape(d * cd, k)).reshape(d, cd, k)
        got_gates = a2a_ep(relay_gates.reshape(d * cd, k)).reshape(d, cd, k)
        got_valid = a2a_ep((map_ep >= 0).reshape(d * cd, 1)).reshape(d, cd)
    else:
        got_tok, got_ids, got_gates = relay_tok, relay_ids, relay_gates
        got_valid = map_ep >= 0

    # ---- stage 3: local per-expert grouping (zero comm) --------------------
    my_pod = lax.axis_index(mesh.pod_axis) if (mesh.pod_axis and p > 1) else 0
    my_ep = lax.axis_index(mesh.ep_axis) if d > 1 else 0
    my_rank = my_pod * d + my_ep
    flat2_tok = got_tok.reshape(d * cd, h)
    flat2_ids = got_ids.reshape(d * cd, k)
    flat2_gates = got_gates.reshape(d * cd, k)
    flat2_valid = got_valid.reshape(d * cd)
    local_e = flat2_ids - my_rank * per_rank                     # [M, K]
    mine = (local_e >= 0) & (local_e < per_rank)
    exp_bits = jnp.sum(
        jnp.where(mine, 1 << jnp.clip(local_e, 0, 30), 0), axis=-1
    ).astype(jnp.int32)
    # OR-safety: top-k ids are distinct -> a token hits each local expert at
    # most once -> sum == OR.  (Routers guarantee distinct ids.)
    ce = max(1, int(round(d * cd * cfg.expert_capacity)))
    exp_tok, map_exp = pack_by_bitmap(flat2_tok, exp_bits, flat2_valid,
                                      per_rank, ce)
    exp_gate = _gate_for_expert(flat2_ids, flat2_gates, map_exp,
                                my_rank * per_rank, per_rank)

    state = DispatchState(map_pod=map_pod, map_ep=map_ep, map_exp=map_exp,
                          recv_src=recv_src, n_tokens=n, cfg=cfg, mesh=mesh)
    return exp_tok, exp_gate, state


def _gate_for_expert(ids: jax.Array, gates: jax.Array, map_exp: jax.Array,
                     base: jax.Array, per_rank: int) -> jax.Array:
    """gate value of each packed (expert, slot) row: the gate of the k-slot
    whose expert id == this expert."""
    e_local, ce = map_exp.shape
    rows_ids = gather_rows(ids, map_exp.reshape(-1)).reshape(e_local, ce, -1)
    rows_gates = gather_rows(gates, map_exp.reshape(-1)
                             ).reshape(e_local, ce, -1)
    want = rows_ids == (base + jnp.arange(e_local))[:, None, None]
    return jnp.sum(jnp.where(want, rows_gates, 0.0), axis=-1)   # [E_l, Ce]


@dataclasses.dataclass
class DispatchState:
    """Pack maps threaded from dispatch to combine (all static-shape)."""
    map_pod: jax.Array    # [P, Cp]  source row per stage-1 slot
    map_ep: jax.Array     # [D, Cd]  stage-1 flat slot per stage-2 slot
    map_exp: jax.Array    # [E_local, Ce] stage-2 flat slot per expert slot
    recv_src: jax.Array   # [P, Cp]  source row id as received (post pod a2a)
    n_tokens: int
    cfg: DispatchConfig
    mesh: EPMesh


jax.tree_util.register_pytree_node(
    DispatchState,
    lambda s: ((s.map_pod, s.map_ep, s.map_exp, s.recv_src),
               (s.n_tokens, s.cfg, s.mesh)),
    lambda aux, ch: DispatchState(*ch, n_tokens=aux[0], cfg=aux[1],
                                  mesh=aux[2]),
)


def hierarchical_combine(expert_out: jax.Array, exp_gate: jax.Array,
                         state: DispatchState) -> jax.Array:
    """Return path with relay-side partial reduction (beyond-paper dual of
    dispatch dedup): per-(token, pod) partials are pre-reduced at the relay
    before crossing the pod axis — ONE partial per (token, pod) on DCN.

    Returns [N, H] combined outputs aligned with the dispatch input rows.
    """
    cfg, mesh = state.cfg, state.mesh
    p, d = mesh.num_pods, mesh.ep_per_pod
    e_local, ce, h = expert_out.shape
    cd = state.map_ep.shape[1]
    cp = state.map_pod.shape[1]

    # ---- apply gates, scatter-add expert slots back to stage-2 slots ------
    weighted = expert_out * exp_gate[..., None]
    flat2 = jnp.zeros((d * cd + 1, h), jnp.float32)
    idx = jnp.where(state.map_exp >= 0, state.map_exp, d * cd)
    flat2 = flat2.at[idx.reshape(-1)].add(
        weighted.reshape(-1, h).astype(jnp.float32))
    flat2 = flat2[:d * cd].reshape(d, cd, h)

    # ---- reverse ep a2a: partials back to the relay ------------------------
    if d > 1:
        back = lax.all_to_all(flat2.reshape(d * cd, h), mesh.ep_axis,
                              split_axis=0, concat_axis=0,
                              tiled=True).reshape(d, cd, h)
    else:
        back = flat2
    # ---- relay-side reduction: sum per stage-1 slot over ep ranks ----------
    flat1 = jnp.zeros((p * cp + 1, h), jnp.float32)
    idxe = jnp.where(state.map_ep >= 0, state.map_ep, p * cp)
    flat1 = flat1.at[idxe.reshape(-1)].add(back.reshape(-1, h))
    flat1 = flat1[:p * cp].reshape(p, cp, h)

    # ---- reverse pod a2a: ONE pre-reduced partial per (token, pod) ---------
    if mesh.pod_axis is not None and p > 1:
        home = lax.all_to_all(flat1.reshape(p * cp, h), mesh.pod_axis,
                              split_axis=0, concat_axis=0,
                              tiled=True).reshape(p, cp, h)
    else:
        home = flat1
    # ---- scatter-add into source rows --------------------------------------
    out = jnp.zeros((state.n_tokens + 1, h), jnp.float32)
    idxp = jnp.where(state.map_pod >= 0, state.map_pod, state.n_tokens)
    out = out.at[idxp.reshape(-1)].add(home.reshape(-1, h))
    return out[:state.n_tokens]


def hierarchical_combine_unicast(expert_out: jax.Array, exp_gate: jax.Array,
                                 state: DispatchState) -> jax.Array:
    """Unicast return path for the hierarchical dispatch: NO relay-side
    reduction — every (token, ep-rank) partial crosses the pod axis
    individually and reduces at the home chip.

    This is the redundant-return baseline the combine planner scores
    against :func:`hierarchical_combine` (one pre-reduced partial per
    (token, pod)): up to ``ep_per_pod`` x more bytes on the slow axis,
    but no relay reduce stage — the Fig 8 trade-off on the return path.
    Numerically equivalent to :func:`hierarchical_combine` (same fp32
    additions, different order).
    """
    mesh = state.mesh
    p, d = mesh.num_pods, mesh.ep_per_pod
    e_local, ce, h = expert_out.shape
    cd = state.map_ep.shape[1]
    cp = state.map_pod.shape[1]

    # ---- apply gates, scatter-add expert slots back to stage-2 slots ------
    weighted = expert_out * exp_gate[..., None]
    flat2 = jnp.zeros((d * cd + 1, h), jnp.float32)
    idx = jnp.where(state.map_exp >= 0, state.map_exp, d * cd)
    flat2 = flat2.at[idx.reshape(-1)].add(
        weighted.reshape(-1, h).astype(jnp.float32))
    flat2 = flat2[:d * cd].reshape(d, cd, h)

    # ---- reverse ep a2a: partials back to the relay ------------------------
    if d > 1:
        back = lax.all_to_all(flat2.reshape(d * cd, h), mesh.ep_axis,
                              split_axis=0, concat_axis=0,
                              tiled=True).reshape(d, cd, h)
    else:
        back = flat2
    # ---- NO relay reduction: one slot per (stage-1 slot, ep rank) ----------
    sl = state.map_ep                                             # [d, cd]
    idx2 = jnp.where(sl >= 0,
                     sl * d + jnp.arange(d, dtype=jnp.int32)[:, None],
                     p * cp * d)
    unred = jnp.zeros((p * cp * d + 1, h), jnp.float32)
    unred = unred.at[idx2.reshape(-1)].add(back.reshape(-1, h))
    unred = unred[:p * cp * d].reshape(p, cp * d, h)

    # ---- reverse pod a2a: d unreduced partials per stage-1 slot ------------
    if mesh.pod_axis is not None and p > 1:
        home = lax.all_to_all(unred.reshape(p * cp * d, h), mesh.pod_axis,
                              split_axis=0, concat_axis=0,
                              tiled=True).reshape(p, cp * d, h)
    else:
        home = unred
    # ---- reduce AFTER crossing, scatter-add into source rows ---------------
    home = home.reshape(p, cp, d, h).sum(axis=2)
    out = jnp.zeros((state.n_tokens + 1, h), jnp.float32)
    idxp = jnp.where(state.map_pod >= 0, state.map_pod, state.n_tokens)
    out = out.at[idxp.reshape(-1)].add(home.reshape(-1, h))
    return out[:state.n_tokens]


# ===========================================================================
# Baseline (unicast) dispatch / combine — one copy per (token, dest chip)
# ===========================================================================

def baseline_dispatch(tokens: jax.Array, expert_ids: jax.Array,
                      gates: jax.Array, cfg: DispatchConfig, mesh: EPMesh):
    """Unicast dispatch: pack one copy per (token, destination RANK) and
    all_to_all over the flattened (pod, ep) domain — k_remote redundant
    copies of each token cross the pod axis (the paper's baseline)."""
    n, h = tokens.shape
    k = expert_ids.shape[-1]
    per_rank = expert_placement(cfg, mesh)
    p, d = mesh.num_pods, mesh.ep_per_pod
    r = p * d
    rank_of = (expert_ids // per_rank).astype(jnp.int32)          # [N, K]
    rank_any = jnp.any(rank_of[..., None] == jnp.arange(r), axis=1)  # [N, R]
    rank_bits32 = [jnp.sum(rank_any[:, w * 31:(w + 1) * 31].astype(jnp.int32)
                           << jnp.arange(min(31, r - w * 31)), axis=-1)
                   for w in range((r + 30) // 31)]
    cr = max(1, int(round(n * cfg.pod_capacity)))
    # pack per rank using multi-word bitmaps
    outs, maps = [], []
    for w, bits in enumerate(rank_bits32):
        nd = min(31, r - w * 31)
        o, m = pack_by_bitmap(tokens, bits, jnp.ones((n,), bool), nd, cr)
        outs.append(o)
        maps.append(m)
    send_tok = jnp.concatenate(outs, axis=0)                      # [R, Cr, H]
    map_rank = jnp.concatenate(maps, axis=0)                      # [R, Cr]
    ids_send = gather_rows(expert_ids, map_rank.reshape(-1)).reshape(r, cr, k)
    gates_send = gather_rows(gates, map_rank.reshape(-1)).reshape(r, cr, k)

    # transport: a2a over ep then pod (equivalent to flattened-domain a2a)
    def a2a_both(x):
        x = x.reshape(p, d, cr, -1)
        if d > 1:
            x = lax.all_to_all(x, mesh.ep_axis, split_axis=1, concat_axis=1,
                               tiled=True)
        if mesh.pod_axis is not None and p > 1:
            x = lax.all_to_all(x, mesh.pod_axis, split_axis=0, concat_axis=0,
                               tiled=True)
        return x.reshape(r, cr, -1)

    got_tok = a2a_both(send_tok)
    got_ids = a2a_both(ids_send).astype(jnp.int32)
    got_gates = a2a_both(gates_send)
    got_valid = a2a_both((map_rank >= 0).astype(jnp.int32)[..., None]
                         )[..., 0] > 0

    my_pod = lax.axis_index(mesh.pod_axis) if (mesh.pod_axis and p > 1) else 0
    my_ep = lax.axis_index(mesh.ep_axis) if d > 1 else 0
    my_rank = my_pod * d + my_ep
    flat_tok = got_tok.reshape(r * cr, h)
    flat_ids = got_ids.reshape(r * cr, k)
    flat_gates = got_gates.reshape(r * cr, k)
    local_e = flat_ids - my_rank * per_rank
    mine = (local_e >= 0) & (local_e < per_rank)
    exp_bits = jnp.sum(jnp.where(mine, 1 << jnp.clip(local_e, 0, 30), 0),
                       axis=-1).astype(jnp.int32)
    ce = max(1, int(round(r * cr * cfg.expert_capacity)))
    exp_tok, map_exp = pack_by_bitmap(flat_tok, exp_bits,
                                      got_valid.reshape(r * cr), per_rank, ce)
    exp_gate = _gate_for_expert(flat_ids, flat_gates, map_exp,
                                my_rank * per_rank, per_rank)
    state = BaselineState(map_rank=map_rank, map_exp=map_exp, n_tokens=n,
                          cfg=cfg, mesh=mesh)
    return exp_tok, exp_gate, state


@dataclasses.dataclass
class BaselineState:
    map_rank: jax.Array   # [R, Cr]
    map_exp: jax.Array    # [E_local, Ce]
    n_tokens: int
    cfg: DispatchConfig
    mesh: EPMesh


jax.tree_util.register_pytree_node(
    BaselineState,
    lambda s: ((s.map_rank, s.map_exp), (s.n_tokens, s.cfg, s.mesh)),
    lambda aux, ch: BaselineState(*ch, n_tokens=aux[0], cfg=aux[1],
                                  mesh=aux[2]),
)


def baseline_combine(expert_out: jax.Array, exp_gate: jax.Array,
                     state: BaselineState) -> jax.Array:
    """Unicast combine: per-(token, expert-rank) outputs return individually
    over both axes (no relay reduction) and are summed at the source."""
    cfg, mesh = state.cfg, state.mesh
    p, d = mesh.num_pods, mesh.ep_per_pod
    r = p * d
    e_local, ce, h = expert_out.shape
    cr = state.map_rank.shape[1]
    weighted = expert_out * exp_gate[..., None]
    flat = jnp.zeros((r * cr + 1, h), jnp.float32)
    idx = jnp.where(state.map_exp >= 0, state.map_exp, r * cr)
    flat = flat.at[idx.reshape(-1)].add(
        weighted.reshape(-1, h).astype(jnp.float32))
    flat = flat[:r * cr]

    def a2a_both_back(x):
        x = x.reshape(p, d, cr, -1)
        if mesh.pod_axis is not None and p > 1:
            x = lax.all_to_all(x, mesh.pod_axis, split_axis=0, concat_axis=0,
                               tiled=True)
        if d > 1:
            x = lax.all_to_all(x, mesh.ep_axis, split_axis=1, concat_axis=1,
                               tiled=True)
        return x.reshape(r, cr, -1)

    home = a2a_both_back(flat)
    out = jnp.zeros((state.n_tokens + 1, h), jnp.float32)
    idxr = jnp.where(state.map_rank >= 0, state.map_rank, state.n_tokens)
    out = out.at[idxr.reshape(-1)].add(home.reshape(-1, h))
    return out[:state.n_tokens]


# ===========================================================================
# Planned gradient sync: AllReduce as a planner op (shard_map lowerings)
# ===========================================================================

def butterfly_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """Recursive-doubling tree AllReduce: log2(R) ppermute rounds, each
    exchanging the full payload with the XOR partner and reducing — the
    latency-optimal endpoint of the reduce scheme family (the ledger the
    planner scores as the ``tree`` plan).  Returns the SUM over the
    axis.  Requires a power-of-two axis; must run inside shard_map."""
    n = axis_size(axis_name)
    if n & (n - 1):
        raise ValueError(f"butterfly_psum needs a power-of-two axis "
                         f"(got {n})")
    out = g
    k = 1
    while k < n:
        perm = [(i, i ^ k) for i in range(n)]
        out = out + lax.ppermute(out, axis_name, perm)
        k <<= 1
    return out


def planned_psum(g: jax.Array, axis_name: str, *, num_servers: int = 1,
                 decision=None, reduce_scheme: str = None,
                 planner=None, hw=None, compute_s: float = 0.0) -> jax.Array:
    """Gradient MEAN-reduce over ``axis_name`` whose schedule comes from
    a planner decision instead of a hard-coded ``lax.psum``.

    ``decision`` is the ``grad_sync`` verdict of a bound
    :class:`~repro.core.plan.ExecutionPlan`; ``reduce_scheme`` pins a
    scheme directly (tests / operational override).  Without either, the
    process planner decides here from the payload and the DP fabric
    (``num_servers`` server groups of the axis, fabric order).  Must be
    called inside ``shard_map`` with ``axis_name`` bound.

    Scheme -> lowering:
      ring          ``lax.psum`` (XLA's own flat ring — the baseline)
      tree          :func:`butterfly_psum` XOR-partner rounds
      hierarchical  ``hierarchical_psum_flat`` (RS -> rail exchange -> AG)
      multiwrite    ``hierarchical_psum_flat`` — on TPU the relay-reduce
                    schedule lowers to the same RS/exchange/AG structure
                    (the ledger difference is the relay engine accounting)
      compressed    int8 error-feedback ``compressed_psum`` (LOSSY —
                    never planner-chosen, explicit opt-in only)

    All lossless schemes are numerically equivalent to
    ``lax.psum(g) / R`` up to float summation order.
    """
    import math as _math

    from repro.core import planner as _planner_mod

    scheme = reduce_scheme
    if scheme is None:
        if decision is None:
            n = axis_size(axis_name)
            payload = _math.prod(g.shape) * g.dtype.itemsize
            pl = planner or _planner_mod.default_planner()
            topo = _planner_mod._ep_topology(
                max(1, num_servers), max(1, n // max(1, num_servers)))
            decision = pl.choose("allreduce", payload, topo, hw,
                                 executable_only=True, compute_s=compute_s)
        scheme = decision.shard_map_kwargs.get("reduce_scheme", "ring")
    r = axis_size(axis_name)
    if scheme == "ring":
        return lax.psum(g, axis_name) / r
    if scheme == "tree":
        if r & (r - 1):
            return lax.psum(g, axis_name) / r     # non-pow2: ring fallback
        return butterfly_psum(g, axis_name) / r
    if scheme in ("hierarchical", "multiwrite"):
        from repro.parallel.compression import hierarchical_psum_flat
        s = max(1, num_servers)
        if r % s:
            return lax.psum(g, axis_name) / r     # unfactorable: fallback
        out = hierarchical_psum_flat(g.reshape(-1), axis_name, s)
        return out.reshape(g.shape).astype(g.dtype)
    if scheme == "compressed":
        from repro.parallel.compression import compressed_psum
        out, _ = compressed_psum(g.reshape(-1), axis_name)
        return out.reshape(g.shape).astype(g.dtype)
    raise ValueError(f"unknown reduce scheme {scheme!r}")


# ===========================================================================
# Analytic pod-axis byte accounting (feeds the paper-validation benches)
# ===========================================================================

def dispatch_pod_bytes(expert_ids, cfg: DispatchConfig, mesh: EPMesh,
                       h: int, elem_bytes: int = 2):
    """(baseline_bytes, multiwrite_bytes) crossing the pod axis per chip —
    the Table-1 quantity at pod scale.  expert_ids: [N, K] (numpy ok)."""
    import numpy as np
    ids = np.asarray(expert_ids)
    per_rank = cfg.num_experts // mesh.num_ranks
    rank = ids // per_rank
    pod = rank // mesh.ep_per_pod
    # chips/pods distinct per token, restricted to REMOTE pods
    base = mw = 0
    for row_rank, row_pod in zip(rank, pod):
        # assume source pod 0 (symmetric under balance)
        remote = row_pod != 0
        base += len(set(row_rank[remote]))
        mw += len(set(row_pod[remote]))
    return base * h * elem_bytes, mw * h * elem_bytes
