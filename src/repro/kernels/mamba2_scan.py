"""Pallas TPU kernel: Mamba2 (SSD) chunked selective-state-space scan.

zamba2-7b's compute hot spot.  The recurrence per head

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T        (h: [ds, dh])
    y_t = C_t^T h_t + D * x_t

is evaluated chunk-parallel (the SSD formulation): within a chunk of Q
steps the contribution is a masked [Q, Q] matmul (MXU work), and the
[ds, dh] state is carried across chunks in VMEM scratch — the kernel grid
is (batch*heads, num_chunks) with chunks innermost, so the state scratch
persists across the sequential chunk dimension.

Cumulative decays are computed in log space (dt*A <= 0) for stability.

Oracle: :func:`repro.kernels.ref.mamba2_ref` (per-step lax.scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba2_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, o_ref,
                   h_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # [Q, dh]
    dt = dt_ref[0].astype(jnp.float32)        # [Q]
    a = a_ref[0, 0]                           # scalar A (negative)
    b = b_ref[0].astype(jnp.float32)          # [Q, ds]
    c = c_ref[0].astype(jnp.float32)          # [Q, ds]
    d = d_ref[0, 0]                           # scalar skip

    log_a = dt * a                            # [Q] log decay per step (<=0)
    cum = jnp.cumsum(log_a)                   # [Q] inclusive
    # intra-chunk: M[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, j<=i
    s = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, Q]
    li = cum[:, None]
    lj = cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(ii >= jj, jnp.exp(li - lj), 0.0)
    m = s * decay * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, dh]
    # inter-chunk: y += exp(cum_i) * C_i^T h_prev
    h_prev = h_ref[...]                       # [ds, dh]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0] = (y + d * x).astype(o_ref.dtype)
    # state update: h = exp(cum_Q) h_prev + sum_j exp(cum_Q - cum_j) dt_j B_j x_j^T
    total = cum[-1]
    w = jnp.exp(total - cum) * dt             # [Q]
    h_ref[...] = jnp.exp(total) * h_prev + jax.lax.dot_general(
        b * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def mamba2_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, d: jax.Array, *, chunk: int = 64,
                interpret: bool = True) -> jax.Array:
    """Chunked SSD scan.

    Args:
      x:  [BH, S, dh] inputs per head.
      dt: [BH, S] step sizes (post-softplus, > 0).
      a:  [BH] per-head A (negative).
      b:  [BH, S, ds] input projections.
      c:  [BH, S, ds] output projections.
      d:  [BH] skip coefficients.
      chunk: chunk length Q (sequence must pad to a multiple).

    Returns: y [BH, S, dh] in x.dtype.
    """
    bh, s, dh = x.shape
    ds = b.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    sp = x.shape[1]
    nc = sp // chunk
    out = pl.pallas_call(
        functools.partial(_mamba2_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk), lambda h, i: (h, i)),
            pl.BlockSpec((1, 1), lambda h, i: (h, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, ds), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, 1), lambda h, i: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, dh), jnp.float32)],
        interpret=interpret,
    )(x, dt, a[:, None].astype(jnp.float32), b, c,
      d[:, None].astype(jnp.float32))
    return out[:, :s]
