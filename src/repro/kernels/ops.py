"""Public jit'd wrappers for the Pallas kernels.

Each op dispatches between the Pallas kernel (TPU: compiled; CPU:
interpret mode for validation) and the pure-jnp reference, controlled by
``use_pallas`` / the ``REPRO_NO_PALLAS`` env toggle.  Model code imports
from here only.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref
from .dispatch_pack import dispatch_pack as _dispatch_pack_kernel
from .flash_attention import flash_attention as _flash_attention_kernel
from .mamba2_scan import mamba2_scan as _mamba2_kernel
from .rwkv6_scan import rwkv6_scan as _rwkv6_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_pallas(flag: bool | None) -> bool:
    if flag is not None:
        return flag
    return os.environ.get("REPRO_NO_PALLAS", "0") != "1"


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128,
                    use_pallas: bool | None = None):
    """[BH, S, D] fused attention (GQA broadcast is the caller's job)."""
    if _use_pallas(use_pallas):
        return _flash_attention_kernel(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=_interpret())
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale)


def decode_attention(q, k, v, kv_len=None, *, scale=None, softcap=None,
                     window=None):
    """Decode-step attention (memory-bound matvec; jnp is already optimal
    on TPU for this shape — no kernel needed)."""
    return ref.decode_attention_ref(q, k, v, kv_len, scale=scale,
                                    softcap=softcap, window=window)


def mamba2_scan(x, dt, a, b, c, d, *, chunk=64, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _mamba2_kernel(x, dt, a, b, c, d, chunk=chunk,
                              interpret=_interpret())
    # chunked jnp twin (same algorithm as the kernel): touches the [ds,dh]
    # state once per CHUNK, not per step — the per-step scan oracle thrashes
    # HBM chunk-times harder and lives in ref.mamba2_ref for tests only.
    return ref.mamba2_chunked_jnp(x, dt, a, b, c, d, chunk=chunk)


def rwkv6_scan(r, k, v, logw, u, *, chunk=32, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _rwkv6_kernel(r, k, v, logw, u, chunk=chunk,
                             interpret=_interpret())
    return ref.rwkv6_chunked_jnp(r, k, v, logw, u, chunk=chunk)


def dispatch_pack(tokens, bitmap, valid, *, num_dests, capacity,
                  block_rows=8, use_pallas: bool | None = None):
    if _use_pallas(use_pallas):
        return _dispatch_pack_kernel(
            tokens, bitmap, valid, num_dests=num_dests, capacity=capacity,
            block_rows=block_rows, interpret=_interpret())
    return ref.pack_ref(tokens, bitmap, valid, num_dests, capacity)
