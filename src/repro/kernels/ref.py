"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references: simple, obviously-right
implementations (per-step scans, dense masked attention, python-loop
packing semantics) that the kernels' interpret-mode outputs are
assert_allclose'd against across shape/dtype sweeps in
tests/test_kernels.py.  They are also what the models fall back to when
``use_kernels=False``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """Dense masked attention.  q/k/v: [BH, S, D] / [BH, T, D]."""
    d = q.shape[-1]
    if scale is None:
        scale = d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qlen, klen = q.shape[1], k.shape[1]
    qpos = jnp.arange(qlen)[:, None]
    kpos = jnp.arange(klen)[None, :]
    mask = jnp.ones((qlen, klen), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len=None, *, scale=None, softcap=None,
                         window=None):
    """Single-token grouped-GQA decode attention over a (possibly
    partially-filled) KV cache.

    q: [B, H, D]; k/v: [B, T, G, D] (cache layout, H = G*rep — NO
    materialized kv broadcast, dots accumulate in fp32 from the cache
    dtype).  kv_len: valid prefix length.  window masks relative to the
    current position.  Returns [B, H, D] in q.dtype.
    """
    b, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    rep = h // g
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(b, g, rep, d)
    s = jnp.einsum("bgrd,btgd->bgrt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(t)
    if kv_len is None:
        kv_len = t
    kv_len = jnp.asarray(kv_len)
    mask = pos < kv_len
    if window is not None:
        mask &= pos >= (kv_len - window)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrt,btgd->bgrd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_ref(x, dt, a, b, c, d):
    """Per-step recurrent oracle.  Shapes as mamba2_scan."""
    bh, s, dh = x.shape
    ds = b.shape[-1]

    def head(xh, dth, ah, bh_, ch, dh_):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            decay = jnp.exp(dtt * ah)
            h = decay * h + dtt * jnp.outer(bt, xt)
            y = ct @ h + dh_ * xt
            return h, y

        h0 = jnp.zeros((ds, dh), jnp.float32)
        _, ys = jax.lax.scan(step, h0,
                             (xh.astype(jnp.float32),
                              dth.astype(jnp.float32),
                              bh_.astype(jnp.float32),
                              ch.astype(jnp.float32)))
        return ys

    ys = jax.vmap(head)(x, dt, a.astype(jnp.float32), b, c,
                        d.astype(jnp.float32))
    return ys.astype(x.dtype)


def mamba2_decode_step(h, xt, dtt, a, bt, ct, d):
    """One decode step: returns (h_new, y_t).  h: [BH, ds, dh]."""
    decay = jnp.exp(dtt * a)[..., None, None]          # [BH,1,1]
    h = decay * h + (dtt[..., None] * bt)[..., :, None] * xt[..., None, :]
    y = jnp.einsum("bs,bsd->bd", ct, h) + d[..., None] * xt
    return h, y


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

def rwkv6_ref(r, k, v, logw, u):
    """Per-step recurrent oracle.  Shapes as rwkv6_scan."""
    bh, s, dk = r.shape
    dv = v.shape[-1]

    def head(rh, kh, vh, wh, uh):
        def step(S, inp):
            rt, kt, vt, lwt = inp
            y = rt @ (S + uh[:, None] * jnp.outer(kt, vt))
            S = jnp.exp(lwt)[:, None] * S + jnp.outer(kt, vt)
            return S, y

        s0 = jnp.zeros((dk, dv), jnp.float32)
        _, ys = jax.lax.scan(step, s0,
                             (rh.astype(jnp.float32),
                              kh.astype(jnp.float32),
                              vh.astype(jnp.float32),
                              wh.astype(jnp.float32)))
        return ys

    ys = jax.vmap(head)(r, k, v, logw, u.astype(jnp.float32))
    return ys.astype(v.dtype)


def rwkv6_decode_step(S, rt, kt, vt, logwt, u):
    """One decode step.  S: [BH, dk, dv]."""
    y = jnp.einsum("bk,bkv->bv", rt,
                   S + (u * kt)[..., :, None] * vt[..., None, :])
    S = jnp.exp(logwt)[..., :, None] * S + kt[..., :, None] * vt[..., None, :]
    return S, y


# ---------------------------------------------------------------------------
# chunked jnp twins (same math as the Pallas kernels, with state carry —
# used by prefill paths that must return the final recurrent state, and as
# the fast non-Pallas fallback)
# ---------------------------------------------------------------------------

def mamba2_chunked_jnp(x, dt, a, b, c, d, *, chunk=64, h0=None,
                       return_final=False):
    """Chunk-parallel SSD scan in pure jnp.  Shapes as mamba2_scan."""
    bh, s, dh = x.shape
    ds = b.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def to_chunks(t):
        return t.reshape(bh, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, bc, cc = (to_chunks(t.astype(jnp.float32))
                       for t in (x, dt, b, c))
    af = a.astype(jnp.float32)

    def step(h, inp):
        xq, dtq, bq, cq = inp                     # [bh, Q, ...]
        log_a = dtq * af[:, None]                 # [bh, Q]
        cum = jnp.cumsum(log_a, axis=1)
        ii = jnp.arange(chunk)
        tri = ii[:, None] >= ii[None, :]
        sqq = jnp.einsum("bqs,bks->bqk", cq, bq)
        decay = jnp.where(tri[None], jnp.exp(cum[:, :, None]
                                             - cum[:, None, :]), 0.0)
        y = jnp.einsum("bqk,bkd->bqd", sqq * decay * dtq[:, None, :], xq)
        y += jnp.exp(cum)[..., None] * jnp.einsum("bqs,bsd->bqd", cq, h)
        total = cum[:, -1]
        w = jnp.exp(total[:, None] - cum) * dtq
        h = (jnp.exp(total)[:, None, None] * h
             + jnp.einsum("bqs,bqd->bsd", bq * w[..., None], xq))
        return h, y

    if h0 is None:
        h0 = jnp.zeros((bh, ds, dh), jnp.float32)
    hf, ys = jax.lax.scan(step, h0, (xc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(bh, -1, dh)[:, :s]
    y = y + (d.astype(jnp.float32)[:, None, None]
             * x[:, :s].astype(jnp.float32))
    y = y.astype(x.dtype)
    return (y, hf) if return_final else y


def rwkv6_chunked_jnp(r, k, v, logw, u, *, chunk=32, s0=None,
                      return_final=False):
    """Chunk-parallel RWKV6 scan in pure jnp.  Shapes as rwkv6_scan."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0)))
    nc = r.shape[1] // chunk

    def to_chunks(t):
        return t.reshape(bh, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    rc, kc, vc, wc = (to_chunks(t.astype(jnp.float32))
                      for t in (r, k, v, logw))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rq, kq, vq, wq = inp                      # [bh, Q, ...]
        cum = jnp.cumsum(wq, axis=1)
        cum_prev = cum - wq
        r_s = rq * jnp.exp(cum_prev)
        k_s = kq * jnp.exp(-cum)
        att = jnp.einsum("bqk,bsk->bqs", r_s, k_s)
        ii = jnp.arange(chunk)
        att = jnp.where((ii[:, None] > ii[None, :])[None], att, 0.0)
        bonus = jnp.einsum("bqk,bqk->bq", rq * uf[:, None], kq)
        y = jnp.einsum("bqs,bsv->bqv", att, vq) + bonus[..., None] * vq
        y += jnp.einsum("bqk,bkv->bqv", r_s, S)
        p_last = jnp.exp(cum[:, -1])
        k_up = kq * jnp.exp(cum[:, -1][:, None] - cum)
        S = p_last[..., None] * S + jnp.einsum("bqk,bqv->bkv", k_up, vq)
        return S, y

    if s0 is None:
        s0 = jnp.zeros((bh, dk, dv), jnp.float32)
    sf, ys = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    y = ys.swapaxes(0, 1).reshape(bh, -1, dv)[:, :s].astype(v.dtype)
    return (y, sf) if return_final else y


# ---------------------------------------------------------------------------
# dispatch pack
# ---------------------------------------------------------------------------

def pack_ref(tokens, bitmap, valid, num_dests, capacity):
    """jnp oracle == core.collectives.pack_by_bitmap (shared semantics)."""
    from repro.core.collectives import pack_by_bitmap
    return pack_by_bitmap(tokens, bitmap, valid, num_dests, capacity)
