"""Pallas TPU kernel: RWKV-6 ("Finch") chunked time-mix scan.

rwkv6-7b's compute hot spot.  Per head, with data-dependent per-channel
decay w_t in (0,1) and bonus u:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T                  (S: [dk, dv])

Chunked evaluation: per-channel cumulative decays P_t = prod_{m<=t} w_m
turn the intra-chunk sum into a strictly-lower-triangular [Q, Q] matmul of
scaled r~ = r * P_{t-1} and k~ = k / P_t vectors (plus the diag(u) bonus
term), and the state is carried in VMEM scratch across chunks — same grid
structure as the mamba2 kernel.

Numerics: P ratios are formed in log space; the chunk length bounds the
log-range (default 32) so k/P stays in f32 range for realistic decays.

Oracle: :func:`repro.kernels.ref.rwkv6_ref` (per-step lax.scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, h_ref, *,
                  chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    r = r_ref[0].astype(jnp.float32)          # [Q, dk]
    k = k_ref[0].astype(jnp.float32)          # [Q, dk]
    v = v_ref[0].astype(jnp.float32)          # [Q, dv]
    logw = w_ref[0].astype(jnp.float32)       # [Q, dk] log decays (<= 0)
    u = u_ref[0].astype(jnp.float32)          # [dk]

    cum = jnp.cumsum(logw, axis=0)            # [Q, dk] inclusive log P_t
    cum_prev = cum - logw                     # log P_{t-1} (P_{-1} = 1)
    r_s = r * jnp.exp(cum_prev)               # r~
    k_s = k * jnp.exp(-cum)                   # k~
    # strictly lower triangular intra-chunk attention + bonus diagonal
    att = jax.lax.dot_general(r_s, k_s, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(ii > jj, att, 0.0)
    bonus = jnp.sum(r * u[None, :] * k, axis=1)          # [Q]
    y = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y += bonus[:, None] * v
    # state contribution
    h_prev = h_ref[...]                       # [dk, dv]
    y += jax.lax.dot_general(r_s, h_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[0] = y.astype(o_ref.dtype)
    # state update: S = diag(P_last) h_prev + sum_j (P_last / P_j) k_j v_j^T
    p_last = jnp.exp(cum[-1])                 # [dk]
    k_up = k * jnp.exp(cum[-1][None, :] - cum)           # [Q, dk]
    h_ref[...] = p_last[:, None] * h_prev + jax.lax.dot_general(
        k_up, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
               u: jax.Array, *, chunk: int = 32,
               interpret: bool = True) -> jax.Array:
    """Chunked RWKV6 time-mix.

    Args:
      r, k: [BH, S, dk]; v: [BH, S, dv].
      logw: [BH, S, dk] log decays (<= 0; w = exp(logw)).
      u:    [BH, dk] bonus.
      chunk: chunk length Q.

    Returns: y [BH, S, dv] in v.dtype.
    """
    bh, s, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0)))
    sp = r.shape[1]
    nc = sp // chunk
    out = pl.pallas_call(
        functools.partial(_rwkv6_kernel, chunk=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, dv), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, dk), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, dk), lambda h, i: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return out[:, :s]
