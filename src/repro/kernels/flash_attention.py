"""Pallas TPU kernel: FlashAttention-2 style fused attention.

The dense-transformer compute hot spot.  Online-softmax tiling over the KV
sequence with q/k/v blocks staged through VMEM; supports

  * causal masking,
  * sliding-window attention (gemma2 local layers),
  * logit soft-capping (gemma2),
  * GQA (kv heads broadcast outside the kernel — the kernel sees matched
    head counts).

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost so the running
(max, sum, acc) state for one q block lives in VMEM scratch across kv
steps.  Block sizes default to MXU-aligned (128) tiles.

Oracle: :func:`repro.kernels.ref.attention_ref` (pure jnp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 softcap: float | None, block_q: int, block_k: int,
                 num_kv_blocks: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # [Bq, D]
    k = k_ref[0].astype(jnp.float32)                    # [Bk, D]
    v = v_ref[0].astype(jnp.float32)                    # [Bk, D]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < kv_len          # padded keys never attend
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # [Bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # [Bq, Bk]
    corr = jnp.exp(m_prev - m_new)                      # [Bq, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = jnp.where(
            l > 0, acc_ref[...] / jnp.where(l > 0, l, 1.0), 0.0
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Fused attention.  q/k/v: [BH, S, D] (matched heads; GQA broadcast is
    the caller's job).  Returns [BH, S, D] in q's dtype."""
    bh, s_len, d = q.shape
    assert k.shape == v.shape == (bh, k.shape[1], d)
    kv_len = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, s_len)
    block_k = min(block_k, kv_len)
    pad_q = (-s_len) % block_q
    pad_k = (-kv_len) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    sq, sk = q.shape[1], k.shape[1]
    nq, nk = sq // block_q, sk // block_k
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :s_len]
    return out
