"""Pallas TPU kernel: bitmap-driven dispatch packing (paper cs_send/cs_relay).

This is the compute hot spot of the MultiWrite data plane: given N token
rows and a per-row destination bitmap (the §4.1 in-packet metadata), pack
each row into the send buffer of every destination whose bit is set —
ONE buffer slot per (row, destination), capacity-bounded, token-order
priority.  The same kernel serves

  * the source node's send-buffer build (cs_send: stage-1 pod packing),
  * the relay's replicate-and-forward step (cs_relay: stage-2 ep packing),
  * local per-expert grouping (stage 3),

because the recursive execution model (§4.3.3) runs the *same logic at
every node*.

TPU adaptation: rather than a byte-stream packet copy loop (AICPU), the
kernel is tiled for VMEM — the grid is (num_dests, row_blocks); each
program scans a [block_rows, H] tile resident in VMEM, tests its
destination bit, and appends matching rows to the destination's output
tile with a running counter in SMEM.  H should be lane-aligned (multiples
of 128) for production shapes.

Validated against :func:`repro.kernels.ref.pack_ref` (== the jnp
implementation used by core/collectives.py) in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(bitmap_ref, valid_ref, tok_ref, out_ref, idx_ref,
                 count_ref, *, capacity: int, block_rows: int):
    d = pl.program_id(0)
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        idx_ref[...] = jnp.full_like(idx_ref, -1)
        count_ref[0] = 0

    rows = tok_ref[0]                                   # [Bn, H]
    bits = (bitmap_ref[0] >> d) & 1                     # [Bn]
    ok = (bits == 1) & (valid_ref[0] == 1)              # [Bn] bool
    base = count_ref[0]
    oki = ok.astype(jnp.int32)
    pos = base + jnp.cumsum(oki) - oki                  # slot per row

    for i in range(block_rows):                         # static unroll
        @pl.when(ok[i] & (pos[i] < capacity))
        def _store(i=i):
            out_ref[0, pl.dslice(pos[i], 1), :] = rows[i][None, :]
            idx_ref[0, pl.dslice(pos[i], 1)] = jnp.full(
                (1,), nb * block_rows + i, jnp.int32)

    count_ref[0] = base + jnp.sum(oki)


@functools.partial(jax.jit,
                   static_argnames=("num_dests", "capacity", "block_rows",
                                    "interpret"))
def dispatch_pack(tokens: jax.Array, bitmap: jax.Array, valid: jax.Array,
                  *, num_dests: int, capacity: int, block_rows: int = 8,
                  interpret: bool = True):
    """Pack rows into per-destination buffers (Pallas).

    Args:
      tokens: [N, H] rows.
      bitmap: [N] int32 destination bitmap (bit d => destination d).
      valid:  [N] bool.
      num_dests: D <= 31.
      capacity: C slots per destination.
      block_rows: VMEM row-tile size.
      interpret: run the kernel body in interpret mode (CPU validation).

    Returns:
      (out [D, C, H], src_idx [D, C] int32 with -1 for empty slots).
    """
    n, h = tokens.shape
    assert num_dests <= 31
    pad = (-n) % block_rows
    if pad:
        tokens = jnp.concatenate(
            [tokens, jnp.zeros((pad, h), tokens.dtype)])
        bitmap = jnp.concatenate([bitmap, jnp.zeros((pad,), bitmap.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])
    nb = tokens.shape[0] // block_rows
    grid = (num_dests, nb)
    kernel = functools.partial(_pack_kernel, capacity=capacity,
                               block_rows=block_rows)
    out, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda d, b: (0, b)),
            pl.BlockSpec((1, block_rows), lambda d, b: (0, b)),
            pl.BlockSpec((1, block_rows, h), lambda d, b: (0, b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, capacity, h), lambda d, b: (d, 0, 0)),
            pl.BlockSpec((1, capacity), lambda d, b: (d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_dests, capacity, h), tokens.dtype),
            jax.ShapeDtypeStruct((num_dests, capacity), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(bitmap.astype(jnp.int32)[None],
      valid.astype(jnp.int32)[None],
      tokens[None])
    return out, idx
