"""Batched serving engine: prefill + KV-cache decode.

Production shape: requests are padded into fixed batch slots, prefilled
once, then decoded step-by-step with the jitted decode function (cache
donated each step).  Greedy or temperature sampling.  Per-slot stop
handling; slots keep decoding until all hit max_new or EOS (static-shape
friendly — finished slots are masked, not removed).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _metrics():
    """Lazy: the metrics plane lives in repro.telemetry, which must not
    be a hard import of the runtime layer."""
    from repro.telemetry import metrics as _m
    return _m.default_registry()


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    eos_id: Optional[int] = None
    cache_dtype: object = jnp.bfloat16


class ServeEngine:
    def __init__(self, model, params, cfg: ServeConfig = ServeConfig(),
                 pctx=None, fabric=None, calibration=None, monitor=None):
        """``fabric``: optional fabric spec/name (see
        ``core.topology.get_fabric``) the planner scores against instead
        of the mesh-derived shape — the serving side of ``--fabric``.
        ``calibration``: optional telemetry CalibrationStore (or path):
        planner decisions are scored under the store's fitted hardware
        model.  ``monitor``: optional telemetry DriftMonitor whose
        predicted-vs-measured state ``plan_report`` surfaces."""
        self.model = model
        self.params = params
        self.cfg = cfg
        if pctx is not None and (fabric is not None
                                 or calibration is not None):
            import dataclasses as _dc

            from repro.core.topology import get_fabric
            repl = {}
            if fabric is not None:
                repl["fabric"] = (get_fabric(fabric)
                                  if isinstance(fabric, str) else fabric)
            if calibration is not None:
                repl["calibration"] = calibration
            pctx = _dc.replace(pctx, **repl)
        self.pctx = pctx
        self.monitor = monitor
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode, donate_argnums=(2,))
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0}
        self._stale_warned = False

    def serving_program(self, batch: int, prompt_len: int):
        """The declared collective program of this serving shape: both
        phases' coupled MoE (dispatch, combine) pairs — prefill at
        batch*prompt_len tokens, decode at batch tokens — plus the
        split-TP boundary gather when the context emits one.  This is
        what gets jointly planned and bound; decode typically stays on
        the unicast pair (small payload, Fig 8) while prefill crosses to
        MultiWrite with a shared microbatch G > 1 (decode has no compute
        to hide chunks behind).  Sites assume bf16 activations (the
        production serving dtype; fp32 smoke launchers bind their own
        program with the right itemsize before building the model)."""
        from repro.parallel.context import build_collective_program
        return build_collective_program(
            self.model.cfg, self.pctx, "serve",
            {"prefill": (batch, prompt_len), "decode": (batch, 1)})

    def execution_plan(self, batch: int, prompt_len: int):
        """The jointly-planned ExecutionPlan for this serving shape: the
        context's bound plan when one covers these phases (serve.py
        binds before building the model, so the traces consumed exactly
        this), else a fresh ``plan_program`` on the context's fabric and
        calibration."""
        if self.pctx is None:
            return None
        bound = self.pctx.execution_plan
        if bound is not None:
            return bound
        program = self.serving_program(batch, prompt_len)
        if not program.sites or self.pctx.plan_policy != "auto":
            return None
        return self.pctx.plan_collectives(program)

    def plan_report(self, batch: int, prompt_len: int) -> dict:
        """Per-phase view of the jointly-planned serving program: each
        phase's dispatch and combine site decisions plus the JOINT
        pipeline verdict (shared microbatch G, combined predicted
        latency) — the decisions the jitted MoE layers consume at trace
        time, resolved against the same bound ExecutionPlan."""
        out = {}
        if self.monitor is not None:
            # predicted-vs-measured error + last re-calibration, from the
            # telemetry drift monitor (the serving face of the loop)
            out["calibration"] = self.monitor.report()
        eplan = self.execution_plan(batch, prompt_len)
        if eplan is None:
            return out
        out["execution_plan"] = eplan.fingerprint
        if self.pctx is not None and self.pctx.execution_plan is eplan:
            # a replan (drift recalibration) may have superseded the
            # bound plan's fingerprint; the traces still execute the OLD
            # plan until a re-bind — surface that instead of hiding it
            stale = self.pctx.bound_plan_stale()
            if stale is not None:
                out["stale"] = stale
                if stale and not self._stale_warned:
                    self._stale_warned = True
                    _metrics()["repro_plan_stale_total"].inc(
                        program=eplan.program.name,
                        fingerprint=eplan.fingerprint)
                    print(f"WARNING: bound ExecutionPlan "
                          f"{eplan.fingerprint} is stale — a replan "
                          f"chose different decisions for this program; "
                          f"serving continues on the old plan until "
                          f"re-bind/re-trace")
        if eplan.phase_report:
            out["phases"] = {ph: dict(rep)
                             for ph, rep in eplan.phase_report.items()}
            # phase-budget SLO verdicts, scrape-visible: 1/0 for budgeted
            # phases, plus every phase's predicted (contended) score
            reg = _metrics()
            for ph, rep in eplan.phase_report.items():
                score = rep.get("contended_score_s", rep.get("score_s"))
                if score is not None:
                    reg["repro_phase_predicted_seconds"].set(
                        score, phase=ph, fingerprint=eplan.fingerprint)
                if rep.get("budget_s") is not None:
                    reg["repro_phase_budget_ok"].set(
                        1.0 if rep.get("budget_ok") else 0.0,
                        phase=ph, fingerprint=eplan.fingerprint)
        if eplan.planner_stats:
            out["planner"] = dict(eplan.planner_stats)
        for site in eplan.program.sites:
            phase, _, kind = site.role.partition("/")
            if kind == "moe_dispatch":
                cell = out.setdefault(phase, {})
                cell["dispatch"] = eplan.decision(site.role).report()
                joint = eplan.joint.get(site.role)
                if joint is not None:
                    cell["joint"] = joint.report()
            elif kind == "moe_combine":
                out.setdefault(phase, {})["combine"] = \
                    eplan.decision(site.role).report()
            elif kind == "split_tp_gather":
                out.setdefault(phase, {})["split_tp_gather"] = \
                    eplan.decision(site.role).report()
        return out

    def generate(self, prompts: np.ndarray, max_new: Optional[int] = None,
                 seed: int = 0) -> np.ndarray:
        """prompts: [B, S] int32 (already padded).  Returns [B, max_new]."""
        cfg = self.model.cfg
        b, s = prompts.shape
        max_new = max_new or self.cfg.max_new_tokens
        plans = self.plan_report(b, s)
        if plans:
            self.stats["plans"] = plans
        cache = self.model.init_cache(b, s + max_new, self.cfg.cache_dtype)
        t0 = time.monotonic()
        from repro.data.pipeline import batch_for_model
        batch = batch_for_model(
            cfg, {"tokens": prompts, "labels": prompts})
        batch.pop("labels", None)
        logits, cache = self._prefill(self.params, batch, cache)
        dt = time.monotonic() - t0
        self.stats["prefill_s"] += dt
        _metrics()["repro_step_wall_seconds"].observe(dt, phase="prefill")
        out = np.zeros((b, max_new), np.int32)
        done = np.zeros((b,), bool)
        key = jax.random.key(seed)
        t0 = time.monotonic()
        for t in range(max_new):
            if self.cfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, jnp.asarray(logits) / self.cfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = np.asarray(nxt, np.int32)
            out[:, t] = np.where(done, 0, nxt)
            if self.cfg.eos_id is not None:
                done |= nxt == self.cfg.eos_id
                if done.all():
                    break
            dec_in = self._decode_batch(nxt[:, None])
            logits, cache = self._decode(self.params, dec_in, cache)
        dt = time.monotonic() - t0
        self.stats["decode_s"] += dt
        _metrics()["repro_step_wall_seconds"].observe(dt, phase="decode")
        self.stats["tokens"] += int((~done).sum()) * max_new
        return out

    def _decode_batch(self, tokens: np.ndarray):
        cfg = self.model.cfg
        if cfg.input_mode == "embeddings" and cfg.family != "encdec":
            # stub frontend: decode feeds token embeddings through the table
            # is not available; hash-embed like the pipeline stub.
            from repro.data.pipeline import _stub_embed
            return {"embeds": jnp.asarray(_stub_embed(tokens, cfg.d_model))}
        return {"tokens": jnp.asarray(tokens)}
