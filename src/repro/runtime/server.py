"""Batched serving engine: prefill + KV-cache decode.

Production shape: requests are padded into fixed batch slots, prefilled
once, then decoded step-by-step with the jitted decode function (cache
donated each step).  Greedy or temperature sampling.  Per-slot stop
handling; slots keep decoding until all hit max_new or EOS (static-shape
friendly — finished slots are masked, not removed).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _metrics():
    """Lazy: the metrics plane lives in repro.telemetry, which must not
    be a hard import of the runtime layer."""
    from repro.telemetry import metrics as _m
    return _m.default_registry()


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    eos_id: Optional[int] = None
    cache_dtype: object = jnp.bfloat16


@dataclasses.dataclass
class _ServeLowering:
    """Traced-lowering artifact of one ExecutionPlan: the jitted
    prefill/decode pair whose MoE layers consumed exactly that plan's
    decisions at trace time, plus the bound context they read them
    from."""
    pctx: object
    model: object
    prefill: Callable
    decode: Callable


@dataclasses.dataclass
class CohortState:
    """In-flight decode state of one cohort (one prefill's worth of
    requests, position-aligned): the KV/recurrent cache, the last
    logits, and the sampling key."""
    cache: object
    logits: object
    key: object
    batch: int


class ServeEngine:
    def __init__(self, model, params, cfg: ServeConfig = ServeConfig(),
                 pctx=None, fabric=None, calibration=None, monitor=None,
                 model_builder=None):
        """``fabric``: optional fabric spec/name (see
        ``core.topology.get_fabric``) the planner scores against instead
        of the mesh-derived shape — the serving side of ``--fabric``.
        ``calibration``: optional telemetry CalibrationStore (or path):
        planner decisions are scored under the store's fitted hardware
        model.  ``monitor``: optional telemetry DriftMonitor whose
        predicted-vs-measured state ``plan_report`` surfaces.
        ``model_builder``: optional ``pctx -> Model`` rebuilding the
        model functions against a re-bound context (defaults to
        ``models.api.build_model`` on the same config) — what
        :meth:`rebind` traces when a replan swaps in."""
        self.model = model
        self.params = params
        self.cfg = cfg
        if pctx is not None and (fabric is not None
                                 or calibration is not None):
            import dataclasses as _dc

            from repro.core.topology import get_fabric
            repl = {}
            if fabric is not None:
                repl["fabric"] = (get_fabric(fabric)
                                  if isinstance(fabric, str) else fabric)
            if calibration is not None:
                repl["calibration"] = calibration
            pctx = _dc.replace(pctx, **repl)
        self.pctx = pctx
        self.monitor = monitor
        self._model_builder = model_builder
        from repro.parallel.context import PlanBinder
        initial = pctx.execution_plan if pctx is not None else None
        self._binder = PlanBinder(self._trace_plan, plan=initial)
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0}
        self._stale_warned = False
        # (batch, prompt_len)-keyed memos: per-step scheduler queries
        # (plan_report, admission probes) must never re-derive the
        # program or re-plan — the planner LRU stays warm and these
        # stay O(1) on the hot path
        self._programs: dict = {}
        self._plan_cache: dict = {}
        self._probe = None

    # -- hot plan re-bind -----------------------------------------------------
    def _trace_plan(self, plan) -> _ServeLowering:
        """PlanBinder trace_fn: (re)build + jit the phase functions under
        ``plan``.  The initial bind reuses the already-constructed model
        (serve.py binds the plan before building it, so its closures
        consumed exactly this plan); a re-bind constructs fresh model
        closures over the newly-bound context so the next trace reads
        the new decisions."""
        base_plan = self.pctx.execution_plan if self.pctx is not None \
            else None
        if plan is base_plan or self.pctx is None:
            pctx, model = self.pctx, self.model
        else:
            pctx = self.pctx.bind(plan)
            if self._model_builder is not None:
                model = self._model_builder(pctx)
            else:
                from repro.models.api import build_model
                model = build_model(self.model.cfg, pctx)
        return _ServeLowering(
            pctx=pctx, model=model, prefill=jax.jit(model.prefill),
            decode=jax.jit(model.decode, donate_argnums=(2,)))

    def rebind(self, plan) -> bool:
        """Stage ``plan`` (e.g. a failover replan from the drift
        monitor) for hot re-bind: its lowering is built NOW, off the
        request path, and swapped in atomically at the next
        :meth:`generate` entry.  Returns True when a swap is pending."""
        self.invalidate_plan_cache()
        return self._binder.stage(plan)

    @property
    def plan_binder(self):
        return self._binder

    @property
    def _prefill(self):
        return self._binder.artifact.prefill

    @property
    def _decode(self):
        return self._binder.artifact.decode

    def serving_program(self, batch: int, prompt_len: int):
        """The declared collective program of this serving shape: both
        phases' coupled MoE (dispatch, combine) pairs — prefill at
        batch*prompt_len tokens, decode at batch tokens — plus the
        split-TP boundary gather when the context emits one.  This is
        what gets jointly planned and bound; decode typically stays on
        the unicast pair (small payload, Fig 8) while prefill crosses to
        MultiWrite with a shared microbatch G > 1 (decode has no compute
        to hide chunks behind).  Sites assume bf16 activations (the
        production serving dtype; fp32 smoke launchers bind their own
        program with the right itemsize before building the model).

        Memoized on ``(batch, prompt_len)``: per-step scheduler queries
        reuse the declared program instead of re-deriving its sites."""
        key = (int(batch), int(prompt_len))
        program = self._programs.get(key)
        if program is None:
            from repro.parallel.context import build_collective_program
            program = build_collective_program(
                self.model.cfg, self.pctx, "serve",
                {"prefill": (batch, prompt_len), "decode": (batch, 1)})
            self._programs[key] = program
        return program

    def invalidate_plan_cache(self) -> None:
        """Drop memoized fresh plans (a recalibration or re-bind may
        have changed what planning would choose; the declared programs
        themselves are shape-only and stay)."""
        self._plan_cache.clear()

    def _fresh_plan(self, batch: int, prompt_len: int):
        """Fresh jointly-planned ExecutionPlan for this exact serving
        shape, memoized on ``(batch, prompt_len)`` — repeated per-step
        queries hit this dict (and underneath it the planner LRU), not
        a re-plan."""
        key = (int(batch), int(prompt_len))
        if key in self._plan_cache:
            return self._plan_cache[key]
        program = self.serving_program(batch, prompt_len)
        plan = None
        if program.sites and self.pctx.plan_policy == "auto":
            plan = self.pctx.plan_collectives(program)
        self._plan_cache[key] = plan
        return plan

    def execution_plan(self, batch: int, prompt_len: int):
        """The jointly-planned ExecutionPlan for this serving shape: the
        context's bound plan when one covers these phases (serve.py
        binds before building the model, so the traces consumed exactly
        this), else a fresh ``plan_program`` on the context's fabric and
        calibration."""
        if self.pctx is None:
            return None
        # the binder's ACTIVE plan (post-swap) supersedes the context's
        # construction-time binding once a hot re-bind has landed
        bound = self._binder.plan or self.pctx.execution_plan
        if bound is not None:
            return bound
        return self._fresh_plan(batch, prompt_len)

    # -- batch-bucket plan prefetch (the serving tier's admission seam) ------
    def bucket_plan(self, batch: int, prompt_len: int):
        """ExecutionPlan for the BUCKETED serving shape — what the
        admission controller stages ahead of growing the decode batch
        across a bucket boundary.  None when the context cannot plan
        (no context, pinned policy, or no collective sites)."""
        if self.pctx is None or self.pctx.plan_policy != "auto":
            return None
        from repro.core.plan import batch_bucket
        return self._fresh_plan(batch_bucket(max(1, batch)), prompt_len)

    def prefetch_bucket(self, batch: int, prompt_len: int) -> bool:
        """Warm the traced-lowering cache for the bucketed serving
        shape's plan, off the step path (``PlanBinder.prefetch``), so a
        later admission across the bucket boundary swaps on a pointer
        flip.  Returns True when a lowering was built."""
        plan = self.bucket_plan(batch, prompt_len)
        if plan is None:
            return False
        return self._binder.prefetch(plan)

    def plan_probe(self, itemsize: int = 2):
        """PlannerProbe over this engine's fabric/calibration — the
        admission controller's latency oracle.  ``itemsize`` must match
        the traced activation dtype (2 = bf16 production, 4 = fp32
        smoke).  None when the engine has no parallel context."""
        if self._probe is not None:
            return self._probe
        if self.pctx is None:
            return None
        from repro.serving.admission import PlannerProbe
        cfg = self.model.cfg
        topo, hw = self.pctx._plan_topo_hw(
            getattr(cfg, "num_experts", 0) or 0)
        self._probe = PlannerProbe(
            topo, token_bytes=cfg.d_model * itemsize,
            num_experts=getattr(cfg, "num_experts", 0) or 64,
            top_k=getattr(cfg, "top_k", 0) or 8, hw=hw,
            d_model=cfg.d_model, tp=self.pctx.model_size)
        return self._probe

    def plan_report(self, batch: int, prompt_len: int) -> dict:
        """Per-phase view of the jointly-planned serving program: each
        phase's dispatch and combine site decisions plus the JOINT
        pipeline verdict (shared microbatch G, combined predicted
        latency) — the decisions the jitted MoE layers consume at trace
        time, resolved against the same bound ExecutionPlan."""
        out = {}
        if self.monitor is not None:
            # predicted-vs-measured error + last re-calibration, from the
            # telemetry drift monitor (the serving face of the loop)
            out["calibration"] = self.monitor.report()
        eplan = self.execution_plan(batch, prompt_len)
        if eplan is None:
            return out
        out["execution_plan"] = eplan.fingerprint
        if self.pctx is not None and self.pctx.execution_plan is eplan:
            # a replan (drift recalibration) may have superseded the
            # bound plan's fingerprint; the traces still execute the OLD
            # plan until a re-bind — surface that instead of hiding it
            stale = self.pctx.bound_plan_stale()
            if stale is not None:
                out["stale"] = stale
                if stale:
                    # hot re-bind instead of the old warn-and-limp flow:
                    # when the drift monitor retargeted this program
                    # (failover/failback), stage its replacement plan —
                    # the swap lands at the next generate() boundary
                    staged = None
                    if self.monitor is not None:
                        staged = self.monitor.staged_plan(
                            eplan.program.name)
                    if staged is not None:
                        out["restaged"] = self.rebind(staged)
                    elif not self._stale_warned:
                        self._stale_warned = True
                        _metrics()["repro_plan_stale_total"].inc(
                            program=eplan.program.name,
                            fingerprint=eplan.fingerprint)
                        print(f"WARNING: bound ExecutionPlan "
                              f"{eplan.fingerprint} is stale — a replan "
                              f"chose different decisions for this "
                              f"program; serving continues on the old "
                              f"plan until re-bind/re-trace")
        if eplan.phase_report:
            out["phases"] = {ph: dict(rep)
                             for ph, rep in eplan.phase_report.items()}
            # phase-budget SLO verdicts, scrape-visible: 1/0 for budgeted
            # phases, plus every phase's predicted (contended) score
            reg = _metrics()
            for ph, rep in eplan.phase_report.items():
                score = rep.get("contended_score_s", rep.get("score_s"))
                if score is not None:
                    reg["repro_phase_predicted_seconds"].set(
                        score, phase=ph, fingerprint=eplan.fingerprint)
                if rep.get("budget_s") is not None:
                    reg["repro_phase_budget_ok"].set(
                        1.0 if rep.get("budget_ok") else 0.0,
                        phase=ph, fingerprint=eplan.fingerprint)
        if eplan.planner_stats:
            out["planner"] = dict(eplan.planner_stats)
        for site in eplan.program.sites:
            phase, _, kind = site.role.partition("/")
            if kind == "moe_dispatch":
                cell = out.setdefault(phase, {})
                cell["dispatch"] = eplan.decision(site.role).report()
                joint = eplan.joint.get(site.role)
                if joint is not None:
                    cell["joint"] = joint.report()
            elif kind == "moe_combine":
                out.setdefault(phase, {})["combine"] = \
                    eplan.decision(site.role).report()
            elif kind == "split_tp_gather":
                out.setdefault(phase, {})["split_tp_gather"] = \
                    eplan.decision(site.role).report()
        return out

    # -- the step-level cohort API (what the BatchScheduler drives) ----------
    def start_cohort(self, prompts: np.ndarray,
                     max_new: Optional[int] = None,
                     seed: int = 0):
        """Prefill one cohort of requests ([b, s] int32, already padded
        to one shared prompt_len) and sample its first tokens.  Returns
        ``(state, tokens, wall_s)`` — feed ``tokens`` back through
        :meth:`step_cohort` for each subsequent decode round."""
        cfg = self.model.cfg
        b, s = prompts.shape
        max_new = max_new or self.cfg.max_new_tokens
        model = self._binder.artifact.model
        t0 = time.monotonic()
        cache = model.init_cache(b, s + max_new, self.cfg.cache_dtype)
        from repro.data.pipeline import batch_for_model
        batch = batch_for_model(
            cfg, {"tokens": prompts, "labels": prompts})
        batch.pop("labels", None)
        logits, cache = self._prefill(self.params, batch, cache)
        state = CohortState(cache=cache, logits=logits,
                            key=jax.random.key(seed), batch=b)
        tokens = self._sample(state)
        return state, tokens, time.monotonic() - t0

    def step_cohort(self, state: "CohortState", tokens: np.ndarray):
        """One decode round: consume the cohort's last sampled tokens,
        sample the next.  Returns ``(state, tokens, wall_s)``."""
        t0 = time.monotonic()
        dec_in = self._decode_batch(np.asarray(tokens, np.int32)[:, None])
        state.logits, state.cache = self._decode(
            self.params, dec_in, state.cache)
        tokens = self._sample(state)
        return state, tokens, time.monotonic() - t0

    def _sample(self, state: "CohortState") -> np.ndarray:
        if self.cfg.temperature > 0:
            state.key, sub = jax.random.split(state.key)
            nxt = jax.random.categorical(
                sub, jnp.asarray(state.logits) / self.cfg.temperature,
                axis=-1)
        else:
            nxt = jnp.argmax(state.logits, axis=-1)
        return np.asarray(nxt, np.int32)

    def generate(self, prompts: np.ndarray, max_new: Optional[int] = None,
                 seed: int = 0) -> np.ndarray:
        """prompts: [B, S] int32 (already padded).  Returns [B, max_new].

        Thin client of the continuous-batching scheduler: the whole
        batch arrives at t=0 and drains as one cohort through the same
        :meth:`start_cohort`/:meth:`step_cohort` loop the serving tier
        interleaves — one code path, bit-exact either way under greedy
        decoding (rows are numerically independent)."""
        b, s = prompts.shape
        max_new = max_new or self.cfg.max_new_tokens
        # step boundary: a staged re-bind (failover replan) lands here —
        # pointer swap onto the pre-traced lowering, never mid-decode
        self._binder.swap_if_pending()
        plans = self.plan_report(b, s)
        if plans:
            self.stats["plans"] = plans
        from repro.serving.admission import AdmissionController
        from repro.serving.queue import Request, RequestQueue
        from repro.serving.scheduler import BatchScheduler
        queue = RequestQueue()
        for i in range(b):
            queue.push(Request(rid=i, arrival_s=0.0,
                               prompt=np.asarray(prompts[i], np.int32),
                               max_new=max_new))
        sched = BatchScheduler(
            queue=queue,
            admission=AdmissionController(capacity=b, policy="greedy"),
            engine=self, eos_id=self.cfg.eos_id, seed=seed)
        sched.run_until_drained()
        out = np.zeros((b, max_new), np.int32)
        never_eos = 0
        for req in sched.completed:
            toks = req.tokens[:max_new]
            out[req.rid, :len(toks)] = toks
            never_eos += 0 if req.eos else 1
        self.stats["prefill_s"] += sched.wall["prefill_s"]
        self.stats["decode_s"] += sched.wall["decode_s"]
        reg = _metrics()
        reg["repro_step_wall_seconds"].observe(
            sched.wall["prefill_s"], phase="prefill")
        reg["repro_step_wall_seconds"].observe(
            sched.wall["decode_s"], phase="decode")
        self.stats["tokens"] += never_eos * max_new
        return out

    def _decode_batch(self, tokens: np.ndarray):
        cfg = self.model.cfg
        if cfg.input_mode == "embeddings" and cfg.family != "encdec":
            # stub frontend: decode feeds token embeddings through the table
            # is not available; hash-embed like the pipeline stub.
            from repro.data.pipeline import _stub_embed
            return {"embeds": jnp.asarray(_stub_embed(tokens, cfg.d_model))}
        return {"tokens": jnp.asarray(tokens)}
