"""Fault-tolerant distributed training loop.

Production behaviors implemented (designed for the 1000+ node regime,
exercised here on CPU):

* **checkpoint/restart** — periodic sharded checkpoints (atomic commit via
  :mod:`repro.checkpoint.store`); on start, resume from the latest
  checkpoint and *deterministically skip* the data stream to the restored
  step (the pipeline is (seed, step)-addressable, so replay is bit-exact).
* **step retry + rollback** — a failing step (device error, preemption,
  injected fault) is retried; after ``max_retries`` the trainer rolls back
  to the last checkpoint and continues — the recovery path a node failure
  takes in production.
* **straggler mitigation** — per-step wall-time ledger with EWMA + MAD
  outlier detection; stragglers raise a callback that production wires to
  re-sharding / hot-sparing (here: recorded + surfaced in metrics).
* **gradient accumulation** microbatching, global-norm clipping, loss
  scaling hooks.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.optim.optimizers import Optimizer, apply_updates, \
    clip_by_global_norm

log = logging.getLogger("repro.trainer")


class TransientFault(RuntimeError):
    """A retryable failure (injected in tests; device errors in prod)."""


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    def tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "step": self.step}

    @classmethod
    def from_tree(cls, t):
        return cls(t["params"], t["opt"], t["step"])


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state, s.step), None),
    lambda _, ch: TrainState(*ch),
)


def make_train_step(model, optimizer: Optimizer, *, grad_accum: int = 1,
                    max_grad_norm: float = 1.0, donate: bool = True,
                    grad_sync: Optional[Callable[[Any], Any]] = None,
                    jit_kwargs: dict | None = None):
    """Build the jitted train step: grad-accum microbatching, clip, update.

    batch leaves must have a leading microbatch dim [grad_accum, ...] when
    grad_accum > 1.  ``jit_kwargs`` (e.g. out_shardings) are forwarded to
    jax.jit.

    ``grad_sync`` is the planner-routed gradient reduction hook: a
    callable applied to the grad pytree *before* clipping (e.g. a
    ``planned_psum`` closure over a shard_map data axis, or a compressed
    variant).  Under plain ``jax.jit`` the DP mean is already inserted
    implicitly by AD — which lowers to the flat ring the planner's
    "ring" decision models — so leave it ``None`` there; pass a hook
    only when the step runs inside shard_map with a bindable axis.
    """

    def step_fn(state: TrainState, batch):
        def loss_fn(p, mb):
            loss, metrics = model.loss(p, mb)
            return loss, metrics

        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), batch)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {}
        if grad_sync is not None:
            grads = grad_sync(grads)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params, state.step)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params, opt_state, state.step + 1)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, out_metrics

    donate_argnums = (0,) if donate else ()
    return jax.jit(step_fn, donate_argnums=donate_argnums,
                   **(jit_kwargs or {}))


@dataclasses.dataclass
class StragglerLedger:
    """EWMA + deviation tracking of per-step wall time."""
    alpha: float = 0.1
    threshold: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        if self.n < 5:          # warmup: compile steps excluded
            self.mean = dt if self.n == 0 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            self.n += 1
            return False
        dev = dt - self.mean
        self.var = (1 - self.alpha) * self.var + self.alpha * dev * dev
        sigma = max(self.var ** 0.5, 1e-6, 0.05 * self.mean)
        is_out = dev > self.threshold * sigma
        if is_out:
            self.events.append((step, dt, self.mean))
        else:
            self.mean += self.alpha * dev
        self.n += 1
        return is_out


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: Optional[str] = None
    keep_last_k: int = 3
    max_retries: int = 2
    log_every: int = 10


class Trainer:
    """Drives the train step with FT behaviors.  ``make_batch(step)`` must
    be deterministic in step (checkpoint/restart replays exactly)."""

    def __init__(self, model, optimizer: Optimizer, make_batch: Callable,
                 cfg: TrainerConfig, *, init_rng=None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 straggler_hook: Optional[Callable[[int, float], None]] = None,
                 step_hook: Optional[Callable[[int, dict], None]] = None,
                 train_step=None, plan_binder=None):
        self.model = model
        self.optimizer = optimizer
        self.make_batch = make_batch
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.straggler_hook = straggler_hook
        # called after every completed step with (step, metrics row) —
        # the online-calibration monitor rides here (launch/train.py
        # --calibrate online)
        self.step_hook = step_hook
        self.ledger = StragglerLedger()
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir,
                                       keep_last_k=cfg.keep_last_k)
                     if cfg.checkpoint_dir else None)
        # optional hot plan re-bind: a PlanBinder whose artifact IS the
        # jitted step function — a failover replan staged mid-run swaps
        # the step fn at the next step boundary without a cold retrace
        self.plan_binder = plan_binder
        if plan_binder is not None and plan_binder.artifact is not None:
            train_step = plan_binder.artifact
        self.train_step = train_step or make_train_step(model, optimizer,
                                                        donate=False)
        self.metrics_history: list[dict] = []
        init_rng = init_rng if init_rng is not None else jax.random.key(0)
        params = model.init(init_rng)
        self.state = TrainState(params, optimizer.init(params),
                                jnp.zeros((), jnp.int32))
        self._maybe_resume()

    # -- checkpoint/restart ----------------------------------------------------
    def _maybe_resume(self):
        if not self.ckpt:
            return
        latest = self.ckpt.latest_step()
        if latest is None:
            return
        tree, extra = self.ckpt.restore(latest, self.state.tree())
        self.state = TrainState.from_tree(tree)
        log.info("resumed from checkpoint step %s", latest)

    def _save(self, step: int):
        if self.ckpt:
            self.ckpt.save(step, self.state.tree(),
                           extra={"wall_time": time.time()})

    def _rollback(self):
        if not self.ckpt:
            raise RuntimeError("fault without checkpointing enabled")
        latest = self.ckpt.latest_step()
        if latest is None:
            raise RuntimeError("fault before first checkpoint")
        tree, _ = self.ckpt.restore(latest, self.state.tree())
        self.state = TrainState.from_tree(tree)
        log.warning("rolled back to checkpoint step %s", latest)

    # -- main loop ----------------------------------------------------------------
    def run(self) -> list[dict]:
        while int(self.state.step) < self.cfg.total_steps:
            step = int(self.state.step)
            if self.plan_binder is not None \
                    and self.plan_binder.swap_if_pending():
                # staged re-bind lands between steps: the pre-traced
                # step fn becomes active without stalling this step
                self.train_step = self.plan_binder.artifact
            batch = self.make_batch(step)
            t0 = time.monotonic()
            for attempt in range(self.cfg.max_retries + 1):
                try:
                    if self.fault_hook:
                        self.fault_hook(step)
                    new_state, metrics = self.train_step(self.state, batch)
                    break
                except TransientFault:
                    log.warning("transient fault at step %d (attempt %d)",
                                step, attempt + 1)
                    if attempt == self.cfg.max_retries:
                        self._rollback()
                        new_state, metrics = None, None
                        break
            if new_state is None:       # rolled back; re-enter loop
                continue
            self.state = new_state
            dt = time.monotonic() - t0
            if self.ledger.record(step, dt) and self.straggler_hook:
                self.straggler_hook(step, dt)
            row = {"step": step, "wall": dt,
                   **{k: float(v) for k, v in metrics.items()}}
            self.metrics_history.append(row)
            from repro.telemetry import metrics as _metrics
            _metrics.default_registry()["repro_step_wall_seconds"].observe(
                dt, phase="train")
            if self.step_hook:
                self.step_hook(step, row)
            if step % self.cfg.log_every == 0:
                log.info("step %d loss %.4f (%.0f ms)", step,
                         row.get("loss", float("nan")), dt * 1e3)
            next_step = step + 1
            if self.ckpt and next_step % self.cfg.checkpoint_every == 0:
                self._save(next_step)
        if self.ckpt:
            self._save(int(self.state.step))
        return self.metrics_history
