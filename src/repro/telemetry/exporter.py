"""Prometheus scrape endpoint + snapshot-to-file export.

Two delivery modes over the same rendered registry:

    MetricsExporter(port=9477).start()   stdlib ThreadingHTTPServer on a
                                         daemon thread serving GET
                                         /metrics (port=0 -> ephemeral,
                                         read back via .port)
    write_snapshot(path)                 one deterministic text file —
                                         what tests and --metrics-snapshot
                                         CI runs diff

No third-party dependencies: the scrape path must never be the thing
that takes the server down, and the stress harness scrapes its own
in-process exporter over real HTTP each epoch (the same bytes an
operator's Prometheus would pull).
"""

from __future__ import annotations

import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .metrics import MetricsRegistry, default_registry

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _Handler(BaseHTTPRequestHandler):
    registry: MetricsRegistry  # set per-server via type()

    def do_GET(self):  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path not in ("/metrics", "/"):
            self.send_error(404, "only /metrics is served here")
            return
        body = self.registry.render().encode("utf-8")
        self.send_response(200)
        self.send_header("Content-Type", CONTENT_TYPE)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):  # scrapes are not log events
        pass


class MetricsExporter:
    """Background /metrics HTTP server over a registry."""

    def __init__(self, port: int = 9477, *, host: str = "127.0.0.1",
                 registry: Optional[MetricsRegistry] = None) -> None:
        self.registry = registry if registry is not None else default_registry()
        self._requested_port = int(port)
        self.host = host
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        """The bound port (resolves port=0 after start())."""
        if self._server is not None:
            return self._server.server_address[1]
        return self._requested_port

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def start(self) -> "MetricsExporter":
        if self._server is not None:
            return self
        handler = type("BoundHandler", (_Handler,),
                       {"registry": self.registry})
        self._server = ThreadingHTTPServer(
            (self.host, self._requested_port), handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="metrics-exporter",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "MetricsExporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def write_snapshot(path: str,
                   registry: Optional[MetricsRegistry] = None) -> str:
    """Render the registry to ``path`` (parents created).  Rendering is
    deterministic — metrics sorted by name, series by label values — so
    two snapshots of identical state are byte-identical."""
    reg = registry if registry is not None else default_registry()
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    text = reg.render()
    with open(path, "w") as f:
        f.write(text)
    return text


def scrape(url: str, timeout: float = 5.0) -> str:
    """HTTP-GET a /metrics URL and return the body text (the stress
    harness's curl-equivalent)."""
    from urllib.request import urlopen
    with urlopen(url, timeout=timeout) as resp:
        return resp.read().decode("utf-8")


# -- launcher plumbing (train.py / serve.py / dryrun / stress share it) -----

def add_metrics_args(parser) -> None:
    parser.add_argument("--metrics-port", type=int, default=None,
                        metavar="PORT",
                        help="serve Prometheus text exposition at "
                             "http://127.0.0.1:PORT/metrics for the "
                             "lifetime of the run (0 = ephemeral port)")
    parser.add_argument("--metrics-snapshot", default=None, metavar="PATH",
                        help="write a final /metrics snapshot to PATH on "
                             "exit (the scrapeless CI/test mode)")


def start_exporter_from_args(args) -> Optional[MetricsExporter]:
    """Start the /metrics endpoint when --metrics-port was given."""
    port = getattr(args, "metrics_port", None)
    if port is None:
        return None
    exporter = MetricsExporter(port).start()
    print(f"metrics: serving Prometheus exposition at {exporter.url}")
    return exporter


def finish_exporter_from_args(args, exporter: Optional[MetricsExporter]
                              = None) -> None:
    """End-of-run half: write --metrics-snapshot, stop the endpoint."""
    path = getattr(args, "metrics_snapshot", None)
    if path:
        write_snapshot(path)
        print(f"metrics: snapshot written to {path}")
    if exporter is not None:
        exporter.stop()
