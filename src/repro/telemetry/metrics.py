"""Dependency-free metrics plane: counters / gauges / histograms with
Prometheus text exposition rendering.

This is the OBSERVABILITY face of the planner/telemetry loop — every
seam of the closed loop (planner decisions, drift watchdog,
recalibrations, plan binds/replans/stale events, step wall times, SLO
verdicts) increments a metric here, and the exporter
(:mod:`repro.telemetry.exporter`) serves the rendered registry at
``/metrics`` or snapshots it to a file.  Zero third-party dependencies:
a scrape target must never be the thing that breaks the server.

Label scheme (keep it small — cardinality is a production budget):

    op             collective op ("dispatch", "allgather", ...)
    payload_bucket power-of-two payload bucket (bytes, as a string)
    fabric         topology name the decision/probe was scored on
    phase          program phase ("train" | "prefill" | "decode")
    scheme         winning plan name ("unicast", "multiwrite", ...)
    program        declared CollectiveProgram name
    fingerprint    ExecutionPlan fingerprint (bind/replan/stale events)
    slo            SLO class ("good" | "acceptable" | "poor" | "unknown")

Every metric this plane can emit is declared ONCE in
:data:`METRIC_SPECS`; :func:`default_registry` pre-registers all of
them so a scrape always exposes the full schema (HELP/TYPE headers even
before the first sample) and METRICS.md can be checked against the spec
table mechanically (the CI docs-sync gate).
"""

from __future__ import annotations

import math
import threading
from typing import Iterable, Mapping, Optional, Sequence

# default latency buckets (seconds): 1us .. ~100s, 4 per decade — wide
# enough for a 10us decode collective and a multi-minute compile step
DEFAULT_BUCKETS = tuple(
    round(10.0 ** (e / 4.0), 10) for e in range(-24, 9)
)


def _escape_label(v: object) -> str:
    return (str(v).replace("\\", "\\\\").replace("\"", "\\\"")
            .replace("\n", "\\n"))


def _format_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _label_key(labelnames: Sequence[str], labels: Mapping) -> tuple:
    extra = set(labels) - set(labelnames)
    if extra:
        raise ValueError(f"unknown label(s) {sorted(extra)}; "
                         f"declared: {list(labelnames)}")
    return tuple(str(labels.get(name, "")) for name in labelnames)


class Metric:
    """Base: one named metric with a fixed label schema."""

    type = "untyped"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = ()) -> None:
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._values: dict[tuple, float] = {}

    # -- introspection (tests / snapshots) -----------------------------------
    def value(self, **labels) -> float:
        return self._values.get(_label_key(self.labelnames, labels), 0.0)

    def samples(self) -> list[tuple[dict, float]]:
        """[(labels dict, value), ...] sorted by label values."""
        return [(dict(zip(self.labelnames, key)), v)
                for key, v in sorted(self._values.items())]

    def clear(self) -> None:
        with self._lock:
            self._values.clear()

    # -- rendering -----------------------------------------------------------
    def _render_series(self, suffix: str, key: tuple, value: float,
                       extra: Sequence[tuple] = ()) -> str:
        pairs = [f'{n}="{_escape_label(v)}"'
                 for n, v in zip(self.labelnames, key)]
        pairs += [f'{n}="{_escape_label(v)}"' for n, v in extra]
        label_s = "{" + ",".join(pairs) + "}" if pairs else ""
        return f"{self.name}{suffix}{label_s} {_format_value(value)}"

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.type}"]
        for key in sorted(self._values):
            lines.append(self._render_series("", key, self._values[key]))
        return lines


class Counter(Metric):
    type = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount


class Gauge(Metric):
    type = "gauge"

    def set(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics: an observation
    equal to a bucket's upper bound ``le`` lands IN that bucket)."""

    type = "histogram"

    def __init__(self, name: str, help: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        super().__init__(name, help, labelnames)
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = tuple(bs)
        # per label key: [bucket counts..., +Inf count, sum]
        self._series: dict[tuple, list] = {}

    def observe(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        v = float(value)
        with self._lock:
            row = self._series.get(key)
            if row is None:
                row = [0] * (len(self.buckets) + 1) + [0.0]
                self._series[key] = row
            for i, b in enumerate(self.buckets):
                if v <= b:
                    row[i] += 1
                    break
            else:
                row[len(self.buckets)] += 1
            row[-1] += v

    # -- introspection -------------------------------------------------------
    def count(self, **labels) -> int:
        row = self._series.get(_label_key(self.labelnames, labels))
        return int(sum(row[:-1])) if row else 0

    def sum(self, **labels) -> float:
        row = self._series.get(_label_key(self.labelnames, labels))
        return float(row[-1]) if row else 0.0

    def bucket_counts(self, **labels) -> dict:
        """Cumulative count per ``le`` bound (including ``+Inf``)."""
        row = self._series.get(_label_key(self.labelnames, labels))
        if row is None:
            row = [0] * (len(self.buckets) + 1) + [0.0]
        out, acc = {}, 0
        for b, c in zip(self.buckets, row):
            acc += c
            out[b] = acc
        out[math.inf] = acc + row[len(self.buckets)]
        return out

    def samples(self) -> list[tuple[dict, float]]:
        return [(dict(zip(self.labelnames, key)), float(sum(row[:-1])))
                for key, row in sorted(self._series.items())]

    def clear(self) -> None:
        with self._lock:
            self._series.clear()

    def render(self) -> list[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} {self.type}"]
        for key in sorted(self._series):
            acc = 0
            row = self._series[key]
            for i, b in enumerate(self.buckets):
                acc += row[i]
                lines.append(self._render_series(
                    "_bucket", key, acc, extra=(("le", _format_value(b)),)))
            acc += row[len(self.buckets)]
            lines.append(self._render_series(
                "_bucket", key, acc, extra=(("le", "+Inf"),)))
            lines.append(self._render_series("_sum", key, row[-1]))
            lines.append(self._render_series("_count", key, acc))
        return lines


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Named metric collection rendering Prometheus text exposition."""

    def __init__(self) -> None:
        self._metrics: dict[str, Metric] = {}
        self._lock = threading.Lock()

    def _register(self, metric: Metric) -> Metric:
        with self._lock:
            existing = self._metrics.get(metric.name)
            if existing is not None:
                if type(existing) is not type(metric) or \
                        existing.labelnames != metric.labelnames:
                    raise ValueError(
                        f"metric {metric.name!r} re-registered with a "
                        f"different type/label schema")
                return existing
            self._metrics[metric.name] = metric
            return metric

    def counter(self, name: str, help: str,
                labelnames: Sequence[str] = ()) -> Counter:
        return self._register(Counter(name, help, labelnames))

    def gauge(self, name: str, help: str,
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge(name, help, labelnames))

    def histogram(self, name: str, help: str,
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram(name, help, labelnames, buckets))

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def __getitem__(self, name: str) -> Metric:
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def reset(self) -> None:
        """Zero every series (registrations stay)."""
        for m in self._metrics.values():
            m.clear()

    def render(self) -> str:
        """Prometheus text exposition (deterministic: metrics sorted by
        name, series sorted by label values)."""
        lines: list[str] = []
        for name in sorted(self._metrics):
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# text-format parsing (tests + the stress harness's scrape assertions)
# ---------------------------------------------------------------------------

def parse_text(text: str) -> dict:
    """Parse Prometheus text exposition back into
    ``{(name, (sorted (label, value) pairs)): float}`` — the round-trip
    half of the render/parse contract tests hold."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if "{" in line:
            name, _, rest = line.partition("{")
            labels_s, _, value_s = rest.rpartition("} ")
            labels = []
            for item in _split_labels(labels_s):
                k, _, v = item.partition("=")
                v = v.strip('"').replace("\\\"", "\"") \
                     .replace("\\n", "\n").replace("\\\\", "\\")
                labels.append((k, v))
            key = (name, tuple(sorted(labels)))
        else:
            name, _, value_s = line.rpartition(" ")
            key = (name, ())
        value_s = value_s.strip()
        value = (math.inf if value_s == "+Inf"
                 else -math.inf if value_s == "-Inf" else float(value_s))
        out[key] = value
    return out


def _split_labels(s: str) -> Iterable[str]:
    """Split ``k1="v1",k2="v2"`` respecting quoted/escaped commas."""
    out, cur, in_q, esc = [], [], False, False
    for ch in s:
        if esc:
            cur.append(ch)
            esc = False
        elif ch == "\\":
            cur.append(ch)
            esc = True
        elif ch == '"':
            cur.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


# ---------------------------------------------------------------------------
# the metric schema (the ONE place a metric name may be introduced;
# METRICS.md must document every name here — CI greps for drift)
# ---------------------------------------------------------------------------

# planning wall times run 100us..10s; step walls run 1ms..minutes
_WALL_BUCKETS = tuple(round(10.0 ** (e / 2.0), 10) for e in range(-8, 5))

METRIC_SPECS = {
    # -- planner -------------------------------------------------------------
    "repro_planner_decisions_total": dict(
        type="counter", labels=("op", "scheme", "fabric", "payload_bucket"),
        help="Fresh planner decisions (cache misses swept and logged), "
             "by winning scheme."),
    "repro_planner_cache_hits_total": dict(
        type="counter", labels=(),
        help="Planner LRU cache hits (per-op and program caches)."),
    "repro_planner_cache_misses_total": dict(
        type="counter", labels=(),
        help="Planner LRU cache misses (fresh sweeps)."),
    "repro_planner_decision_flips_total": dict(
        type="counter", labels=("op", "fabric", "payload_bucket"),
        help="Fresh decisions whose winning scheme differs from the "
             "previous decision for the same (op, fabric, payload) cell "
             "— the in-process plan churn a recalibration causes."),
    "repro_planner_decision_log_dropped_total": dict(
        type="counter", labels=(),
        help="decision_log rows evicted by the ring buffer cap."),
    "repro_planner_planning_wall_seconds": dict(
        type="histogram", labels=("program",), buckets=_WALL_BUCKETS,
        help="plan_program wall time per declared program."),
    "repro_planner_search_combos_scored": dict(
        type="gauge", labels=("program",),
        help="Phase-search combinations scored by the last plan_program "
             "for this program."),
    "repro_planner_search_combos_pruned": dict(
        type="gauge", labels=("program",),
        help="Phase-search combinations pruned (product - scored) by the "
             "last plan_program for this program."),
    "repro_planner_search_product": dict(
        type="gauge", labels=("program",),
        help="Full candidate product of the last plan_program for this "
             "program (what the exhaustive oracle would sweep)."),
    # -- drift monitor -------------------------------------------------------
    "repro_drift_ratio": dict(
        type="gauge", labels=("op", "fabric"),
        help="Median |measured-predicted|/predicted over the monitor's "
             "observation window, per op (1.0 = 100% drift)."),
    "repro_drift_checks_total": dict(
        type="counter", labels=("fabric",),
        help="Drift checks performed by the monitor."),
    "repro_probe_observations_total": dict(
        type="counter", labels=("op", "fabric"),
        help="Probe records fed into the drift monitor."),
    "repro_recalibrations_total": dict(
        type="counter", labels=("fabric",),
        help="Fit + refresh_hardware + replan events."),
    "repro_recalibration_seconds": dict(
        type="histogram", labels=("fabric",), buckets=_WALL_BUCKETS,
        help="Wall time of one recalibration (fit + hardware swap + "
             "program replans)."),
    "repro_fit_rejected_total": dict(
        type="counter", labels=("fabric",),
        help="Per-class link fits rejected by the confidence floor "
             "(untrusted: too few points, low R^2, ...) during "
             "recalibration."),
    # -- plan lifecycle ------------------------------------------------------
    "repro_plan_bind_total": dict(
        type="counter", labels=("program", "fingerprint"),
        help="ExecutionPlan binds (pctx.bind) by program and plan "
             "fingerprint."),
    "repro_plan_replan_total": dict(
        type="counter", labels=("program", "changed"),
        help="Program replans after recalibration; changed=\"true\" "
             "when the fresh fingerprint differs."),
    "repro_plan_stale_total": dict(
        type="counter", labels=("program", "fingerprint"),
        help="Stale-bound-plan warnings (one-shot per drift event): the "
             "bound fingerprint was superseded by a replan."),
    # -- runtime (serve/train) ----------------------------------------------
    "repro_step_wall_seconds": dict(
        type="histogram", labels=("phase",), buckets=_WALL_BUCKETS,
        help="Wall time per executed step: train steps, serve prefill, "
             "serve decode (whole decode loop)."),
    "repro_phase_budget_ok": dict(
        type="gauge", labels=("phase", "fingerprint"),
        help="1 when the phase's contended score meets its declared "
             "latency budget, else 0 (phases without budgets absent)."),
    "repro_phase_predicted_seconds": dict(
        type="gauge", labels=("phase", "fingerprint"),
        help="Planner-predicted contention-aware score of each phase of "
             "the bound/reported ExecutionPlan."),
    # -- SLO classification --------------------------------------------------
    "repro_slo_class_total": dict(
        type="counter",
        labels=("op", "payload_bucket", "fabric", "slo"),
        help="Probe measurements classified against the planner's own "
             "predicted latency: good (<= 1.2x), acceptable (<= 2x), "
             "poor (> 2x), unknown (no usable prediction)."),
    "repro_slo_ratio": dict(
        type="gauge", labels=("op", "payload_bucket", "fabric"),
        help="Latest measured/predicted latency ratio per op x payload "
             "cell (the quantity the SLO bands cut)."),
    # -- fault tolerance -----------------------------------------------------
    "repro_probe_failures_total": dict(
        type="counter", labels=("reason", "fabric"),
        help="Probe attempts that failed after exhausting the retry "
             "policy (reason: timeout, error); failed probes produce no "
             "calibration record instead of crashing the cycle."),
    "repro_plan_infeasible_total": dict(
        type="counter", labels=("op", "fabric"),
        help="Plan candidates masked as infeasible under the topology's "
             "FailureState (ledger charges a dead link, or the plan's "
             "relay engine is dead) during a planner sweep."),
    "repro_failures_detected_total": dict(
        type="counter", labels=("fabric", "kind"),
        help="Fault declarations by the failure detector (kind: link) "
             "after K consecutive probe timeouts on the same target."),
    "repro_failures_recovered_total": dict(
        type="counter", labels=("fabric", "kind"),
        help="Fault revivals by the failure detector: a previously-dead "
             "target answered a probe again."),
    "repro_failed_links": dict(
        type="gauge", labels=("fabric",),
        help="Directed links currently declared dead by the failure "
             "detector."),
    "repro_plan_rebind_total": dict(
        type="counter", labels=("program", "fingerprint"),
        help="Hot plan re-binds: a staged ExecutionPlan swapped in at a "
             "step boundary by the double-buffered binder."),
    "repro_rebind_cold_retrace_total": dict(
        type="counter", labels=("program",),
        help="Re-bind swaps that had to build their traced lowering AT "
             "the swap point (the pending artifact was missing) — the "
             "cold retrace the double-buffered binder exists to avoid; "
             "should stay 0."),
    "repro_lowering_cache_hits_total": dict(
        type="counter", labels=("program",),
        help="Traced-lowering cache hits keyed on plan fingerprint: a "
             "staged plan reused an existing lowering (e.g. recovery "
             "flipping back to the pre-failure plan) with no retrace."),
    "repro_lowering_cache_misses_total": dict(
        type="counter", labels=("program",),
        help="Traced-lowering cache misses: a staged plan's lowering was "
             "built fresh, off the step path (double-buffered, not a "
             "cold retrace)."),
    # -- serving tier (continuous batching) ----------------------------------
    "repro_request_ttft_seconds": dict(
        type="histogram", labels=(), buckets=_WALL_BUCKETS,
        help="Per-request time to first token (virtual serving clock), "
             "queue wait included."),
    "repro_request_tpot_seconds": dict(
        type="histogram", labels=(), buckets=_WALL_BUCKETS,
        help="Per-request time per output token over the decode tail "
             "(excludes the prefill-produced first token)."),
    "repro_request_queue_wait_seconds": dict(
        type="histogram", labels=(), buckets=_WALL_BUCKETS,
        help="Per-request wait between arrival and admission into a "
             "decode cohort."),
    "repro_serving_queue_depth": dict(
        type="gauge", labels=(),
        help="Arrived-but-unadmitted requests after the last scheduling "
             "iteration."),
    "repro_serving_in_flight": dict(
        type="gauge", labels=(),
        help="Live (admitted, unfinished) sequences after the last "
             "scheduling iteration — the decode batch the planner's "
             "crossovers are cut against."),
    "repro_requests_total": dict(
        type="counter", labels=("outcome",),
        help="Request lifecycle events by outcome (admitted, "
             "completed)."),
    "repro_admission_rejects_total": dict(
        type="counter", labels=("reason",),
        help="Ready requests NOT admitted this iteration, by reason: "
             "capacity (slots full) or tpot_slo (the planner predicts "
             "the grown decode bucket would blow the TPOT SLO — the "
             "crossover-aware hold)."),
    "repro_request_slo_class_total": dict(
        type="counter", labels=("metric", "slo"),
        help="Per-request SLO classes cut against the planner's own "
             "predicted service times (metric: ttft, tpot), using the "
             "standard good/acceptable/poor bands times the request's "
             "deadline-class slack."),
    "repro_plan_prefetch_total": dict(
        type="counter", labels=("program",),
        help="Batch-bucket plan prefetches: a neighboring bucket's "
             "ExecutionPlan staged through PlanBinder ahead of "
             "admission, so batch growth across the bucket swaps on a "
             "warm lowering (pointer flip, never a cold retrace)."),
}


def _build(registry: MetricsRegistry) -> MetricsRegistry:
    for name, spec in METRIC_SPECS.items():
        kind = spec["type"]
        if kind == "counter":
            registry.counter(name, spec["help"], spec["labels"])
        elif kind == "gauge":
            registry.gauge(name, spec["help"], spec["labels"])
        else:
            registry.histogram(name, spec["help"], spec["labels"],
                               spec.get("buckets", DEFAULT_BUCKETS))
    return registry


_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """Process-wide registry with every :data:`METRIC_SPECS` metric
    pre-registered — what the instrumented seams and the exporter share."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = _build(MetricsRegistry())
    return _DEFAULT


def reset_default_registry() -> MetricsRegistry:
    """Zero every series of the process-wide registry (tests / the
    stress harness start each run from a clean plane)."""
    reg = default_registry()
    reg.reset()
    return reg
