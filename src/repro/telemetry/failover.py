"""Link-failure detection from probe evidence (the telemetry DETECTOR).

The paper motivates MultiWrite's graceful degradation with multicast's
management-plane fragility; this module supplies the *detection* half of
the fault-tolerance arc: per-rail point-to-point probes under the
bounded-retry :class:`~repro.telemetry.probe.ProbePolicy`, consecutive
timeouts counted as strikes, ``strikes`` consecutive misses declaring
the directed link dead, and any later success reviving it (asymmetric
hysteresis: K strikes to kill, one success to heal — a flapping link is
re-declared only after K fresh consecutive misses).

The detector always probes the HEALTHY base topology's rails — including
links currently declared dead — because recovery can only be noticed by
probing the very link the effective (failed) topology no longer has.
:meth:`FailureDetector.failures` yields the accumulated
:class:`~repro.core.topology.FailureState`, which the
:class:`~repro.telemetry.monitor.DriftMonitor` composes onto the base
fabric via ``with_failures`` and feeds to the planner.
"""

from __future__ import annotations

from typing import Optional

from repro.core import plan as plan_ir
from repro.core.topology import FailureState, Topology

from .probe import ProbePolicy, measure_safely

# detector probes are small and frequent: enough bytes that a healthy
# rail's serialization dominates alpha, small enough to stay cheap
RAIL_PROBE_BYTES = 1 << 20

# detector attempts retry once with a short backoff — a scan is a health
# check, not a calibration; the K-strike hysteresis absorbs flakiness
DETECT_POLICY = ProbePolicy(retries=1, backoff_s=0.005)


def rail_probe_ledger(topo: Topology, key: tuple[int, int],
                      payload_bytes: float = RAIL_PROBE_BYTES
                      ) -> plan_ir.Ledger:
    """Single-link probe ledger: ``payload_bytes`` over exactly one
    directed link — finer than the server-pair ``linkprobe`` plan (which
    stripes all rails of a direction and would indict the whole
    direction when one rail is dark)."""
    return plan_ir.Ledger(topo=topo, link_bytes={key: float(payload_bytes)},
                          relay_bytes={}, flow_counts={key: 1})


class FailureDetector:
    """Declares directed inter-server links dead after ``strikes``
    consecutive probe timeouts, and revives them on the next success.

    The detector only watches *rails* (inter-server links): the paper's
    failure surface is the RoCE/management plane, intra-server full-mesh
    links are not individually probeable at this granularity, and a dead
    intra link surfaces as drift instead.
    """

    def __init__(self, base_topo: Topology, *, strikes: int = 2,
                 payload_bytes: float = RAIL_PROBE_BYTES,
                 policy: ProbePolicy = DETECT_POLICY) -> None:
        self.base_topo = base_topo
        self.strikes = max(1, int(strikes))
        self.payload_bytes = float(payload_bytes)
        self.policy = policy
        self.rails: tuple = tuple(sorted(
            key for key in base_topo.links
            if base_topo.server_of(key[0]) != base_topo.server_of(key[1])))
        self._strikes: dict[tuple[int, int], int] = {}
        self._dead: set = set()
        self.events: list[dict] = []

    def dead_links(self) -> frozenset:
        return frozenset(self._dead)

    def failures(self) -> FailureState:
        """The accumulated fault set, ready for ``with_failures``."""
        return FailureState(dead_links=self._dead)

    def scan(self, executor) -> bool:
        """One probe pass over every rail of the base topology; returns
        True when the dead-link set changed (the monitor's cue to
        recompute the surviving-capacity graph)."""
        from . import metrics as _metrics
        reg = _metrics.default_registry()
        changed = False
        for key in self.rails:
            ledger = rail_probe_ledger(self.base_topo, key,
                                       self.payload_bytes)
            measured = measure_safely(
                executor, "linkprobe", "p2p", self.payload_bytes,
                self.base_topo, policy=self.policy, ledger=ledger,
                knobs={}, src_server=self.base_topo.server_of(key[0]),
                dst_server=self.base_topo.server_of(key[1]),
                src_node=key[0], dst_node=key[1])
            if measured is None:
                n = self._strikes.get(key, 0) + 1
                self._strikes[key] = n
                if n >= self.strikes and key not in self._dead:
                    self._dead.add(key)
                    changed = True
                    self.events.append({"kind": "link_dead", "link": key,
                                        "strikes": n})
                    reg["repro_failures_detected_total"].inc(
                        fabric=self.base_topo.name, kind="link")
            else:
                self._strikes[key] = 0
                if key in self._dead:
                    self._dead.discard(key)
                    changed = True
                    self.events.append({"kind": "link_recovered",
                                        "link": key})
                    reg["repro_failures_recovered_total"].inc(
                        fabric=self.base_topo.name, kind="link")
        reg["repro_failed_links"].set(len(self._dead),
                                      fabric=self.base_topo.name)
        return changed
