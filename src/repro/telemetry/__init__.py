"""Telemetry & online calibration: measured collectives close the
planner's feedback loop.

    probe.py    timed execution of registered plans — live mesh or a
                pure-simulation backend with injectable ground truth
    store.py    append-only JSONL CalibrationStore (schema-versioned,
                keyed by fabric fingerprint / op / payload bucket)
    fit.py      per-link-class alpha/beta regression -> the measurements
                dict HardwareModel.recalibrated accepts
    monitor.py  drift watchdog: predicted-vs-measured divergence
                triggers re-fit + planner.refresh_hardware (LRU cache
                invalidated — decisions flip at runtime)

Consumed by: ParallelContext(calibration=...), train.py/serve.py
--calibrate, dryrun --calibration, ServeEngine.plan_report and
benchmarks bench_calibration.
"""

from .fit import (FitResult, calibrated_hw, fit_link_class,
                  fit_link_classes, fit_link_roles, fit_measurements,
                  fit_overlap_eff)
from .monitor import DriftMonitor, StepAttribution, startup_calibration
from .probe import (GroundTruth, LiveProbe, SimProbe, default_payloads,
                    ledger_class_bytes, ledger_role_bytes, link_class,
                    link_role, probe_link_directions, probe_record,
                    probe_sweep)
from .store import (SCHEMA_VERSION, CalibrationStore, resolve_store,
                    topo_key)

__all__ = [
    "CalibrationStore", "DriftMonitor", "FitResult", "GroundTruth",
    "LiveProbe", "SCHEMA_VERSION", "SimProbe", "StepAttribution",
    "calibrated_hw", "default_payloads", "fit_link_class",
    "fit_link_classes", "fit_link_roles", "fit_measurements",
    "fit_overlap_eff", "ledger_class_bytes", "ledger_role_bytes",
    "link_class", "link_role", "probe_link_directions", "probe_record",
    "probe_sweep", "resolve_store", "startup_calibration", "topo_key",
]
