"""Telemetry & online calibration: measured collectives close the
planner's feedback loop.

    probe.py    timed execution of registered plans — live mesh or a
                pure-simulation backend with injectable ground truth
    store.py    append-only JSONL CalibrationStore (schema-versioned,
                keyed by fabric fingerprint / op / payload bucket)
    fit.py      per-link-class alpha/beta regression -> the measurements
                dict HardwareModel.recalibrated accepts
    monitor.py  drift watchdog: predicted-vs-measured divergence
                triggers re-fit + planner.refresh_hardware (LRU cache
                invalidated — decisions flip at runtime)
    metrics.py  dependency-free counter/gauge/histogram registry with
                Prometheus text exposition (METRIC_SPECS is the schema)
    exporter.py stdlib /metrics HTTP endpoint + snapshot-to-file
    slo.py      good/acceptable/poor banding of measured latency
                against the planner's own prediction

Consumed by: ParallelContext(calibration=...), train.py/serve.py
--calibrate, dryrun --calibration, ServeEngine.plan_report,
launch/stress.py soak runs and benchmarks bench_calibration.
"""

from .exporter import MetricsExporter, scrape, write_snapshot
from .failover import FailureDetector, rail_probe_ledger
from .fit import (FitResult, calibrated_hw, fit_link_class,
                  fit_link_classes, fit_link_roles, fit_measurements,
                  fit_overlap_eff)
from .metrics import (METRIC_SPECS, Counter, Gauge, Histogram,
                      MetricsRegistry, default_registry, parse_text,
                      reset_default_registry)
from .monitor import DriftMonitor, StepAttribution, startup_calibration
from .probe import (GroundTruth, LiveProbe, ProbePolicy, ProbeTimeout,
                    SimProbe, attributed_bottleneck, default_payloads,
                    ledger_class_bytes, ledger_role_bytes, link_class,
                    link_role, measure_safely, probe_link_directions,
                    probe_record, probe_sweep)
from .slo import classify, classify_record, classify_records
from .store import (SCHEMA_VERSION, CalibrationStore, resolve_store,
                    topo_key)

__all__ = [
    "CalibrationStore", "Counter", "DriftMonitor", "FailureDetector",
    "FitResult", "Gauge", "GroundTruth", "Histogram", "LiveProbe",
    "METRIC_SPECS", "MetricsExporter", "MetricsRegistry", "ProbePolicy",
    "ProbeTimeout", "SCHEMA_VERSION", "SimProbe", "StepAttribution",
    "attributed_bottleneck", "calibrated_hw", "classify",
    "classify_record", "classify_records", "default_payloads",
    "default_registry", "fit_link_class", "fit_link_classes",
    "fit_link_roles", "fit_measurements", "fit_overlap_eff",
    "ledger_class_bytes", "ledger_role_bytes", "link_class", "link_role",
    "measure_safely", "parse_text", "probe_link_directions",
    "probe_record", "probe_sweep", "rail_probe_ledger",
    "reset_default_registry", "resolve_store", "scrape",
    "startup_calibration", "topo_key", "write_snapshot",
]
