"""Drift watchdog: predicted-vs-measured divergence drives re-fits.

The MONITOR closes the telemetry loop at runtime:

    probe (timed plans)  ->  store (JSONL)  ->  drift check
                                                    │ > threshold
                                                    ▼
    planner.refresh_hardware(hw')  <-  HardwareModel.recalibrated
         (LRU cache invalidated,          ▲
          decisions genuinely flip)       └─ fit (per-class alpha/beta)

Drift is the per-op MEDIAN relative error between the latency model's
predicted ledger times and the measured times, maximized over ops — a
degraded rail shows up even while the (unaffected) intra-server
AllGather keeps predicting perfectly.  When the worst op's divergence
exceeds ``threshold``, the monitor re-fits the store's latest records,
folds the fitted bandwidths into a fresh :class:`HardwareModel`, and
swaps it into the planner — whose cache invalidation makes the next
``choose`` re-sweep, so dispatch/combine decisions flip WITHOUT process
restart (the closed-loop acceptance property of tests/test_telemetry.py).
"""

from __future__ import annotations

import time
from collections import deque
from typing import Optional, Sequence

from repro.core.latency_model import HardwareModel
from repro.core.planner import Planner
from repro.core.topology import Topology

from . import slo as _slo
from .fit import fit_measurements, fit_overlap_eff
from .metrics import default_registry
from .probe import DEFAULT_OPS, probe_link_directions, probe_sweep
from .store import CalibrationStore, topo_key


class DriftMonitor:
    """Watches predicted-vs-measured error; re-fits + recalibrates the
    planner when it diverges.

    ``threshold`` is the relative-error trip point (0.25 = re-fit once
    the worst op's median divergence passes 25%); ``window`` bounds the
    per-op observation deques; ``cooldown`` is the minimum number of
    ``check`` calls between recalibrations (a re-fit needs fresh probes
    to judge itself against before it may fire again).
    """

    def __init__(self, planner: Planner, store: CalibrationStore,
                 topo: Topology, *, threshold: float = 0.25,
                 window: int = 32, min_observations: int = 3,
                 cooldown: int = 1,
                 base_hw: Optional[HardwareModel] = None,
                 detector=None) -> None:
        self.planner = planner
        self.store = store
        # base_topo stays the healthy fabric; topo is the EFFECTIVE one
        # (base with the detector's declared failures applied)
        self.base_topo = topo
        self.topo = topo
        self.threshold = float(threshold)
        self.window = int(window)
        self.min_observations = int(min_observations)
        self.cooldown = int(cooldown)
        # fits always start from the pristine base so repeated
        # recalibrations replace (never compound) earlier overrides
        self.base_hw = base_hw or planner.hw
        self.detector = detector    # Optional[failover.FailureDetector]
        self._errs: dict[str, deque] = {}
        self.events: list[dict] = []
        self.checks = 0
        self._last_recal_check = -10 ** 9

    # -- observations --------------------------------------------------------
    def observe(self, record: dict) -> None:
        """Feed one probe record's (predicted, measured) pair."""
        reg = default_registry()
        reg["repro_probe_observations_total"].inc(
            op=str(record.get("op", "?")), fabric=self.topo.name)
        _slo.observe_record(record, registry=reg)
        p = float(record["predicted_s"])
        m = float(record["measured_s"])
        if p <= 0:
            return
        dq = self._errs.setdefault(
            record.get("op", "?"), deque(maxlen=self.window))
        dq.append(abs(m - p) / p)
        # close the planner's audit trail: if this probe timed the plan
        # of a logged (still-unmeasured) decision at the same payload
        # bucket AND the same knob configuration, fill its measured
        # side.  The knob match matters for pipelined rows: a default
        # G=1 probe timing must never land in a G>1 decision row —
        # fit_overlap_eff would misread the collective-only time as a
        # pipelined end-to-end time and inflate overlap_eff toward 1.
        rk = record.get("knobs")
        rt = record.get("fabric_name")
        for row in reversed(self.planner.decision_log):
            if (row["op"] == record.get("op")
                    and row["plan"] == record.get("plan")
                    and row["payload_bytes"] == record.get("bucket")
                    and (rk is None or dict(row.get("knobs", {})) == dict(rk))
                    and (rt is None or row.get("topo") in (None, rt))
                    and row["measured_s"] is None):
                row["measured_s"] = m
                break

    @staticmethod
    def _median(vals: Sequence[float]) -> float:
        s = sorted(vals)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def drift(self) -> float:
        """Worst-op median relative error over the observation window."""
        per_op = [self._median(dq) for dq in self._errs.values() if dq]
        return max(per_op, default=0.0)

    def drift_by_op(self) -> dict:
        return {op: self._median(dq)
                for op, dq in self._errs.items() if dq}

    def _n_observations(self) -> int:
        return sum(len(dq) for dq in self._errs.values())

    # -- the loop ------------------------------------------------------------
    def recalibrate(self, *, force: bool = False) -> Optional[dict]:
        """Fit the store's latest records for this fabric, swap the
        fitted model into the planner, and REPLAN every registered
        collective program under it — re-calibration operates on whole
        programs (the unit consumers bind), not just per-op cache
        entries: the event carries each program's fresh fingerprint and
        whether any jointly-planned decision moved.  Returns the event
        dict, or None when no class fit cleared the confidence floor."""
        t_start = time.perf_counter()
        reg = default_registry()
        records = list(
            self.store.latest_by_key(fabric=topo_key(self.topo)).values())
        measurements, fits = fit_measurements(records, self.topo)
        rejected = sum(1 for f in fits.values() if not f.trusted)
        if rejected:
            reg["repro_fit_rejected_total"].inc(rejected,
                                                fabric=self.topo.name)
        # overlap-efficiency hook: measured pipelined decisions in the
        # planner's log calibrate hw.overlap_eff alongside the link fits
        eta = fit_overlap_eff(self.planner.decision_log)
        if eta is not None:
            measurements = dict(measurements)
            measurements["overlap_eff"] = eta
        if not measurements and not force:
            return None
        new_hw = (self.base_hw.recalibrated(measurements, self.topo)
                  if measurements else self.base_hw)
        drift = self.drift()
        self.planner.refresh_hardware(new_hw)
        program_events = self.planner.replan_programs()
        event = {
            "kind": "recalibrated",
            "time": time.time(),
            "check": self.checks,
            "drift": drift,
            "drift_by_op": self.drift_by_op(),
            "fabric": topo_key(self.topo),
            "n_records": len(records),
            "fits": {cls: f.report() for cls, f in fits.items()},
            "measured_links": len(measurements.get("links", {})),
            "overlap_eff": measurements.get("overlap_eff"),
            "programs": [{"program": e["program"],
                          "fingerprint": e["fingerprint"],
                          "changed": e["changed"]}
                         for e in program_events],
        }
        self.events.append(event)
        self._last_recal_check = self.checks
        for dq in self._errs.values():
            dq.clear()            # judged against the new model from here
        reg["repro_recalibrations_total"].inc(fabric=self.topo.name)
        reg["repro_recalibration_seconds"].observe(
            time.perf_counter() - t_start, fabric=self.topo.name)
        return event

    def apply_failures(self, failures) -> Optional[dict]:
        """Recompute the effective topology from the healthy base plus
        ``failures`` (a :class:`~repro.core.topology.FailureState`) and
        RETARGET every registered program onto it — the reaction half of
        the fault-tolerance arc.  Returns a ``failover``/``failback``
        event (with per-program replan results, including a typed
        ``NoFeasiblePlanError`` for unplannable programs), or None when
        the effective fabric is unchanged."""
        new_topo = self.base_topo.with_failures(failures)
        if new_topo.fingerprint() == self.topo.fingerprint():
            return None
        old_topo = self.topo
        self.topo = new_topo
        retargets = self.planner.retarget_programs(old_topo, new_topo)
        event = {
            "kind": "failover" if failures else "failback",
            "time": time.time(),
            "check": self.checks,
            "fabric": topo_key(new_topo),
            "dead_links": sorted(failures.dead_links),
            "dead_relays": sorted(failures.dead_relays),
            "lost_npus": sorted(failures.lost_npus),
            "programs": [{"program": e["program"],
                          "fingerprint": e["fingerprint"],
                          "changed": e["changed"],
                          "error": str(e["error"]) if e.get("error")
                          else None}
                         for e in retargets],
            "plans": {e["program"]: e["plan"] for e in retargets},
        }
        self.events.append(event)
        # predictions are judged against the new fabric from here on
        for dq in self._errs.values():
            dq.clear()
        return event

    def replanned(self, program_name: str):
        """Latest replanned ExecutionPlan for ``program_name`` (from the
        planner's program registry), or None — what a launch surface
        re-binds after a recalibration event reports ``changed``."""
        for ev in self.planner.replan_programs():
            if ev["program"] == program_name:
                return ev["plan"]
        return None

    def check(self) -> Optional[dict]:
        """Recalibrate iff drift exceeds the threshold (and the window
        holds enough observations, and the cooldown elapsed)."""
        self.checks += 1
        reg = default_registry()
        reg["repro_drift_checks_total"].inc(fabric=self.topo.name)
        for op, v in self.drift_by_op().items():
            reg["repro_drift_ratio"].set(v, op=op, fabric=self.topo.name)
        if self._n_observations() < self.min_observations:
            return None
        if self.checks - self._last_recal_check < self.cooldown:
            return None
        if self.drift() <= self.threshold:
            return None
        return self.recalibrate()

    def run_cycle(self, executor, *, ops: Sequence[str] = DEFAULT_OPS,
                  payloads=None, directions: bool = True,
                  **scenario_kw) -> Optional[dict]:
        """One full telemetry cycle: probe sweep + directed rail
        microbenchmarks (predicted under the planner's CURRENT model)
        -> store -> observe -> drift check.  Returns the recalibration
        event if one fired.  ``directions=False`` skips the per-direction
        p2p probes (they exist so never-bottlenecking rail directions —
        asymmetric forward rails — get fitted instead of staying
        nominal).  With a failure ``detector`` attached, every cycle
        starts with a rail scan against the HEALTHY base fabric (the
        only place a dead rail's recovery is visible) and a change in
        the declared fault set retargets all programs via
        :meth:`apply_failures` before the calibration probes run on the
        surviving capacity graph."""
        if self.detector is not None and self.detector.scan(executor):
            self.apply_failures(self.detector.failures())
        records = probe_sweep(self.topo, executor, ops=ops,
                              payloads=payloads, hw=self.planner.hw,
                              **scenario_kw)
        if directions:
            records += probe_link_directions(self.topo, executor,
                                             hw=self.planner.hw)
        self.store.extend(records)
        for r in records:
            self.observe(r)
        return self.check()

    # -- reporting (ServeEngine.plan_report / train logs) --------------------
    @property
    def last_recalibration(self) -> Optional[dict]:
        # events interleave recalibrations with failover/failback; the
        # last RECAL is the one carrying drift/fit fields
        for e in reversed(self.events):
            if "drift" in e:
                return e
        return None

    @property
    def last_failover(self) -> Optional[dict]:
        for e in reversed(self.events):
            if e.get("kind") in ("failover", "failback"):
                return e
        return None

    def staged_plan(self, program_name: str):
        """The most recent retargeted plan for ``program_name`` from a
        failover/failback event, if any — what a serving engine stages
        for hot re-bind when its bound plan goes stale."""
        for e in reversed(self.events):
            plan = e.get("plans", {}).get(program_name)
            if plan is not None:
                return plan
        return None

    def report(self) -> dict:
        last = self.last_recalibration
        fail = self.last_failover
        recals = sum(1 for e in self.events if "drift" in e)
        return {
            "drift_pct": round(100.0 * self.drift(), 2),
            "drift_by_op_pct": {op: round(100.0 * v, 2)
                                for op, v in self.drift_by_op().items()},
            "observations": self._n_observations(),
            "checks": self.checks,
            "threshold_pct": 100.0 * self.threshold,
            "recalibrations": recals,
            "last_recalibration": (
                None if last is None else
                {k: last[k] for k in ("check", "drift", "fits",
                                      "measured_links", "n_records")}),
            "last_failover": (
                None if fail is None else
                {k: fail[k] for k in ("kind", "check", "fabric",
                                      "dead_links", "dead_relays",
                                      "lost_npus")}),
            "store_records": len(self.store),
        }


class StepAttribution:
    """Feeds LIVE training-step wall times into the joint pipeline
    decision's measurement rows (``Planner.note_measurement``), closing
    the ROADMAP gap where only SimProbe/synthetic rows reached
    ``fit_overlap_eff``.

    A step's wall time is ``other + n_layers * t_pipe`` where ``t_pipe``
    is the per-layer MoE round-trip time the bound joint decision
    brackets with its (serial, ideal) endpoints.  The non-MoE remainder
    ``other`` is either supplied by the caller (``overhead_s`` — e.g. a
    roofline estimate, which makes the attribution unbiased) or, by
    default, MIN-ANCHORED: the fastest observed step is assumed to have
    achieved the predicted pipeline time, and later steps' attribution
    measures their EXCESS over it.  The min-anchored estimator is
    deliberately conservative — it cannot invent an efficiency better
    than predicted, only pull the fit down when steps run consistently
    slower — and the median inside ``fit_overlap_eff`` absorbs
    straggler-polluted steps.  Probe timings remain the calibration
    ground truth; these rows keep the eta fit fed between probe sweeps.
    """

    def __init__(self, planner: Planner, decision, *, n_layers: int = 1,
                 overhead_s: Optional[float] = None,
                 warmup: int = 3) -> None:
        self.planner = planner
        self.decision = decision
        self.n_layers = max(1, int(n_layers))
        self.overhead_s = overhead_s
        self.warmup = int(warmup)
        self._seen = 0
        self._min_wall = float("inf")      # running min: O(1) for
        #   million-step training loops
        self.fed = 0

    def observe_step(self, wall_s: float) -> Optional[dict]:
        """Attribute one completed step's wall time; returns the decision
        log row it landed in (or None during warmup / when the
        attribution is non-positive)."""
        self._seen += 1
        if self._seen <= self.warmup:      # compile/warmup steps excluded
            return None
        wall_s = float(wall_s)
        self._min_wall = min(self._min_wall, wall_s)
        overhead = self.overhead_s
        if overhead is None:
            overhead = (self._min_wall
                        - self.n_layers * self.decision.predicted_s)
        measured = (wall_s - overhead) / self.n_layers
        if measured <= 0:
            return None
        row = self.planner.note_measurement(self.decision, measured)
        self.fed += 1
        return row


def startup_calibration(topo: Topology, store_path=None, *,
                        planner: Optional[Planner] = None, probe=None,
                        threshold: float = 0.25):
    """Launcher-side startup (shared by train.py --calibrate and
    serve.py --calibrate): probe sweep + fit + recalibrate before step 0
    so planner decisions are scored under measured bandwidths from the
    first trace.  ``probe`` defaults to the simulated executor (no
    fabric to time on CPU hosts); live deployments pass a LiveProbe.
    Returns (store, monitor, event) — event carries the drift AT fit
    time (the monitor's window is cleared by the re-fit)."""
    from repro.core.planner import default_planner

    from .probe import GroundTruth, SimProbe
    from .store import CalibrationStore

    planner = planner or default_planner()
    store = CalibrationStore(store_path)
    monitor = DriftMonitor(planner, store, topo, threshold=threshold)
    probe = probe or SimProbe(GroundTruth())
    event = monitor.run_cycle(probe) or monitor.recalibrate(force=True)
    return store, monitor, event
