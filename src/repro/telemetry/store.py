"""Append-only calibration store: measured collective timings as JSONL.

The store is the persistence layer of the telemetry loop (probe ->
STORE -> fit -> monitor): every probe run appends one record per
(plan, payload) measurement, and the fitter reads the records back —
possibly in a different process, days later — keyed by

    (fabric fingerprint, op, payload bucket)

so measurements from one fabric never calibrate another (the planner
keys its own cache on the same ``Topology.fingerprint()``).

Records are schema-versioned plain dicts (see
:data:`SCHEMA_VERSION`); unknown *newer* schemas are skipped on read
(forward compatibility for rolling deployments), older ones pass
through an upgrade hook.  Files live under ``results/calibration/`` by
default; ``path=":memory:"`` gives a process-local store for tests and
self-contained benchmarks.
"""

from __future__ import annotations

import hashlib
import itertools
import json
import os
from typing import Iterable, Optional

SCHEMA_VERSION = 1

_STORE_UIDS = itertools.count()

# required fields of a v1 record (probe.py emits these)
RECORD_FIELDS = ("fabric", "op", "plan", "payload_bytes", "bucket",
                 "predicted_s", "measured_s")

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "calibration")


def topo_key(topo) -> str:
    """Stable string identity of a fabric for record keying: the name
    plus a short hash of the full fingerprint (name alone would alias
    re-bandwidthed variants)."""
    fp = repr(topo.fingerprint()).encode()
    return f"{topo.name}:{hashlib.sha1(fp).hexdigest()[:12]}"


def _upgrade(rec: dict) -> Optional[dict]:
    """Schema migration hook.  Returns None for records this build cannot
    read (newer schema than SCHEMA_VERSION)."""
    v = int(rec.get("schema", 1))
    if v > SCHEMA_VERSION:
        return None
    # v1 is the only historical schema so far; future bumps migrate here.
    return rec


class CalibrationStore:
    """Append-only JSONL store of probe measurements.

    ``path`` may be a file path (created on first append, parents
    included), a directory (a ``calibration.jsonl`` inside it), or
    ``":memory:"`` for a non-persistent store.
    """

    def __init__(self, path: Optional[str] = None) -> None:
        if path is None:
            path = os.path.join(DEFAULT_DIR, "calibration.jsonl")
        if path != ":memory:" and (os.path.isdir(path)
                                   or path.endswith(os.sep)):
            path = os.path.join(path, "calibration.jsonl")
        self.path = path
        self._uid = next(_STORE_UIDS)
        self._records: list[dict] = []
        self._load()

    # -- persistence ---------------------------------------------------------
    @property
    def in_memory(self) -> bool:
        return self.path == ":memory:"

    def _load(self) -> None:
        if self.in_memory or not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = _upgrade(json.loads(line))
                except json.JSONDecodeError:
                    continue          # torn tail write: skip, keep reading
                if rec is not None:
                    self._records.append(rec)

    def append(self, record: dict) -> dict:
        missing = [k for k in RECORD_FIELDS if k not in record]
        if missing:
            raise ValueError(f"calibration record missing {missing}")
        rec = dict(record)
        rec.setdefault("schema", SCHEMA_VERSION)
        self._records.append(rec)
        if not self.in_memory:
            os.makedirs(os.path.dirname(os.path.abspath(self.path)),
                        exist_ok=True)
            with open(self.path, "a") as f:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return rec

    def extend(self, records: Iterable[dict]) -> int:
        n = 0
        for r in records:
            self.append(r)
            n += 1
        return n

    # -- queries -------------------------------------------------------------
    def records(self, *, fabric: Optional[str] = None,
                op: Optional[str] = None, plan: Optional[str] = None,
                bucket: Optional[int] = None,
                source: Optional[str] = None) -> list[dict]:
        """Records in append order, filtered by any of the key fields."""
        out = []
        for r in self._records:
            if fabric is not None and r.get("fabric") != fabric:
                continue
            if op is not None and r.get("op") != op:
                continue
            if plan is not None and r.get("plan") != plan:
                continue
            if bucket is not None and r.get("bucket") != bucket:
                continue
            if source is not None and r.get("source") != source:
                continue
            out.append(r)
        return out

    def latest_by_key(self, **filters) -> dict[tuple, dict]:
        """Most recent record per (op, plan, bucket) — the fitter's view:
        a re-probed payload bucket supersedes its older measurements, so
        a degradation does not average against the healthy history.
        Directed "linkprobe" records additionally key on their direction
        (bottleneck role): the two directions of an ordered server pair
        are distinct measurements, not re-probes of each other."""
        out: dict[tuple, dict] = {}
        for r in self.records(**filters):
            key = (r["op"], r["plan"], r["bucket"])
            if r["op"] == "linkprobe":
                key += (r.get("bottleneck_role"),)
            out[key] = r
        return out

    def fabrics(self) -> list[str]:
        return sorted({r.get("fabric", "?") for r in self._records})

    def version(self) -> tuple:
        """Memoization token: unique per store INSTANCE (two ':memory:'
        stores never alias) and bumped by every append."""
        return (self._uid, len(self._records))

    def __len__(self) -> int:
        return len(self._records)

    def __repr__(self) -> str:
        return (f"CalibrationStore({self.path!r}, {len(self)} records, "
                f"schema<={SCHEMA_VERSION})")


def resolve_store(spec) -> CalibrationStore:
    """A CalibrationStore from a store, path string, or None (default
    location) — the ``--calibration`` / ``ParallelContext.calibration``
    resolution point."""
    if isinstance(spec, CalibrationStore):
        return spec
    return CalibrationStore(spec)
