"""SLO classification of measured collective latencies.

The bands are derived from the planner's OWN predicted latency, not a
hand-pinned threshold table: a cell is "good" when the fabric delivers
what the fitted HardwareModel promised, "poor" when reality has drifted
past the point where the planner's decisions can be trusted.  That
makes the SLO self-updating — a recalibration that swaps in a truer
model moves the bands with it.

    good        measured <= GOOD_RATIO   x predicted   (default 1.2x)
    acceptable  measured <= ACCEPT_RATIO x predicted   (default 2.0x)
    poor        measured >  ACCEPT_RATIO x predicted
    unknown     no usable prediction (missing / zero / negative)

Boundaries are inclusive on the cheaper side: measured == 1.2x is still
"good", == 2.0x is still "acceptable" (a measurement exactly on a band
edge never flaps to the worse class from float formatting).

Consumed by DriftMonitor.observe (every probe record is classified into
``repro_slo_class_total`` / ``repro_slo_ratio``) and by the stress
harness, which asserts good -> poor -> good across an injected
degradation window.
"""

from __future__ import annotations

from typing import Iterable, Mapping, Optional

GOOD_RATIO = 1.2
ACCEPT_RATIO = 2.0

CLASSES = ("good", "acceptable", "poor", "unknown")


def classify(measured_s: Optional[float], predicted_s: Optional[float],
             *, good: float = GOOD_RATIO,
             acceptable: float = ACCEPT_RATIO) -> str:
    """Band a single measurement against its prediction."""
    if predicted_s is None or measured_s is None:
        return "unknown"
    p = float(predicted_s)
    m = float(measured_s)
    if not (p > 0.0) or m != m or p != p:  # non-positive or NaN
        return "unknown"
    if m <= good * p:
        return "good"
    if m <= acceptable * p:
        return "acceptable"
    return "poor"


def classify_record(record: Mapping, *, good: float = GOOD_RATIO,
                    acceptable: float = ACCEPT_RATIO) -> str:
    """Band one probe/store record (``measured_s`` vs ``predicted_s``)."""
    return classify(record.get("measured_s"), record.get("predicted_s"),
                    good=good, acceptable=acceptable)


def classify_records(records: Iterable[Mapping], *,
                     good: float = GOOD_RATIO,
                     acceptable: float = ACCEPT_RATIO) -> dict:
    """Per-cell worst-case banding over a batch of records.

    Returns ``{(op, payload_bucket): class}`` where each cell takes the
    WORST class observed in the batch (a cell with one poor probe among
    nine good ones is poor — SLOs report the tail, not the mode).
    """
    rank = {c: i for i, c in enumerate(("good", "acceptable", "poor"))}
    cells: dict = {}
    for rec in records:
        cls = classify_record(rec, good=good, acceptable=acceptable)
        if cls == "unknown":
            continue
        key = (rec.get("op"), rec.get("bucket"))
        prev = cells.get(key)
        if prev is None or rank[cls] > rank[prev]:
            cells[key] = cls
    return cells


REQUEST_METRICS = ("ttft", "tpot")


def classify_request(measured: Mapping, predicted: Mapping, *,
                     slack: float = 1.0, good: float = GOOD_RATIO,
                     acceptable: float = ACCEPT_RATIO) -> dict:
    """Band one serving request's TTFT/TPOT against the planner's
    predicted service times (the serving tier's per-request SLO).

    ``measured``/``predicted`` map ``"ttft"``/``"tpot"`` to seconds;
    ``slack`` multiplies the prediction before banding — the deadline
    class's tolerance (interactive 1x, batch traffic much looser).
    Returns per-metric classes plus ``"overall"`` (the worst, matching
    the worst-per-cell convention of :func:`classify_records`)."""
    rank = {c: i for i, c in enumerate(("good", "acceptable", "poor"))}
    out = {}
    worst = None
    for m in REQUEST_METRICS:
        p = predicted.get(m)
        scaled = p * slack if p is not None else None
        cls = classify(measured.get(m), scaled,
                       good=good, acceptable=acceptable)
        out[m] = cls
        if cls != "unknown" and (worst is None or
                                 rank[cls] > rank[worst]):
            worst = cls
    out["overall"] = worst if worst is not None else "unknown"
    return out


def observe_request(measured: Mapping, predicted: Mapping, *,
                    slack: float = 1.0, registry=None,
                    good: float = GOOD_RATIO,
                    acceptable: float = ACCEPT_RATIO) -> dict:
    """Classify one request (:func:`classify_request`) and emit the
    per-metric classes into ``repro_request_slo_class_total``."""
    from . import metrics as _m
    reg = registry if registry is not None else _m.default_registry()
    cls = classify_request(measured, predicted, slack=slack,
                           good=good, acceptable=acceptable)
    for m in REQUEST_METRICS:
        reg["repro_request_slo_class_total"].inc(metric=m, slo=cls[m])
    return cls


def observe_record(record: Mapping, *, registry=None,
                   good: float = GOOD_RATIO,
                   acceptable: float = ACCEPT_RATIO) -> str:
    """Classify one record and emit it into the metrics plane."""
    from . import metrics as _m
    reg = registry if registry is not None else _m.default_registry()
    cls = classify_record(record, good=good, acceptable=acceptable)
    labels = dict(op=str(record.get("op", "")),
                  payload_bucket=str(record.get("bucket", "")),
                  fabric=str(record.get("fabric_name", "")))
    reg["repro_slo_class_total"].inc(slo=cls, **labels)
    p = record.get("predicted_s")
    m = record.get("measured_s")
    if p and m is not None and float(p) > 0.0:
        reg["repro_slo_ratio"].set(float(m) / float(p), **labels)
    return cls
