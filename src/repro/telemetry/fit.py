"""Per-link-group alpha/beta regression over probe sweeps (the FIT).

Each probe record carries the bottleneck bytes of the plan it timed at
two granularities: per link CLASS (``class_bytes``: ``intra`` =
in-server full mesh, ``inter`` = rails) and per directed link ROLE
(``role_bytes``: one role per ordered server pair, ``inter:0>1`` vs
``inter:1>0``) — the refinement that keeps an asymmetric fabric's
forward and return rails on separate fit lines instead of collapsing
both directions to one "inter" bandwidth.  For a link group ``c`` the
latency model predicts

    t  =  alpha  +  x_c / bw_c  (+ small relay/engine terms)

for every record whose class-``c`` bytes dominate, so an ordinary
least-squares fit of measured time against ``x_c`` over the payload
sweep recovers ``1/bw_c`` as the slope and the startup alpha as the
intercept — the paper's "measured bandwidth of both link types" (§5.2)
obtained from the live system rather than a datasheet.

The fit is guarded: iterative outlier rejection (relative-residual
trim) and a confidence floor (point count, distinct payloads, R²,
positive slope) — an untrusted class contributes nothing, so a noisy or
short sweep degrades to "keep the nominal model" instead of poisoning
the planner.

:func:`fit_measurements` emits exactly the ``measurements`` mapping
``HardwareModel.recalibrated`` accepts: per-link bandwidth overrides for
every link of each trusted class, plus ``alpha_base`` when a relay-free
sweep pinned it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.latency_model import DEFAULT, HardwareModel
from repro.core.plan import BASELINE_PLAN
from repro.core.topology import Topology

from .probe import link_class, link_role
from .store import CalibrationStore, topo_key

LINK_CLASSES = ("intra", "inter")
# minimum points for the overlap-efficiency fit (decision-log rows with
# a measured time AND a non-degenerate serial/ideal bracket)
OVERLAP_MIN_POINTS = 3

# confidence floor defaults: a fit below any of these is not trusted
MIN_POINTS = 3
MIN_DISTINCT_PAYLOADS = 3
R2_FLOOR = 0.9
REL_OUTLIER = 0.35          # relative residual above this is rejected


@dataclasses.dataclass(frozen=True)
class FitResult:
    """One link class's fitted alpha/beta line."""

    link_class: str
    bw: float                  # bytes/s (1 / slope)
    alpha_s: float             # intercept
    n_used: int
    n_total: int
    n_rejected: int
    r2: float
    trusted: bool
    reason: str = ""           # why not trusted (empty when trusted)
    alpha_clean: bool = False  # intercept from relay-free single-stage
    #                            records only (safe to map to alpha_base)

    def report(self) -> dict:
        return {"class": self.link_class, "bw_gbps": self.bw / 1e9,
                "alpha_us": self.alpha_s * 1e6, "n_used": self.n_used,
                "n_rejected": self.n_rejected, "r2": round(self.r2, 4),
                "trusted": self.trusted, "reason": self.reason}


def _least_squares(x: np.ndarray, y: np.ndarray) -> tuple[float, float, float]:
    """(slope, intercept, r2) of y ~ slope*x + intercept."""
    slope, intercept = np.polyfit(x, y, 1)
    pred = slope * x + intercept
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0
    return float(slope), float(intercept), r2


def _dominant_class(rec: dict) -> str:
    """The link class whose serialization dominates this record — the
    stored bottleneck class (computed against nominal bandwidths at
    probe time)."""
    return rec.get("bottleneck_class", "intra")


def _dominant_role(rec: dict) -> str:
    """The directed link ROLE dominating this record; old-schema records
    without role fields fall back to the class (== role for intra)."""
    return rec.get("bottleneck_role", _dominant_class(rec))


def is_fit_record(rec: dict) -> bool:
    """Only each op's BASELINE plan feeds the regression: baselines are
    pure-serialization probes (t = alpha + bytes/bw, at most a small
    store-and-forward term), while the multiwrite plans add their own
    payload-linear relay/engine terms — points from different plans
    would fall on different lines and collapse the fit.  The fitted
    bandwidths then score EVERY plan through the shared latency model."""
    return rec.get("plan") == BASELINE_PLAN.get(rec.get("op"))


def fit_link_class(records: Sequence[dict], cls: str, *,
                   min_points: int = MIN_POINTS,
                   min_payloads: int = MIN_DISTINCT_PAYLOADS,
                   r2_floor: float = R2_FLOOR,
                   rel_outlier: float = REL_OUTLIER,
                   bytes_field: str = "class_bytes",
                   dominant_fn=None) -> Optional[FitResult]:
    """LS fit of one link GROUP (class or directed role) over the
    records that bottleneck on it.  Returns None when no record
    regresses against this group at all."""
    dominant_fn = dominant_fn or _dominant_class
    xs, ys, clean = [], [], []
    for r in records:
        if dominant_fn(r) != cls:
            continue
        x = float(r.get(bytes_field, {}).get(cls, 0.0))
        if x <= 0:
            continue
        xs.append(x)
        ys.append(float(r["measured_s"]))
        clean.append(not r.get("relayed", True)
                     and int(r.get("stages", 1)) == 1)
    if not xs:
        return None
    x = np.asarray(xs)
    y = np.asarray(ys)
    n_total = len(xs)

    def untrusted(reason, slope=0.0, intercept=0.0, r2=0.0, used=0, rej=0):
        bw = 1.0 / slope if slope > 0 else 0.0
        return FitResult(cls, bw, intercept, used, n_total, rej, r2,
                         trusted=False, reason=reason)

    if n_total < 2:
        return untrusted(f"{n_total} point(s): cannot regress", used=n_total)
    slope, intercept, r2 = _least_squares(x, y)
    keep = np.ones(n_total, bool)
    if slope > 0:
        rel = np.abs(y - (slope * x + intercept)) / np.maximum(y, 1e-12)
        keep = rel <= rel_outlier
        if keep.sum() >= 2 and keep.sum() < n_total:
            slope, intercept, r2 = _least_squares(x[keep], y[keep])
    n_used = int(keep.sum())
    n_rej = n_total - n_used
    if slope <= 0:
        return untrusted("non-positive slope (bw unidentifiable)",
                         slope, intercept, r2, n_used, n_rej)
    if n_used < min_points:
        return untrusted(f"{n_used} < {min_points} points after rejection",
                         slope, intercept, r2, n_used, n_rej)
    if len(np.unique(x[keep])) < min_payloads:
        return untrusted("payload sweep too narrow",
                         slope, intercept, r2, n_used, n_rej)
    if r2 < r2_floor:
        return untrusted(f"r2 {r2:.3f} < floor {r2_floor}",
                         slope, intercept, r2, n_used, n_rej)
    alpha_clean = all(c for c, k in zip(clean, keep) if k)
    return FitResult(cls, 1.0 / slope, max(0.0, intercept), n_used, n_total,
                     n_rej, r2, trusted=True, alpha_clean=alpha_clean)


def fit_link_classes(records: Sequence[dict], *,
                     classes: Sequence[str] = LINK_CLASSES,
                     baseline_only: bool = True,
                     **floor_kw) -> dict[str, FitResult]:
    if baseline_only:
        records = [r for r in records if is_fit_record(r)]
    out = {}
    for cls in classes:
        fit = fit_link_class(records, cls, **floor_kw)
        if fit is not None:
            out[cls] = fit
    return out


def fit_link_roles(records: Sequence[dict], *,
                   baseline_only: bool = True,
                   **floor_kw) -> dict[str, FitResult]:
    """Per-ROLE (directed) alpha/beta fits — the per-link refinement of
    :func:`fit_link_classes`.  Each ordered server pair's rails regress
    on their own line, so an asymmetric fabric (``2x8asym``: the return
    rails run at half bandwidth) fits both directions separately instead
    of collapsing them onto one "inter" slope.  The ``intra`` role is
    identical to the class fit and skipped here."""
    if baseline_only:
        records = [r for r in records if is_fit_record(r)]
    roles = sorted({_dominant_role(r) for r in records
                    if r.get("role_bytes")} - {"intra"})

    def inter_roles(rec: dict) -> list:
        return [k for k, v in rec.get("role_bytes", {}).items()
                if k != "intra" and v > 0]

    out = {}
    for role in roles:
        # a record witnesses a DIRECTED line cleanly only when its
        # ledger charges that one inter direction (the per-direction
        # p2p sweep).  A bidirectional record's measured time is set by
        # whichever direction is truly slower — under asymmetric
        # degradation that need not be the direction carrying the most
        # bytes, so such records sit on the WRONG line and poison the
        # regression (observed: the healthy return direction never
        # reaches a trusted fit, and recalibration churns every cycle).
        # When single-direction evidence exists, regress on it alone;
        # fabrics without direction probes keep the old mixed pool.
        sole = [r for r in records
                if _dominant_role(r) == role and len(inter_roles(r)) == 1]
        pool = sole if sole else records
        fit = fit_link_class(pool, role, bytes_field="role_bytes",
                             dominant_fn=_dominant_role, **floor_kw)
        if fit is not None:
            out[role] = fit
    return out


def fit_measurements(records: Sequence[dict], topo: Topology,
                     **floor_kw) -> tuple[dict, dict[str, FitResult]]:
    """(measurements, fits): the ``measurements`` dict feeds
    ``HardwareModel.recalibrated`` directly — per-link bandwidths for
    every link of each TRUSTED group, plus ``alpha_base`` when a
    relay-free sweep pinned the intercept.  Links take the directed
    per-ROLE fit when one cleared the confidence floor (asymmetric
    fabrics keep both rail directions distinct); the class-level fit is
    the fallback for every link of a NOMINALLY-UNIFORM class, while a
    heterogeneous class's unfitted directions keep their nominal
    bandwidth (see the inline rationale).  The returned ``fits`` dict
    carries both levels (classes under ``intra``/``inter``, roles under
    ``inter:a>b``).  Empty dict = nothing trustworthy, keep the current
    model."""
    fits = fit_link_classes(records, **floor_kw)
    role_fits = fit_link_roles(records, **floor_kw)
    # classes whose NOMINAL link bandwidths are uniform: their links are
    # interchangeable a priori, so the class fit generalizes to every
    # link (incl. directions that never bottlenecked — a uniform
    # degradation on a 4x8 fabric must override ALL 96 inter links even
    # though only a couple of directed roles ever set the max).  A
    # heterogeneous class (asymmetric / mixed-rail fabric) is different:
    # its class line is dominated by whichever direction bottlenecks,
    # carries no evidence about the others, and would mislabel them —
    # there only directed ROLE fits apply and unfitted links keep
    # nominal.
    nominal_by_class: dict[str, set] = {}
    for key, ln in topo.links.items():
        nominal_by_class.setdefault(link_class(topo, *key), set()).add(ln.bw)
    links = {}
    measurements: dict = {}
    for key in topo.links:
        cls = link_class(topo, *key)
        rf = role_fits.get(link_role(topo, *key))
        cf = fits.get(cls)
        if rf is not None and rf.trusted:
            links[key] = rf.bw
        elif cf is not None and cf.trusted and \
                len(nominal_by_class[cls]) == 1:
            links[key] = cf.bw
    intra = fits.get("intra")
    if (intra is not None and intra.trusted and intra.alpha_clean
            and intra.alpha_s > 0):
        measurements["alpha_base"] = intra.alpha_s
    if links:
        measurements["links"] = links
    elif "alpha_base" not in measurements:
        measurements = {}
    return measurements, {**fits, **role_fits}


def fit_overlap_eff(decision_rows: Sequence[dict], *,
                    min_points: int = OVERLAP_MIN_POINTS,
                    rel_span_floor: float = 0.02) -> Optional[float]:
    """Achieved overlap efficiency from ``Planner.decision_log`` rows.

    Every pipelined (``microbatch > 1``) decision is logged with its
    serial (``overlap_eff=0``) and ideal (``overlap_eff=1``) score
    endpoints; a measured execution time landing between them identifies
    the efficiency the pipeline actually achieved:

        eta  =  (serial - measured) / (serial - ideal)

    clamped to [0, 1].  Rows without a measurement, or whose endpoints
    coincide (non-pipelined decisions carry no overlap signal, gated by
    ``rel_span_floor``), contribute nothing.  Returns the MEDIAN eta
    over the contributing rows — robust to the odd straggler-polluted
    measurement — or None below ``min_points`` (keep the current
    calibration).  The result feeds ``HardwareModel.recalibrated`` as
    the ``overlap_eff`` scalar, closing the loop the same way the link
    bandwidth fits do."""
    etas = []
    for row in decision_rows:
        m = row.get("measured_s")
        s = row.get("predicted_serial_s")
        i = row.get("predicted_ideal_s")
        if m is None or not s or i is None:
            continue
        span = float(s) - float(i)
        if span <= rel_span_floor * float(s):
            continue
        etas.append(min(1.0, max(0.0, (float(s) - float(m)) / span)))
    if len(etas) < min_points:
        return None
    return float(np.median(etas))


# ---------------------------------------------------------------------------
# store -> HardwareModel (memoized — the ParallelContext / dryrun surface)
# ---------------------------------------------------------------------------

_HW_CACHE: dict[tuple, HardwareModel] = {}


def calibrated_hw(store: CalibrationStore, topo: Topology,
                  base: HardwareModel = DEFAULT) -> HardwareModel:
    """The hardware model the store's measurements imply for ``topo``:
    ``base`` recalibrated with the fitted per-class bandwidths, or
    ``base`` unchanged when the store has nothing trustworthy for this
    fabric.  Fits use the LATEST record per (op, plan, payload bucket),
    so re-probed buckets supersede stale history.  Memoized on (store
    instance + revision, fabric, base) — distinct ':memory:' stores
    never alias."""
    key = (store.version(), topo.fingerprint(), base.fingerprint())
    hit = _HW_CACHE.get(key)
    if hit is not None:
        return hit
    records = list(store.latest_by_key(fabric=topo_key(topo)).values())
    measurements, _ = fit_measurements(records, topo)
    hw = base.recalibrated(measurements, topo) if measurements else base
    if len(_HW_CACHE) > 64:
        _HW_CACHE.clear()
    _HW_CACHE[key] = hw
    return hw
