"""Per-link-class alpha/beta regression over probe sweeps (the FIT).

Each probe record carries the per-class bottleneck bytes of the plan it
timed (``class_bytes``).  For a link class ``c`` (``intra`` = in-server
full mesh, ``inter`` = rails) the latency model predicts

    t  =  alpha  +  x_c / bw_c  (+ small relay/engine terms)

for every record whose class-``c`` bytes dominate, so an ordinary
least-squares fit of measured time against ``x_c`` over the payload
sweep recovers ``1/bw_c`` as the slope and the startup alpha as the
intercept — the paper's "measured bandwidth of both link types" (§5.2)
obtained from the live system rather than a datasheet.

The fit is guarded: iterative outlier rejection (relative-residual
trim) and a confidence floor (point count, distinct payloads, R²,
positive slope) — an untrusted class contributes nothing, so a noisy or
short sweep degrades to "keep the nominal model" instead of poisoning
the planner.

:func:`fit_measurements` emits exactly the ``measurements`` mapping
``HardwareModel.recalibrated`` accepts: per-link bandwidth overrides for
every link of each trusted class, plus ``alpha_base`` when a relay-free
sweep pinned it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.latency_model import DEFAULT, HardwareModel
from repro.core.plan import BASELINE_PLAN
from repro.core.topology import Topology

from .probe import link_class
from .store import CalibrationStore, topo_key

LINK_CLASSES = ("intra", "inter")

# confidence floor defaults: a fit below any of these is not trusted
MIN_POINTS = 3
MIN_DISTINCT_PAYLOADS = 3
R2_FLOOR = 0.9
REL_OUTLIER = 0.35          # relative residual above this is rejected


@dataclasses.dataclass(frozen=True)
class FitResult:
    """One link class's fitted alpha/beta line."""

    link_class: str
    bw: float                  # bytes/s (1 / slope)
    alpha_s: float             # intercept
    n_used: int
    n_total: int
    n_rejected: int
    r2: float
    trusted: bool
    reason: str = ""           # why not trusted (empty when trusted)
    alpha_clean: bool = False  # intercept from relay-free single-stage
    #                            records only (safe to map to alpha_base)

    def report(self) -> dict:
        return {"class": self.link_class, "bw_gbps": self.bw / 1e9,
                "alpha_us": self.alpha_s * 1e6, "n_used": self.n_used,
                "n_rejected": self.n_rejected, "r2": round(self.r2, 4),
                "trusted": self.trusted, "reason": self.reason}


def _least_squares(x: np.ndarray, y: np.ndarray) -> tuple[float, float, float]:
    """(slope, intercept, r2) of y ~ slope*x + intercept."""
    slope, intercept = np.polyfit(x, y, 1)
    pred = slope * x + intercept
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0
    return float(slope), float(intercept), r2


def _dominant_class(rec: dict) -> str:
    """The link class whose serialization dominates this record — the
    stored bottleneck class (computed against nominal bandwidths at
    probe time)."""
    return rec.get("bottleneck_class", "intra")


def is_fit_record(rec: dict) -> bool:
    """Only each op's BASELINE plan feeds the regression: baselines are
    pure-serialization probes (t = alpha + bytes/bw, at most a small
    store-and-forward term), while the multiwrite plans add their own
    payload-linear relay/engine terms — points from different plans
    would fall on different lines and collapse the fit.  The fitted
    bandwidths then score EVERY plan through the shared latency model."""
    return rec.get("plan") == BASELINE_PLAN.get(rec.get("op"))


def fit_link_class(records: Sequence[dict], cls: str, *,
                   min_points: int = MIN_POINTS,
                   min_payloads: int = MIN_DISTINCT_PAYLOADS,
                   r2_floor: float = R2_FLOOR,
                   rel_outlier: float = REL_OUTLIER) -> Optional[FitResult]:
    """LS fit of one link class over the records that bottleneck on it.
    Returns None when no record regresses against this class at all."""
    xs, ys, clean = [], [], []
    for r in records:
        if _dominant_class(r) != cls:
            continue
        x = float(r.get("class_bytes", {}).get(cls, 0.0))
        if x <= 0:
            continue
        xs.append(x)
        ys.append(float(r["measured_s"]))
        clean.append(not r.get("relayed", True)
                     and int(r.get("stages", 1)) == 1)
    if not xs:
        return None
    x = np.asarray(xs)
    y = np.asarray(ys)
    n_total = len(xs)

    def untrusted(reason, slope=0.0, intercept=0.0, r2=0.0, used=0, rej=0):
        bw = 1.0 / slope if slope > 0 else 0.0
        return FitResult(cls, bw, intercept, used, n_total, rej, r2,
                         trusted=False, reason=reason)

    if n_total < 2:
        return untrusted(f"{n_total} point(s): cannot regress", used=n_total)
    slope, intercept, r2 = _least_squares(x, y)
    keep = np.ones(n_total, bool)
    if slope > 0:
        rel = np.abs(y - (slope * x + intercept)) / np.maximum(y, 1e-12)
        keep = rel <= rel_outlier
        if keep.sum() >= 2 and keep.sum() < n_total:
            slope, intercept, r2 = _least_squares(x[keep], y[keep])
    n_used = int(keep.sum())
    n_rej = n_total - n_used
    if slope <= 0:
        return untrusted("non-positive slope (bw unidentifiable)",
                         slope, intercept, r2, n_used, n_rej)
    if n_used < min_points:
        return untrusted(f"{n_used} < {min_points} points after rejection",
                         slope, intercept, r2, n_used, n_rej)
    if len(np.unique(x[keep])) < min_payloads:
        return untrusted("payload sweep too narrow",
                         slope, intercept, r2, n_used, n_rej)
    if r2 < r2_floor:
        return untrusted(f"r2 {r2:.3f} < floor {r2_floor}",
                         slope, intercept, r2, n_used, n_rej)
    alpha_clean = all(c for c, k in zip(clean, keep) if k)
    return FitResult(cls, 1.0 / slope, max(0.0, intercept), n_used, n_total,
                     n_rej, r2, trusted=True, alpha_clean=alpha_clean)


def fit_link_classes(records: Sequence[dict], *,
                     classes: Sequence[str] = LINK_CLASSES,
                     baseline_only: bool = True,
                     **floor_kw) -> dict[str, FitResult]:
    if baseline_only:
        records = [r for r in records if is_fit_record(r)]
    out = {}
    for cls in classes:
        fit = fit_link_class(records, cls, **floor_kw)
        if fit is not None:
            out[cls] = fit
    return out


def fit_measurements(records: Sequence[dict], topo: Topology,
                     **floor_kw) -> tuple[dict, dict[str, FitResult]]:
    """(measurements, fits): the ``measurements`` dict feeds
    ``HardwareModel.recalibrated`` directly — per-link bandwidths for
    every link of each TRUSTED class, plus ``alpha_base`` when a
    relay-free sweep pinned the intercept.  Empty dict = nothing
    trustworthy, keep the current model."""
    fits = fit_link_classes(records, **floor_kw)
    links = {}
    measurements: dict = {}
    for cls, fit in fits.items():
        if not fit.trusted:
            continue
        for key in topo.links:
            if link_class(topo, *key) == cls:
                links[key] = fit.bw
        if cls == "intra" and fit.alpha_clean and fit.alpha_s > 0:
            measurements["alpha_base"] = fit.alpha_s
    if links:
        measurements["links"] = links
    elif "alpha_base" not in measurements:
        measurements = {}
    return measurements, fits


# ---------------------------------------------------------------------------
# store -> HardwareModel (memoized — the ParallelContext / dryrun surface)
# ---------------------------------------------------------------------------

_HW_CACHE: dict[tuple, HardwareModel] = {}


def calibrated_hw(store: CalibrationStore, topo: Topology,
                  base: HardwareModel = DEFAULT) -> HardwareModel:
    """The hardware model the store's measurements imply for ``topo``:
    ``base`` recalibrated with the fitted per-class bandwidths, or
    ``base`` unchanged when the store has nothing trustworthy for this
    fabric.  Fits use the LATEST record per (op, plan, payload bucket),
    so re-probed buckets supersede stale history.  Memoized on (store
    instance + revision, fabric, base) — distinct ':memory:' stores
    never alias."""
    key = (store.version(), topo.fingerprint(), base.fingerprint())
    hit = _HW_CACHE.get(key)
    if hit is not None:
        return hit
    records = list(store.latest_by_key(fabric=topo_key(topo)).values())
    measurements, _ = fit_measurements(records, topo)
    hw = base.recalibrated(measurements, topo) if measurements else base
    if len(_HW_CACHE) > 64:
        _HW_CACHE.clear()
    _HW_CACHE[key] = hw
    return hw
